"""Distributed-numerics equivalence on a forced 8-device CPU mesh.

Each case runs in a subprocess (the device count must be set before jax
initializes) and asserts that the sharded computation matches the
single-device reference: TP, CP, EP (shard_map MoE), and the sharded train
step.
"""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig, TrainConfig
from repro.models import build_lm, init_lm, lm_forward
from repro.models import moe as M
from repro.sharding import ShardPlan, make_plan
from repro.launch.steps import init_train_state, make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

CASE = "%s"
mesh = jax.make_mesh((4, 2), ("data", "model"))

if CASE in ("tp", "cp"):
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=96,
                      remat="none", dtype="float32")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 96)
    ref, _, _ = lm_forward(params, lm, ShardPlan(mesh=None), tokens=toks)
    plan = make_plan(mesh, CASE)
    f = jax.jit(lambda p, t: lm_forward(p, lm, plan, tokens=t)[0])
    out = f(params, toks)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    print("OK", CASE)

elif CASE == "ep":
    cfg = ModelConfig(name="m", d_model=32, d_ff=64, dtype="float32",
                      moe=MoEConfig(num_experts=8, top_k=2,
                                    capacity_factor=8.0))
    mdef = M.make_moe(cfg)
    params = M.init_moe(jax.random.PRNGKey(0), mdef, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 16, 32))
    ref, _ = M.moe_forward(params, x, mdef, cfg)
    f = jax.jit(lambda p, x: M.moe_forward(p, x, mdef, cfg, mesh=mesh,
                                           dp_axes=("data",))[0])
    out = f(params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    print("OK ep")

elif CASE == "wire":
    # psum_int8 under a 2-device dp mesh: (a) the reduced gradient matches
    # the single-device grad_compress semantics (shared pmax block scale,
    # codes summed in a widened int32 accumulator, decoded once), (b) the
    # device-local error-feedback residual is preserved, (c) the ONLY
    # payload-sized collective operand is int8 — the dp_wire bytes really
    # are int8 on the wire.
    from jax.sharding import Mesh
    from repro.optim.grad_compress import WIRE_SPEC, psum_int8_tree
    from repro.numerics.codecs import blockwise_geometry
    from repro.sharding import ShardPlan, compat_shard_map

    plan = ShardPlan(mesh=None, dp_axes=("data",))
    assert plan.dp_axis() == "data" and ShardPlan(
        mesh=None, dp_axes=("pod", "data")).dp_axis() == ("pod", "data")

    mesh2 = Mesh(np.array(jax.devices()[:2]), ("data",))
    ndev = 2
    shapes = [(1500,), (7, 129), ()]
    key = jax.random.PRNGKey(0)
    gs = {f"g{i}": jax.random.normal(jax.random.fold_in(key, i),
                                     (ndev,) + s) * (i + 1)
          for i, s in enumerate(shapes)}
    rs = {f"g{i}": 0.01 * jax.random.normal(jax.random.fold_in(key, 100 + i),
                                            (ndev,) + s)
          for i, s in enumerate(shapes)}

    def local(g, r):
        g1 = jax.tree.map(lambda a: a[0], g)
        r1 = jax.tree.map(lambda a: a[0], r)
        out, nr = psum_int8_tree(g1, tuple(jax.tree_util.tree_leaves(r1)),
                                 "data", WIRE_SPEC)
        nr_tree = jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(g1), list(nr))
        return out, jax.tree.map(lambda a: a[None], nr_tree)

    f = compat_shard_map(local, mesh2, in_specs=(P("data"), P("data")),
                         out_specs=(P(), P("data")))
    out, nres = jax.jit(f)(gs, rs)

    # single-device oracle: the SAME per-shard quantize + widened code sum,
    # written as plain jnp over the stacked per-device axis — no mesh, no
    # collectives. The shard_map path must match it BITWISE: the int8 wire
    # changes where the bytes travel, not the values.
    @jax.jit
    def ref_leaf(gd, rd):                   # (ndev, *s) each
        flat = (gd.astype(jnp.float32) + rd).reshape(ndev, -1)
        n = flat.shape[1]
        b, nb, pad = blockwise_geometry(WIRE_SPEC, n)
        blocks = jnp.pad(flat, ((0, 0), (0, pad))).reshape(ndev, nb, b)
        sc = jnp.max(jnp.abs(blocks), axis=-1) / WIRE_SPEC.qmax
        sc = jnp.maximum(jnp.max(sc, axis=0), 1e-20)    # shared (pmax) scale
        codes = jnp.clip(jnp.round(blocks / sc[None, :, None]), -127, 127)
        total = jnp.sum(codes.astype(jnp.int32), axis=0)  # widened accum
        summed = (total.astype(jnp.float32) * sc[:, None]).reshape(-1)[:n]
        res = (blocks - codes * sc[None, :, None]).reshape(ndev, -1)[:, :n]
        return summed.reshape(gd.shape[1:]), res.reshape(gd.shape)

    for name, s in zip(sorted(gs), shapes):
        ref_sum, ref_res = ref_leaf(gs[name], rs[name])
        np.testing.assert_array_equal(np.asarray(out[name]),
                                      np.asarray(ref_sum))
        np.testing.assert_allclose(np.asarray(nres[name]),
                                   np.asarray(ref_res), atol=1e-6)
        # and the sum is the real gradient sum within quantization error
        exact = np.asarray(gs[name] + rs[name]).sum(0)
        tol = 2 * ndev * max(np.abs(np.asarray(gs[name])).max() / 127, 1e-6)
        np.testing.assert_allclose(np.asarray(out[name]), exact, atol=tol)

    # wire dtype: walk the jaxpr (incl. the shard_map body) — every
    # all_gather operand must be int8
    jaxpr = jax.make_jaxpr(f)(gs, rs)

    def walk(jx, found):
        for eqn in jx.eqns:
            if "all_gather" in eqn.primitive.name:
                found.append(eqn.invars[0].aval.dtype)
            for v in eqn.params.values():
                inner = getattr(v, "jaxpr", v)
                if hasattr(inner, "eqns"):
                    walk(inner, found)
        return found

    gathers = walk(jaxpr.jaxpr, [])
    assert gathers and all(d == jnp.dtype(jnp.int8) for d in gathers), gathers
    print("OK wire", len(gathers))

elif CASE == "train":
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=96,
                      remat="full", dtype="float32")
    lm = build_lm(cfg)
    tcfg = TrainConfig(total_steps=5, warmup_steps=1, grad_clip=1.0)
    params = init_lm(jax.random.PRNGKey(0), lm)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 96),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 96)}
    # reference single-device
    s0 = init_train_state(params, tcfg)
    _, m_ref = make_train_step(lm, ShardPlan(mesh=None), tcfg)(s0, batch)
    # sharded
    plan = make_plan(mesh, "tp")
    pspec = plan.params_pspec_tree(params)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                          is_leaf=lambda s: isinstance(s, P))
    params_sh = jax.device_put(params, pshard)
    s1 = init_train_state(params_sh, tcfg)
    step = jax.jit(make_train_step(lm, plan, tcfg))
    s1, m_sh = step(s1, batch)
    np.testing.assert_allclose(float(m_sh["loss"]), float(m_ref["loss"]),
                               rtol=2e-3)
    print("OK train", float(m_sh["loss"]))
"""


@pytest.mark.parametrize("case", ["tp", "cp", "ep", "train", "wire"])
def test_sharded_equivalence(case):
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT % case],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # pin the platform: the forced 8-device host mesh is a CPU
             # construct, and without this a libtpu install spins on TPU
             # metadata discovery inside the cleared env
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd="/root/repo")
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert f"OK" in r.stdout

"""Distributed-numerics equivalence on a forced 8-device CPU mesh.

Each case runs in a subprocess (the device count must be set before jax
initializes) and asserts that the sharded computation matches the
single-device reference: TP, CP, EP (shard_map MoE), and the sharded train
step.
"""
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig, TrainConfig
from repro.models import build_lm, init_lm, lm_forward
from repro.models import moe as M
from repro.sharding import ShardPlan, make_plan
from repro.launch.steps import init_train_state, make_train_step
from jax.sharding import NamedSharding, PartitionSpec as P

CASE = "%s"
mesh = jax.make_mesh((4, 2), ("data", "model"))

if CASE in ("tp", "cp"):
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=96,
                      remat="none", dtype="float32")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 96)
    ref, _, _ = lm_forward(params, lm, ShardPlan(mesh=None), tokens=toks)
    plan = make_plan(mesh, CASE)
    f = jax.jit(lambda p, t: lm_forward(p, lm, plan, tokens=t)[0])
    out = f(params, toks)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    print("OK", CASE)

elif CASE == "ep":
    cfg = ModelConfig(name="m", d_model=32, d_ff=64, dtype="float32",
                      moe=MoEConfig(num_experts=8, top_k=2,
                                    capacity_factor=8.0))
    mdef = M.make_moe(cfg)
    params = M.init_moe(jax.random.PRNGKey(0), mdef, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 16, 32))
    ref, _ = M.moe_forward(params, x, mdef, cfg)
    f = jax.jit(lambda p, x: M.moe_forward(p, x, mdef, cfg, mesh=mesh,
                                           dp_axes=("data",))[0])
    out = f(params, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-3, atol=2e-3)
    print("OK ep")

elif CASE == "train":
    cfg = ModelConfig(name="t", num_layers=2, d_model=64, num_heads=4,
                      num_kv_heads=2, d_ff=128, vocab_size=96,
                      remat="full", dtype="float32")
    lm = build_lm(cfg)
    tcfg = TrainConfig(total_steps=5, warmup_steps=1, grad_clip=1.0)
    params = init_lm(jax.random.PRNGKey(0), lm)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 96),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0, 96)}
    # reference single-device
    s0 = init_train_state(params, tcfg)
    _, m_ref = make_train_step(lm, ShardPlan(mesh=None), tcfg)(s0, batch)
    # sharded
    plan = make_plan(mesh, "tp")
    pspec = plan.params_pspec_tree(params)
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec,
                          is_leaf=lambda s: isinstance(s, P))
    params_sh = jax.device_put(params, pshard)
    s1 = init_train_state(params_sh, tcfg)
    step = jax.jit(make_train_step(lm, plan, tcfg))
    s1, m_sh = step(s1, batch)
    np.testing.assert_allclose(float(m_sh["loss"]), float(m_ref["loss"]),
                               rtol=2e-3)
    print("OK train", float(m_sh["loss"]))
"""


@pytest.mark.parametrize("case", ["tp", "cp", "ep", "train"])
def test_sharded_equivalence(case):
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT % case],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root"},
        cwd="/root/repo")
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert f"OK" in r.stdout

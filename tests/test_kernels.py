"""Per-kernel allclose vs pure-jnp oracle: shape/dtype sweeps in
interpret mode (the kernel body runs in Python on CPU; on TPU the same
BlockSpecs compile to MXU code)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ttm
from repro.kernels import ops, ref

PE1_SHAPES = [(37, 5, 48), (128, 1, 16), (8, 7, 130), (256, 16, 256),
              (1, 3, 16)]
PE2_SHAPES = [(19, 7, 33, 21), (8, 1, 128, 16), (64, 16, 256, 8),
              (1, 4, 16, 130)]
PE3_SHAPES = [(130, 47, 65), (64, 128, 128), (8, 1, 300), (256, 16, 16)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 \
        else dict(rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shape", PE1_SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_pe1_sweep(shape, dt):
    a, b, c = shape
    d = max(8, a // 2)
    z = jax.random.normal(jax.random.PRNGKey(0), (a, b, c)).astype(dt)
    g = jax.random.normal(jax.random.PRNGKey(1), (b, d, c)).astype(dt)
    np.testing.assert_allclose(
        np.asarray(ops.pe1(z, g), np.float32),
        np.asarray(ref.pe1_ref(z, g), np.float32), **_tol(dt))


@pytest.mark.parametrize("shape", PE1_SHAPES[:2])
def test_pe1_fused_requant(shape):
    a, b, c = shape
    d = max(8, a // 2)
    z = jax.random.normal(jax.random.PRNGKey(0), (a, b, c))
    g = jax.random.normal(jax.random.PRNGKey(1), (b, d, c))
    step = jnp.asarray(-4.0)
    np.testing.assert_allclose(
        ops.pe1(z, g, step_log2=step, bits=8),
        ref.pe1_quant_ref(z, g, step, 8), rtol=1e-5, atol=1e-5)


# PE1 fused-epilogue differential harness (mirrors test_paged_attention's
# oracle pattern): the in-kernel requant writeback must be BIT-identical to
# the codec-reference path — same tile-grid accumulation (the unfused
# kernel), epilogue applied through the registry's encode→decode.
# (256, 16, 256) exercises a multi-step K grid (b*c = 4096 -> 8 K-tiles).
PE1_EPILOGUE_SHAPES = [(37, 5, 48), (128, 1, 16), (256, 16, 256),
                       (8, 7, 130)]


@pytest.mark.parametrize("shape", PE1_EPILOGUE_SHAPES)
@pytest.mark.parametrize("bits", [4, 8])
def test_pe1_epilogue_bit_identical_to_codec(shape, bits):
    from repro import numerics as N
    a, b, c = shape
    d = max(8, a // 2)
    z = jax.random.normal(jax.random.PRNGKey(0), (a, b, c))
    g = jax.random.normal(jax.random.PRNGKey(1), (b, d, c))
    step = jnp.asarray(-3.0)
    fused = ops.pe1(z, g, step_log2=step, bits=bits, impl="pallas")
    # codec-reference path: identical accumulation (the unfused kernel over
    # the same tile grid), then the registry codec's encode->decode
    acc = ops.pe1(z, g, impl="pallas")
    spec = N.QuantSpec("pow2", bits, 0, "int8" if bits <= 8 else "int16")
    unfused = N.decode(N.encode(acc, spec, step), jnp.float32)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


@pytest.mark.parametrize("bits", [4, 8])
def test_pe1_jnp_impl_matches_reference_oracle(bits):
    """The "jnp" impl (registry-composed einsum + epilogue) equals the
    hand-written oracle — and the kernel stays allclose to it (float
    reassociation only)."""
    a, b, c = 37, 5, 48
    d = 16
    z = jax.random.normal(jax.random.PRNGKey(2), (a, b, c))
    g = jax.random.normal(jax.random.PRNGKey(3), (b, d, c))
    step = jnp.asarray(-4.0)
    jnp_out = ops.pe1(z, g, step_log2=step, bits=bits, impl="jnp")
    np.testing.assert_array_equal(
        np.asarray(jnp_out), np.asarray(ref.pe1_quant_ref(z, g, step, bits)))
    np.testing.assert_allclose(
        np.asarray(ops.pe1(z, g, step_log2=step, bits=bits, impl="pallas")),
        np.asarray(jnp_out), rtol=1e-4, atol=1e-4)


def test_pe1_epilogue_owned_by_registry():
    """The kernel's requant body IS the registry codec's epilogue — one
    implementation, checked by identity of the functions' outputs on the
    raw accumulator (guards against the epilogue drifting back to a
    hand-rolled copy)."""
    from repro import numerics as N
    from repro.numerics.codecs import get_codec
    acc = jax.random.normal(jax.random.PRNGKey(4), (64, 64)) * 7
    spec = N.QuantSpec("pow2", 8)
    step = jnp.asarray(-2.0)
    epi = get_codec(spec, "reference").epilogue(acc, spec, step)
    np.testing.assert_array_equal(
        np.asarray(epi),
        np.asarray(N.decode(N.encode(acc, spec, step), jnp.float32)))
    np.testing.assert_array_equal(np.asarray(epi),
                                  np.asarray(ref.quantize_ref(acc, step, 8)))


@pytest.mark.parametrize("shape", PE2_SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_pe2_sweep(shape, dt):
    a, b, c, d = shape
    z = jax.random.normal(jax.random.PRNGKey(0), (a, b, c)).astype(dt)
    g = jax.random.normal(jax.random.PRNGKey(1), (b, d)).astype(dt)
    np.testing.assert_allclose(
        np.asarray(ops.pe2(z, g), np.float32),
        np.asarray(ref.pe2_ref(z, g), np.float32), **_tol(dt))


@pytest.mark.parametrize("shape", PE3_SHAPES)
@pytest.mark.parametrize("dt", DTYPES)
def test_pe3_sweep(shape, dt):
    b, j, i = shape
    y = jax.random.normal(jax.random.PRNGKey(0), (b, j)).astype(dt)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, i)).astype(dt)
    np.testing.assert_allclose(
        np.asarray(ops.pe3(y, x), np.float32),
        np.asarray(ref.pe3_ref(y, x), np.float32), **_tol(dt))


@pytest.mark.parametrize("n", [100, 4096, 65536 + 17])
@pytest.mark.parametrize("bits", [4, 8, 16])
def test_quantize_sweep(n, bits):
    x = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 4
    step = jnp.asarray(-3.0)
    np.testing.assert_allclose(ops.quantize_fused(x, step, bits),
                               ref.quantize_ref(x, step, bits),
                               rtol=1e-6, atol=1e-6)


def test_full_ttm_chain_through_kernels():
    """Paper forward (Eqs. 8-10) routed through the PE kernels equals the
    einsum chain — the end-to-end kernel contract."""
    spec = ttm.make_spec(512, 896, 4, 16)
    cores = ttm.init_cores(jax.random.PRNGKey(5), spec)
    x = jax.random.normal(jax.random.PRNGKey(3), (6, 896))
    np.testing.assert_allclose(ops.ttm_matvec_kernels(cores, x, spec),
                               ttm.ttm_matvec(cores, x, spec),
                               rtol=1e-4, atol=1e-4)


def test_pe3_then_contract_grad_path():
    """PE3 kernel + Eq.14-19 contraction = autodiff core grads."""
    spec = ttm.make_spec(24, 30, 3, 6)
    cores = ttm.init_cores(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 30))
    ybar = jax.random.normal(jax.random.PRNGKey(2), (16, 24))
    what = ops.pe3(ybar, x)
    manual = ttm.core_grads_from_what(what, cores, spec)

    def loss(cores):
        return jnp.sum(ttm.ttm_matvec(cores, x, spec) * ybar)

    auto = jax.grad(loss)(cores)
    for a, m in zip(auto, manual):
        np.testing.assert_allclose(a, m, rtol=1e-3, atol=1e-3)

"""Speculative-decoding acceptance tests.

The contract: a spec engine (draft proposes k tokens, target verifies all
k+1 positions in ONE q-block kernel call, rejection sampling accepts a
prefix) emits tokens distributed exactly as the non-speculative engine —
and for greedy requests that means TOKEN-IDENTICAL output, because every
accept/replace decision reads argmax one-hots.

(a) greedy spec == greedy non-spec across fp32/int8 pools, gather/fused
    attention, a zoo draft (stablelm-3b drafting for yi-34b) and an
    independent random draft;
(b) page-pressure preemption + re-admission (rollback + draft re-prefill)
    keeps the identity;
(c) a self-draft (draft == target) accepts EVERYTHING — the canary for
    draft-cache consistency (a stale/missing draft K/V position shows up
    as acceptance < 1 long before it corrupts output);
(d) eos / max_new truncation mid-emission;
(e) telemetry: summary()["spec"] schema, ledger draft sites, spec_step
    trace events;
(f) config validation (missing draft, vocab mismatch, recurrent archs).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.models import build_lm, init_lm
from repro.serve import Engine, EngineConfig, PoolConfig, SamplingParams
from repro.sharding import ShardPlan

PLAN = ShardPlan(mesh=None)


def _setup(arch, seed=0, vocab=None):
    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    if vocab is not None:
        cfg = cfg.replace(vocab_size=vocab)
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(seed), lm)
    return cfg, lm, params


def _prompts(cfg, n, lo, hi, seed=3):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size,
                        int(rng.randint(lo, hi + 1))).tolist()
            for _ in range(n)]


def _run(lm, params, pcfg, prompts, gens, draft=None, spec_k=0,
         sampling=None, eos_id=-1, trace=None, **ekw):
    eng = Engine(lm, params, EngineConfig(pool=pcfg, spec_k=spec_k, **ekw),
                 PLAN, draft=draft, trace=trace)
    rids = [eng.submit(p, max_new_tokens=g,
                       sampling=sampling or SamplingParams(), eos_id=eos_id)
            for p, g in zip(prompts, gens)]
    res = eng.run()
    return [res[r].tokens for r in rids], eng


# ---------------------------------------------------------------------------
# (a) greedy token identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantized,fused",
                         [(False, False), (True, False), (True, True)])
def test_greedy_spec_identical_to_nonspec(quantized, fused):
    """Zoo draft pair: stablelm-3b (draft) proposes for yi-34b (target),
    staggered ragged requests on 2 slots, generations crossing page
    boundaries."""
    cfg, lm, params = _setup("yi-34b")
    _, dlm, dparams = _setup("stablelm-3b", seed=1, vocab=cfg.vocab_size)
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=8,
                      quantized=quantized)
    prompts = _prompts(cfg, 4, 5, 14)
    gens = [12, 9, 11, 10]
    ref, _ = _run(lm, params, pcfg, prompts, gens, fused_attention=fused)
    out, eng = _run(lm, params, pcfg, prompts, gens, draft=(dlm, dparams),
                    spec_k=3, fused_attention=fused)
    assert out == ref
    spec = eng.summary()["spec"]
    assert spec["steps"] > 0 and spec["proposed"] > 0
    # first token per request comes from prefill, not a spec step
    assert spec["emitted"] == sum(len(t) for t in out) - len(out)


def test_spec_k_variants_all_identical():
    """The emitted stream must not depend on k."""
    cfg, lm, params = _setup("yi-34b")
    _, dlm, dparams = _setup("stablelm-3b", seed=1, vocab=cfg.vocab_size)
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=8,
                      quantized=False)
    prompts = _prompts(cfg, 2, 6, 12, seed=5)
    gens = [10, 8]
    ref, _ = _run(lm, params, pcfg, prompts, gens)
    for k in (1, 2, 4):
        out, _ = _run(lm, params, pcfg, prompts, gens, draft=(dlm, dparams),
                      spec_k=k)
        assert out == ref, k


# ---------------------------------------------------------------------------
# (b) preemption / rollback
# ---------------------------------------------------------------------------

def test_spec_preemption_and_resume_identity():
    cfg, lm, params = _setup("yi-34b")
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=8,
                      num_pages=5, quantized=False)
    prompts = _prompts(cfg, 4, 5, 12, seed=7)
    gens = [12, 12, 12, 12]
    ref, ref_eng = _run(lm, params, pcfg, prompts, gens)
    out, eng = _run(lm, params, pcfg, prompts, gens, draft=(lm, params),
                    spec_k=3)
    assert eng.summary()["preemptions"] >= 1
    assert ref_eng.summary()["preemptions"] >= 1
    assert out == ref


def test_spec_rollback_frees_overhang_pages():
    """A rejected draft span must not leak its speculatively-mapped pages:
    after every request retires all pages are back on the free list."""
    cfg, lm, params = _setup("yi-34b")
    _, dlm, dparams = _setup("stablelm-3b", seed=2, vocab=cfg.vocab_size)
    pcfg = PoolConfig(num_slots=2, page_size=4, pages_per_slot=10,
                      quantized=False)
    prompts = _prompts(cfg, 3, 5, 10, seed=9)
    out, eng = _run(lm, params, pcfg, prompts, [9, 8, 7],
                    draft=(dlm, dparams), spec_k=4)
    assert eng.sched.alloc.free_pages == pcfg.total_pages


# ---------------------------------------------------------------------------
# (c) self-draft acceptance canary
# ---------------------------------------------------------------------------

def test_self_draft_accepts_everything():
    """draft == target on the gather path: every proposal must be accepted
    (greedy AND sampled — P == Q makes the accept test pass with prob 1).
    Anything below 1.0 means the draft's cache diverged from the target's
    context (e.g. the last proposal's K/V missing after a fully-accepted
    block)."""
    cfg, lm, params = _setup("yi-34b")
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=8,
                      quantized=False)
    prompts = _prompts(cfg, 4, 5, 14)
    for sampling in (SamplingParams(),
                     SamplingParams(temperature=0.9, top_k=20, top_p=0.95)):
        out, eng = _run(lm, params, pcfg, prompts, [12, 9, 11, 10],
                        draft=(lm, params), spec_k=3, sampling=sampling)
        spec = eng.summary()["spec"]
        assert spec["acceptance_rate"] == 1.0, (sampling, spec)
        assert spec["tokens_per_step"] > 1.0


def test_sampled_spec_runs_and_completes():
    """Sampled requests with an independent draft: rejection sampling keeps
    every request completing to its full horizon."""
    cfg, lm, params = _setup("yi-34b")
    _, dlm, dparams = _setup("stablelm-3b", seed=1, vocab=cfg.vocab_size)
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=8,
                      quantized=True)
    prompts = _prompts(cfg, 3, 5, 12, seed=11)
    out, eng = _run(lm, params, pcfg, prompts, [8, 8, 8],
                    draft=(dlm, dparams), spec_k=2,
                    sampling=SamplingParams(temperature=1.0, top_k=40,
                                            top_p=0.9))
    assert all(len(t) == 8 for t in out)


# ---------------------------------------------------------------------------
# (d) truncation
# ---------------------------------------------------------------------------

def test_eos_truncates_mid_block():
    """Pick eos = a token the greedy reference emits mid-stream: the spec
    engine must stop at exactly the same place even when that token lands
    in the middle of an accepted draft block."""
    cfg, lm, params = _setup("yi-34b")
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=8,
                      quantized=False)
    prompts = _prompts(cfg, 2, 6, 12, seed=13)
    ref, _ = _run(lm, params, pcfg, prompts, [12, 12])
    eos = ref[0][4]     # 5th generated token of request 0
    ref_e, _ = _run(lm, params, pcfg, prompts, [12, 12], eos_id=eos)
    out_e, eng = _run(lm, params, pcfg, prompts, [12, 12],
                      draft=(lm, params), spec_k=3, eos_id=eos)
    assert out_e == ref_e
    assert out_e[0][-1] == eos and len(out_e[0]) <= 5


def test_max_new_exact():
    cfg, lm, params = _setup("yi-34b")
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=8,
                      quantized=False)
    prompts = _prompts(cfg, 2, 6, 10, seed=15)
    # max_new not a multiple of k+1: the last block must truncate
    out, _ = _run(lm, params, pcfg, prompts, [7, 5], draft=(lm, params),
                  spec_k=3)
    assert [len(t) for t in out] == [7, 5]


# ---------------------------------------------------------------------------
# (e) telemetry
# ---------------------------------------------------------------------------

def test_spec_summary_ledger_and_trace():
    from repro.obs import TraceRecorder
    cfg, lm, params = _setup("yi-34b")
    _, dlm, dparams = _setup("stablelm-3b", seed=1, vocab=cfg.vocab_size)
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=8,
                      quantized=True)
    trace = TraceRecorder()
    prompts = _prompts(cfg, 2, 6, 12, seed=17)
    out, eng = _run(lm, params, pcfg, prompts, [8, 6],
                    draft=(dlm, dparams), spec_k=2, trace=trace)
    s = eng.summary()
    spec = s["spec"]
    for key in ("steps", "proposed", "accepted", "emitted",
                "acceptance_rate", "tokens_per_step"):
        assert key in spec
    assert spec["proposed"] >= spec["accepted"] >= 0
    assert 0.0 <= spec["acceptance_rate"] <= 1.0
    assert spec["emitted"] == sum(len(t) for t in out) - len(out)
    # ledger: draft sites are counted residents
    sites = s["memory"]["sites"]
    assert "draft_params" in sites and "draft_kv_pool" in sites
    assert sites["draft_kv_pool"]["bytes"] > 0
    # trace: spec_step events carry the acceptance telemetry
    ev = trace.events("spec_step")
    assert ev and all("accepted" in e.fields and "proposed" in e.fields
                      for e in ev)
    assert sum(e.fields["emitted"] for e in ev) == spec["emitted"]


# ---------------------------------------------------------------------------
# (f) validation
# ---------------------------------------------------------------------------

def test_spec_requires_draft_and_matching_vocab():
    cfg, lm, params = _setup("yi-34b")
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=8)
    with pytest.raises(ValueError, match="draft"):
        Engine(lm, params, EngineConfig(pool=pcfg, spec_k=2), PLAN)
    _, dlm, dparams = _setup("stablelm-3b", seed=1,
                             vocab=cfg.vocab_size + 1)
    with pytest.raises(ValueError, match="vocab"):
        Engine(lm, params, EngineConfig(pool=pcfg, spec_k=2), PLAN,
               draft=(dlm, dparams))
    with pytest.raises(ValueError, match="spec_k"):
        Engine(lm, params, EngineConfig(pool=pcfg, spec_k=-1), PLAN)


def test_spec_rejects_recurrent_archs():
    cfg, lm, params = _setup("yi-34b")
    rcfg, rlm, rparams = _setup("rwkv6-1.6b")
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=8)
    # recurrent draft
    with pytest.raises(NotImplementedError, match="DRAFT"):
        Engine(lm, params, EngineConfig(pool=pcfg, spec_k=2), PLAN,
               draft=(rlm, rparams))
    # recurrent target
    _, dlm, dparams = _setup("stablelm-3b", seed=1,
                             vocab=rcfg.vocab_size)
    with pytest.raises(NotImplementedError, match="TARGET"):
        Engine(rlm, rparams, EngineConfig(pool=pcfg, spec_k=2), PLAN,
               draft=(dlm, dparams))

"""ShardPlan latent-mesh regressions (no multi-device mesh needed):

(a) ``_div`` on an absent mesh axis answers "don't shard", not KeyError —
    the dp-only 1-D mesh is a first-class citizen,
(b) the replicate-guard in ``param_spec`` matches exact leaf names; a
    zoo-wide audit asserts every >= 2-D projection leaf in every registered
    config gets a non-trivial spec on an 8-way model mesh,
(c) ``params_pspec_tree`` is a single in-place tree_map_with_path pass —
    distinct tree paths can never collide through their "/"-joined strings.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import AbstractMesh, PartitionSpec as P

import repro.configs as C
from repro.models import build_lm, init_lm, lm_forward
from repro.sharding import ShardPlan, _REPLICATED_LEAVES, _div, make_plan

MESH8 = AbstractMesh((("data", 1), ("model", 8)))
DP8 = AbstractMesh((("data", 8),))


# ---------------------------------------------------------------------------
# (a) absent mesh axes
# ---------------------------------------------------------------------------

def test_div_absent_axis_is_false_not_keyerror():
    assert _div(64, DP8, "model") is False          # was: KeyError
    assert _div(64, DP8, ("pod", "data")) is False  # partially absent tuple
    assert _div(64, DP8, "data") is True
    assert _div(63, DP8, "data") is False
    assert _div(4, DP8, "data") is False            # smaller than the axis
    assert _div(64, None, "data") is False
    assert _div(64, DP8, None) is False


def test_param_spec_on_dp_only_mesh():
    plan = ShardPlan(mesh=DP8, strategy="tp")
    # every site that used to index mesh.shape["model"] directly
    for key, shape in [("layers/attn/q/w", (64, 512)),
                       ("layers/ffn/up/w", (64, 96)),
                       ("embed/w", (256, 64))]:
        spec = plan.param_spec(key, shape)
        assert "model" not in jax.tree_util.tree_leaves(tuple(spec))
    assert plan.model_size() == 1
    assert plan.shards_kv_heads(8) is False
    assert plan.kv_page_spec((2, 9, 8, 8, 16)) == P(None, None, None, None,
                                                    None)
    assert plan.state_spec("h", (2, 4, 128, 8)) == P(None, None, None, None)


def test_dp_only_mesh_forward_matches_meshless():
    """lm_forward under a dp-only mesh (the KeyError repro: _div and the
    attention chunk constraint both indexed the absent ``model`` axis)."""
    cfg = C.get_reduced("internlm2-1.8b").replace(dtype="float32",
                                                  remat="none")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    ref, _, _ = lm_forward(params, lm, ShardPlan(mesh=None), tokens=toks)
    mesh = jax.make_mesh((1,), ("data",))
    out, _, _ = jax.jit(
        lambda p, t: lm_forward(p, lm, make_plan(mesh, "tp"), tokens=t))(
            params, toks)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# (b) replicate-guard: exact names + zoo-wide audit
# ---------------------------------------------------------------------------

def _leaf_items(shapes):
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        yield key, leaf


@pytest.mark.parametrize("arch", sorted(C.ARCHS))
def test_param_spec_zoo_audit(arch):
    """Every >= 2-D leaf that is not an exact-name replicated vector (or a
    TT core/lambda) must receive a non-trivial spec on an 8-way model mesh.
    The old bare-prefix guard ("b", "u", "D", ...) would silently replicate
    any future projection leaf sharing a first letter — this audit turns
    that class of bug into a test failure."""
    cfg = C.get_config(arch)
    lm = build_lm(cfg)
    shapes = jax.eval_shape(lambda k: init_lm(k, lm), jax.random.PRNGKey(0))
    plan = ShardPlan(mesh=MESH8, strategy=C.get_strategy(arch))
    audited = 0
    for key, leaf in _leaf_items(shapes):
        name = key.split("/")[-1]
        if leaf.ndim < 2 or name in _REPLICATED_LEAVES \
                or name.startswith(("core_", "lambda_")):
            continue
        spec = plan.param_spec(key, leaf.shape)
        assert any(ax is not None for ax in spec), \
            f"{arch}: projection leaf {key} {leaf.shape} replicated by " \
            f"{plan.strategy} plan: {spec}"
        audited += 1
    assert audited > 0


def test_replicated_leaves_stay_replicated():
    plan = ShardPlan(mesh=MESH8, strategy="tp")
    # stacked 2-D forms of the replicated vectors (leading layer axis)
    for name, shape in [("b", (2, 64)), ("u", (2, 4, 16)),
                        ("mu_x", (2, 1, 64)), ("A_log", (2, 128)),
                        ("conv_w", (2, 4, 128)), ("wscale_log2", (2, 8)),
                        ("core_0", (1, 4, 8, 8)), ("lambda_3", (2, 2))]:
        assert plan.param_spec(f"layers/x/{name}", shape) == P(), name


# ---------------------------------------------------------------------------
# (c) single-pass params_pspec_tree
# ---------------------------------------------------------------------------

def test_params_pspec_tree_no_path_collision():
    """Two distinct tree paths whose "/"-joined strings are identical must
    each get their own spec (the old dict-keyed double-flatten overwrote one
    with the other)."""
    plan = ShardPlan(mesh=MESH8, strategy="tp")
    a = jax.ShapeDtypeStruct((64, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((2, 64, 512), jnp.float32)
    params = {"layers": {"attn/q": {"w": a}},
              "layers/attn": {"q": {"w": b}}}   # both join to layers/attn/q/w
    specs = plan.params_pspec_tree(params)
    assert jax.tree_util.tree_structure(
        specs, is_leaf=lambda s: isinstance(s, P)) == \
        jax.tree_util.tree_structure(params)
    assert specs["layers"]["attn/q"]["w"] == \
        plan.param_spec("layers/attn/q/w", a.shape)
    assert specs["layers/attn"]["q"]["w"] == \
        plan.param_spec("layers/attn/q/w", b.shape)
    # and the two shapes really do yield different specs
    assert specs["layers"]["attn/q"]["w"] != specs["layers/attn"]["q"]["w"]


def test_params_pspec_tree_matches_per_leaf_param_spec():
    cfg = C.get_reduced("jamba-1.5-large").replace(dtype="float32",
                                                   remat="none")
    lm = build_lm(cfg)
    shapes = jax.eval_shape(lambda k: init_lm(k, lm), jax.random.PRNGKey(0))
    plan = ShardPlan(mesh=MESH8, strategy="tp")
    specs = plan.params_pspec_tree(shapes)
    flat_specs = dict(
        ("/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                  for p in path), s)
        for path, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda s: isinstance(s, P))[0])
    for key, leaf in _leaf_items(shapes):
        assert flat_specs[key] == plan.param_spec(key, leaf.shape), key

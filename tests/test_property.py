"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow      # fuzz suite: extended/sharded CI job

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quant as Q
from repro.core import rank_adapt as RA
from repro.core import ttm

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


@st.composite
def spec_strategy(draw):
    d = draw(st.integers(1, 4))
    j_dims = tuple(draw(st.integers(1, 6)) for _ in range(d))
    i_dims = tuple(draw(st.integers(1, 6)) for _ in range(d))
    r = draw(st.integers(1, 6))
    return ttm.make_spec(int(np.prod(j_dims)), int(np.prod(i_dims)), d, r,
                         j_dims=j_dims, i_dims=i_dims)


@given(spec_strategy(), st.integers(0, 2 ** 31 - 1))
def test_ttm_matvec_is_linear_and_matches_dense(spec, seed):
    cores = ttm.init_cores(jax.random.PRNGKey(seed % 2 ** 31), spec)
    x = jax.random.normal(jax.random.PRNGKey((seed + 1) % 2 ** 31),
                          (3, spec.in_dim))
    w = ttm.ttm_to_dense(cores, spec)
    y = ttm.ttm_matvec(cores, x, spec)
    np.testing.assert_allclose(y, x @ w.T, rtol=5e-3, atol=5e-3)
    # linearity
    y2 = ttm.ttm_matvec(cores, 2.0 * x, spec)
    np.testing.assert_allclose(y2, 2.0 * y, rtol=5e-3, atol=5e-3)


@given(spec_strategy())
def test_ttm_param_count_never_exceeds_formula(spec):
    total = sum(spec.ranks[n] * spec.j_dims[n] * spec.i_dims[n]
                * spec.ranks[n + 1] for n in range(spec.d))
    assert spec.num_params == total


@given(st.integers(2, 16), st.floats(-8, 8), st.integers(0, 2 ** 31 - 1))
def test_quant_bounded_error(bits, step_log2, seed):
    """|Q(x) - x| <= scale/2 inside the representable range."""
    x = jax.random.normal(jax.random.PRNGKey(seed % 2 ** 31), (64,)) * 2.0
    step = jnp.asarray(step_log2, jnp.float32)
    y = Q.fake_quant(x, step, bits)
    scale = float(jnp.exp2(step))
    lo = -(2 ** (bits - 1)) * scale
    hi = (2 ** (bits - 1) - 1) * scale
    inside = (np.asarray(x) >= lo) & (np.asarray(x) <= hi)
    err = np.abs(np.asarray(y) - np.asarray(x))
    assert (err[inside] <= scale / 2 + 1e-6).all()


@given(st.integers(0, 2 ** 31 - 1))
def test_quant_idempotent(seed):
    x = jax.random.normal(jax.random.PRNGKey(seed % 2 ** 31), (32,))
    q1 = Q.quantize_store(x, jnp.asarray(-2.0), 8)
    q2 = Q.quantize_store(q1, jnp.asarray(-2.0), 8)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@given(spec_strategy(), st.integers(0, 2 ** 31 - 1))
def test_lambda_update_matches_eq4_exactly(spec, seed):
    if spec.d < 2:
        return
    cores = ttm.init_cores(jax.random.PRNGKey(seed % 2 ** 31), spec)
    lambdas = RA.update_lambdas(cores, spec)
    for n in range(spec.d - 1):
        expect = 2.0 / (1 + spec.ranks[n] * spec.i_dims[n] * spec.j_dims[n]) \
            * np.sum(np.square(np.asarray(cores[n], np.float64)),
                     axis=(0, 1, 2))
        np.testing.assert_allclose(np.asarray(lambdas[n]),
                                   np.maximum(expect, RA.LAMBDA_FLOOR),
                                   rtol=1e-4)


@given(st.integers(0, 2 ** 31 - 1), st.integers(10, 60))
def test_paged_pool_slot_isolation(seed, steps):
    """Random submit/admit/decode/retire/preempt sequences preserve the
    paged KV pool's isolation invariants: no slot ever reads another slot's
    pages, page accounting stays disjoint, and writes to retired/inactive
    slots land on the trash page (see tests/pool_walk.py; a deterministic
    seed sweep in test_serve.py keeps this exercised without hypothesis)."""
    from pool_walk import run_pool_walk
    run_pool_walk(seed, steps)


@given(st.integers(0, 2 ** 31 - 1), st.integers(10, 60))
def test_prefix_cache_sharing_invariants(seed, steps):
    """Random walks over a prefix-cache-enabled scheduler preserve the
    sharing invariants: page refcounts equal the live-reader count, shared
    (tree-owned) pages are never written through after insertion, COW forks
    carry the source page bit-exactly, ownership partitions (free list /
    tree / private) stay disjoint, and every slot's gathered view equals
    the token-derived expectation whether it prefilled or hit the cache
    (see tests/pool_walk.py::run_prefix_walk)."""
    from pool_walk import run_prefix_walk
    run_prefix_walk(seed, steps)


@given(st.integers(1, 64), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
def test_scale_manager_monotone_response(n, k, seed):
    """Scaling the input up never decreases the chosen exponent."""
    s1 = Q.init_scale(0)
    s2 = Q.init_scale(0)
    x = jax.random.normal(jax.random.PRNGKey(seed % 2 ** 31), (max(n, 2),))
    for _ in range(30):
        s1 = Q.update_scale(s1, x)
        s2 = Q.update_scale(s2, x * (2.0 ** k))
    assert int(s2.log2) >= int(s1.log2)

"""repro.obs acceptance tests — the unified telemetry layer:

(a) counter registry + the preserved ``fallback_count`` view,
(b) quant-health device aggregates agree BITWISE across codec backends,
(c) trace recorder: deterministic-clock lifecycle reconstructs a properly
    nested admit→preempt→resume→retire span tree, ring overflow keeps the
    newest events, JSONL and Chrome-trace exports round-trip,
(d) zero-overhead guarantees: an attached (or disabled) recorder leaves the
    engine's decode jaxpr byte-identical, and a health-off policy's decode
    and train-step jaxprs match a policy-free / health-free build,
(e) ServeMetrics edge cases: unknown-rid hooks don't crash, wall clock
    covers still-running requests, and health folds into ``summary()``.
"""
import itertools
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro import numerics as N
from repro.models import build_lm, init_lm
from repro.obs import (CounterRegistry, TraceRecorder, check_nesting,
                       chrome_trace, fraction, kernel_costs, pow2_clip_stats,
                       read_jsonl, record_kernel_call, request_spans,
                       saturation_counts, scale_drift_stats, tree_sat_stats,
                       write_jsonl)
from repro.serve import Engine, EngineConfig, PoolConfig
from repro.serve.metrics import ServeMetrics
from repro.sharding import ShardPlan

PLAN = ShardPlan(mesh=None)


def _counter_clock():
    c = itertools.count()
    return lambda: float(next(c))


# ---------------------------------------------------------------------------
# (a) counters
# ---------------------------------------------------------------------------

def test_counter_registry_basics():
    r = CounterRegistry()
    r.inc("a.b")
    r.inc("a.b", 4)
    r.inc("z")
    assert r.get("a.b") == 5 and r.get("z") == 1 and r.get("missing") == 0
    assert r.snapshot("a.") == {"a.b": 5}
    r.reset("a.b")
    assert r.get("a.b") == 0 and r.get("z") == 1
    r.reset()
    assert r.snapshot() == {}


def test_kernel_cost_table_handles_dotted_names():
    # the global registry keeps kernel.<name>.<field>; <name> itself may be
    # dotted (pe1.pallas) — the table must split on the LAST dot only
    record_kernel_call("obs_test.pallas", bytes_moved=128, flops=7)
    record_kernel_call("obs_test.pallas")
    costs = kernel_costs()["obs_test.pallas"]
    assert costs["calls"] >= 2 and costs["bytes"] >= 128
    assert costs["flops"] >= 7


def test_fallback_count_is_a_registry_view():
    """``pallas_backend.fallback_count`` is now a view over the shared
    registry (``numerics.codec_fallback``) — both directions must agree."""
    from repro.numerics import pallas_backend as PB
    from repro.obs import registry
    PB.reset_fallback_count()
    assert PB.fallback_count() == 0
    registry.inc(PB.FALLBACK_COUNTER, 3)
    assert PB.fallback_count() == 3
    PB.reset_fallback_count()
    assert registry.get(PB.FALLBACK_COUNTER) == 0


# ---------------------------------------------------------------------------
# (b) quant-health aggregates — bitwise backend agreement
# ---------------------------------------------------------------------------

def test_clip_and_saturation_counts_bit_agree_across_backends():
    spec = N.QuantSpec("pow2", 8, 0, "int8", "per_tensor_max")
    x = jax.random.normal(jax.random.PRNGKey(0), (6, 64)) * 8
    sc = jnp.asarray(np.random.RandomState(1).randint(-4, 0, (6,)),
                     jnp.float32)
    clipped, total = pow2_clip_stats(x, sc, spec.bits)
    # manual oracle
    r = np.asarray(x) / np.exp2(np.asarray(sc))[:, None]
    lo, hi = N.qrange(8)
    assert int(total) == x.size
    assert int(clipped) == int(((r < lo) | (r > hi)).sum())
    # the counts are integer-exact: both backends' encodes agree bitwise,
    # and so do the saturation counts over them
    sat = {}
    for backend in N.BACKENDS:
        qt = N.encode(x, spec, sc, backend=backend)
        sat[backend] = tuple(int(v) for v in saturation_counts(qt))
    assert sat["reference"] == sat["pallas"]
    # every clipped value saturates (plus values exactly at the edge)
    assert sat["reference"][0] >= int(clipped)
    assert sat["reference"][1] == x.size


def test_clip_stats_valid_mask_and_drift():
    x = jnp.ones((4, 8)) * 1000.0           # everything clips at scale 2^0
    clipped, total = pow2_clip_stats(
        x, jnp.zeros((4,)), 8, valid=jnp.asarray([1, 1, 0, 0],
                                                 bool)[:, None])
    assert int(clipped) == 16 and int(total) == 16
    dsum, dn = scale_drift_stats(jnp.zeros((4,)),
                                 jnp.asarray([1.0, -2.0, 5.0, 0.0]),
                                 valid=jnp.asarray([1, 1, 0, 1], bool))
    assert float(dsum) == 3.0 and float(dn) == 3.0
    assert float(fraction(jnp.asarray(0), jnp.asarray(0))) == 0.0
    assert float(fraction(jnp.asarray(3), jnp.asarray(4))) == 0.75


def test_tree_sat_stats_counts_float_leaves_only():
    tree = {"w": jnp.ones((8, 4)) * 5.0,    # saturates a fixed tiny scale
            "idx": jnp.arange(3, dtype=jnp.int32)}
    spec = N.QuantSpec("pow2", 8, 0, "int8", "per_tensor_max")
    sat, tot = tree_sat_stats(tree, spec)
    assert int(tot) == 32                    # int leaf excluded
    # per-tensor-max scale is clip-free: only exact-edge values saturate
    sat2, _ = tree_sat_stats(tree, spec, scale_for=lambda g: jnp.asarray(-8.0))
    assert int(sat2) == 32                   # tiny fixed scale: all saturate


def test_fake_quant_stats_returns_value_and_counts():
    spec = N.QuantSpec("pow2", 8)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 16)) * 4
    y, (clipped, total) = N.fake_quant_stats(x, spec, jnp.asarray(-2.0))
    np.testing.assert_array_equal(
        np.asarray(y), np.asarray(N.fake_quant(x, spec, jnp.asarray(-2.0))))
    assert int(total) == x.size and int(clipped) >= 0


# ---------------------------------------------------------------------------
# (c) trace recorder
# ---------------------------------------------------------------------------

def _lifecycle_recorder() -> TraceRecorder:
    rec = TraceRecorder(clock=_counter_clock())
    rec.emit("submit", rid=1, prompt_len=4, max_new=8)          # t=0
    rec.emit("admit", rid=1, slot=0, pages=1)                   # t=1
    rec.emit("prefill", rid=1, slot=0, len=4, dur=1.0)          # t=2
    rec.emit("first_token", rid=1, slot=0)                      # t=3
    rec.emit("preempt", rid=1, slot=0, gen_len=2)               # t=4
    rec.emit("admit", rid=1, slot=1, pages=1)                   # t=5 resume
    rec.emit("prefill", rid=1, slot=1, len=6, dur=1.0)          # t=6
    rec.emit("retire", rid=1, slot=1, new_tokens=8,
             reason="max_new")                                  # t=7
    return rec


def test_lifecycle_span_nesting_admit_preempt_resume_retire():
    spans = request_spans(_lifecycle_recorder().events())
    s = spans[1]
    assert (s.start, s.end, s.dur) == (0.0, 7.0, 7.0)
    assert [c.name for c in s.children] == ["scheduled", "scheduled"]
    first, second = s.children
    assert first.fields["outcome"] == "preempted"
    assert (first.start, first.end) == (1.0, 4.0)
    assert second.fields["outcome"] == "retired"
    assert (second.start, second.end) == (5.0, 7.0)
    # prefill child sits inside its residency (start backdated by dur)
    assert [c.name for c in first.children] == ["prefill"]
    assert first.children[0].start == 1.0 and first.children[0].end == 2.0
    assert check_nesting(s)
    assert s.fields["reason"] == "max_new"


def test_ring_overflow_keeps_newest_and_counts_drops():
    rec = TraceRecorder(capacity=4, clock=_counter_clock())
    for i in range(10):
        rec.emit("decode_step", step=i)
    assert len(rec) == 4 and rec.dropped == 6
    assert [e.fields["step"] for e in rec.events()] == [6, 7, 8, 9]
    assert len(rec.events("decode_step")) == 4
    rec.clear()
    assert len(rec) == 0 and rec.dropped == 0
    with pytest.raises(ValueError):
        TraceRecorder(capacity=0)


def test_disabled_recorder_emits_nothing():
    rec = TraceRecorder(clock=_counter_clock())
    rec.enabled = False
    rec.emit("submit", rid=0)
    assert len(rec) == 0


def test_jsonl_round_trip(tmp_path):
    rec = _lifecycle_recorder()
    path = str(tmp_path / "trace.jsonl")
    assert write_jsonl(rec, path) == 8
    back = read_jsonl(path)
    assert [(e.ts, e.kind, e.fields) for e in back] == \
        [(e.ts, e.kind, e.fields) for e in rec.events()]


def test_chrome_trace_round_trips_and_rebases():
    doc = json.loads(json.dumps(chrome_trace(_lifecycle_recorder())))
    evs = doc["traceEvents"]
    assert len(evs) == 8
    # ts rebased to the first event; us units
    assert evs[0]["ts"] == 0.0
    # dur events (prefill) are complete slices backdated by their duration
    slices = [e for e in evs if e["ph"] == "X"]
    assert {e["name"] for e in slices} == {"prefill"}
    assert slices[0]["ts"] == 1e6 and slices[0]["dur"] == 1e6
    # request lifecycle: one async begin per admit, one end per
    # preempt/retire, shared id
    bars = [e for e in evs if e["ph"] in ("b", "e")]
    assert [e["ph"] for e in bars] == ["b", "e", "b", "e"]
    assert all(e["cat"] == "request" and e["id"] == 1 for e in bars)


# ---------------------------------------------------------------------------
# (d) zero overhead — jaxpr identity
# ---------------------------------------------------------------------------

def _serve_setup():
    cfg = C.get_reduced("internlm2-1.8b").replace(dtype="float32",
                                                  remat="none")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    return cfg, lm, params


def _decode_jaxpr(eng) -> str:
    B = eng.pcfg.num_slots
    table = jnp.zeros((B, eng.pcfg.pages_per_slot), jnp.int32)
    lens = jnp.ones((B,), jnp.int32)
    active = jnp.ones((B,), bool)
    tokens = jnp.zeros((B, 1), jnp.int32)
    return str(jax.make_jaxpr(eng._decode_impl)(
        eng.params, eng.pool, eng.spool, table, lens, active, tokens))


def test_recorder_and_health_off_leave_decode_jaxpr_identical():
    cfg, lm, params = _serve_setup()
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=3,
                      quantized=True)
    base = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
    traced = Engine(lm, params, EngineConfig(pool=pcfg), PLAN,
                    trace=TraceRecorder(clock=_counter_clock()))
    # a policy with health OFF resolves to the same pool numerics
    pol_off = N.NumericsPolicy(enable=True, health=False)
    off = Engine(lm, params, EngineConfig(pool=pcfg, policy=pol_off), PLAN)
    ref = _decode_jaxpr(base)
    assert _decode_jaxpr(traced) == ref, \
        "an attached recorder must not change the decode jaxpr"
    assert _decode_jaxpr(off) == ref, \
        "health=False must trace the exact health-free decode step"
    # sanity: switching health ON does change the program
    pol_on = N.NumericsPolicy(enable=True, health=True)
    on = Engine(lm, params, EngineConfig(pool=pcfg, policy=pol_on), PLAN)
    assert _decode_jaxpr(on) != ref


def test_train_step_health_gating_jaxpr_and_schema():
    import dataclasses

    from repro.configs.base import ModelConfig, TrainConfig
    from repro.launch.steps import init_train_state, make_train_step

    def build(health):
        cfg = ModelConfig(name="t", num_layers=1, d_model=32, num_heads=2,
                          num_kv_heads=2, d_ff=64, vocab_size=64,
                          remat="none", dtype="float32")
        cfg = cfg.replace(quant=dataclasses.replace(
            cfg.quant, enable=True, health=health))
        lm = build_lm(cfg)
        params = init_lm(jax.random.PRNGKey(0), lm)
        tcfg = TrainConfig(learning_rate=1e-3, total_steps=4)
        state = init_train_state(params, tcfg, policy=cfg.quant.policy())
        return make_train_step(lm, PLAN, tcfg), state

    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "labels": jnp.zeros((2, 8), jnp.int32)}
    step_off, state_off = build(False)
    step_on, state_on = build(True)
    jx_off = str(jax.make_jaxpr(step_off)(state_off, batch))
    jx_on = str(jax.make_jaxpr(step_on)(state_on, batch))
    assert jx_on != jx_off
    # schema: health metrics appear exactly when the policy asks
    _, m_off = jax.eval_shape(step_off, state_off, batch)
    _, m_on = jax.eval_shape(step_on, state_on, batch)
    assert "health" not in m_off
    h = m_on["health"]
    assert set(h["grad_edge"]) >= {"sat_fraction", "saturated", "total"}
    assert {"scale_log2", "mean_abs", "in_band"} <= set(h["activation"])


# ---------------------------------------------------------------------------
# (e) ServeMetrics + engine-driven trace
# ---------------------------------------------------------------------------

def test_metrics_unknown_rid_hooks_do_not_crash():
    m = ServeMetrics(clock=_counter_clock())
    m.request_finished(99, 5)               # never submitted
    m.request_first_token(7)
    m.request_admitted(7, prompt_len=3)
    s = m.summary()
    assert s["requests_completed"] == 1 and s["generated_tokens"] == 5


def test_metrics_wall_clock_covers_running_requests():
    clk = {"t": 0.0}
    m = ServeMetrics(clock=lambda: clk["t"])
    m.request_submitted(0)
    m.request_admitted(0, 4)                # t0 = 0
    clk["t"] = 10.0
    m.request_first_token(0)
    m.request_finished(0, 10)
    # a second request is still running: wall must extend past the last
    # finish or tokens_per_s is inflated
    m.request_submitted(1)
    m.request_admitted(1, 4)
    clk["t"] = 40.0
    s = m.summary()
    assert s["wall_s"] == 40.0
    assert s["tokens_per_s"] == pytest.approx(10 / 40.0)
    # once everything finished, wall snaps back to the last finish time
    m.request_finished(1, 4)
    assert m.summary()["wall_s"] == 40.0


def test_metrics_timeline_and_health_summary():
    m = ServeMetrics(clock=_counter_clock())
    m.num_slots = 4
    m.decode_step(4, free_pages=10, dur=0.5)
    m.decode_step(2, free_pages=6, dur=0.5)
    m.record_health("kv_cache", 3, 100)
    m.record_health("kv_cache", 1, 100)
    m.record_health("ssm_state", 0, 50, drift_sum=2.0, drift_n=4.0)
    s = m.summary()
    assert s["batch_fill_mean"] == 3.0 and s["batch_fill_frac"] == 0.75
    assert s["free_pages_min"] == 6
    kv = s["quant_health"]["kv_cache"]
    assert kv == {"clipped": 4, "total": 200, "clip_fraction": 0.02,
                  "scale_drift_log2": 0.0}
    assert s["quant_health"]["ssm_state"]["scale_drift_log2"] == 0.5


def test_engine_emits_trace_and_kv_health():
    cfg, lm, params = _serve_setup()
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                      quantized=True)
    pol = N.NumericsPolicy(enable=True, health=True)
    rec = TraceRecorder()
    eng = Engine(lm, params, EngineConfig(pool=pcfg, policy=pol), PLAN,
                 trace=rec)
    rng = np.random.RandomState(0)
    rids = [eng.submit(rng.randint(0, cfg.vocab_size, 6).tolist(),
                       max_new_tokens=4) for _ in range(3)]
    res = eng.run()
    assert sorted(res) == sorted(rids)
    kinds = {e.kind for e in rec}
    assert {"submit", "admit", "prefill", "first_token", "decode_step",
            "retire"} <= kinds
    assert {"page_alloc", "page_free"} <= kinds
    # every request span closes and nests
    spans = request_spans(rec.events())
    assert sorted(spans) == sorted(rids)
    for s in spans.values():
        assert s.end is not None and check_nesting(s)
    # decode steps carry durations and the batch-fill timeline matches
    steps = rec.events("decode_step")
    assert steps and all(e.fields["dur"] >= 0 for e in steps)
    assert len(eng.metrics.timeline) == len(steps)
    # kv-site quant health flowed into the summary with sane values
    kv = eng.summary()["quant_health"]["kv_cache"]
    assert kv["total"] > 0
    assert 0.0 <= kv["clip_fraction"] < 0.5

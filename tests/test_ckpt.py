"""Checkpointing: roundtrip, atomicity, async writer, resume, GC."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as CK


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "nested": {"b": jnp.ones((5,), jnp.int32),
                       "c": (jnp.zeros((2, 2)), jnp.full((3,), 2.5))}}


def test_roundtrip(tmp_path):
    t = _tree()
    path = str(tmp_path / "x.ckpt")
    CK.save(path, t, {"step": 7})
    back, meta = CK.load(path, like=t)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_allclose(a, b)


def test_dtype_cast_on_restore(tmp_path):
    t = {"w": jnp.ones((4,), jnp.float32)}
    path = str(tmp_path / "x.ckpt")
    CK.save(path, t)
    like = {"w": jnp.zeros((4,), jnp.bfloat16)}
    back, _ = CK.load(path, like=like)
    assert back["w"].dtype == jnp.bfloat16


def test_missing_key_raises(tmp_path):
    path = str(tmp_path / "x.ckpt")
    CK.save(path, {"a": jnp.ones(3)})
    with pytest.raises(KeyError):
        CK.load(path, like={"a": jnp.ones(3), "b": jnp.ones(2)})


def test_no_tmp_left_behind(tmp_path):
    path = str(tmp_path / "x.ckpt")
    CK.save(path, _tree())
    assert not os.path.exists(path + ".tmp")


def test_async_checkpointer_and_gc(tmp_path):
    ck = CK.AsyncCheckpointer(str(tmp_path), keep=2)
    for step in (10, 20, 30, 40):
        ck.save(step, {"w": jnp.full((4,), float(step))})
    ck.wait()
    ck.close()
    assert CK.latest_step(str(tmp_path)) == 40
    steps = sorted(int(f.split("_")[1].split(".")[0])
                   for f in os.listdir(tmp_path) if f.endswith(".ckpt"))
    assert steps == [30, 40]    # GC kept last 2
    back, meta = CK.load(CK.step_path(str(tmp_path), 40),
                         like={"w": jnp.zeros((4,))})
    assert meta["step"] == 40
    np.testing.assert_allclose(back["w"], 40.0)


def test_elastic_restore_resharding(tmp_path):
    """Mesh-agnostic restore: save unsharded, load with a device_put target
    (single-device here; the same path reshards onto any mesh)."""
    t = {"w": jnp.arange(16, dtype=jnp.float32)}
    path = str(tmp_path / "x.ckpt")
    CK.save(path, t)
    shard = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    back, _ = CK.load(path, like=t, sharding_tree={"w": shard})
    np.testing.assert_allclose(back["w"], t["w"])
    assert back["w"].sharding == shard

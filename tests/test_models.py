"""Per-arch reduced-config smoke tests (assignment requirement): one
forward/train step on CPU asserting output shapes + no NaNs; decode
consistency for the decode-capable families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import TrainConfig
from repro.launch.steps import init_train_state, make_loss_fn, make_train_step
from repro.models import (build_lm, init_lm, lm_decode_step, lm_forward,
                          lm_init_cache)
from repro.sharding import ShardPlan

PLAN = ShardPlan(mesh=None)
ARCHS = sorted(C.ARCHS)


def _batch(cfg, b=2, s=16):
    key = jax.random.PRNGKey(7)
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    if cfg.frontend == "audio":
        return {"frames": jax.random.normal(key, (b, s, cfg.d_model)),
                "labels": labels}
    if cfg.frontend == "vision":
        p = s // 2
        return {"patches": jax.random.normal(key, (b, p, cfg.d_model)),
                "tokens": toks[:, :s - p], "labels": labels[:, :s - p]}
    return {"tokens": toks, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_no_nan(arch):
    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    batch = _batch(cfg)
    kwargs = {}
    if cfg.frontend == "audio":
        kwargs["embeds"] = batch["frames"]
    elif cfg.frontend == "vision":
        kwargs["embeds"] = batch["patches"]
        kwargs["tokens"] = batch["tokens"]
    else:
        kwargs["tokens"] = batch["tokens"]
    logits, aux, _ = lm_forward(params, lm, PLAN, **kwargs)
    b = batch["labels"].shape[0]
    s_total = (batch["frames"].shape[1] if cfg.frontend == "audio" else
               (batch["patches"].shape[1] + batch["tokens"].shape[1]
                if cfg.frontend == "vision" else batch["tokens"].shape[1]))
    assert logits.shape == (b, s_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    lm = build_lm(cfg)
    tcfg = TrainConfig(total_steps=10, warmup_steps=1)
    params = init_lm(jax.random.PRNGKey(0), lm)
    state = init_train_state(params, tcfg)
    step = jax.jit(make_train_step(lm, PLAN, tcfg), donate_argnums=(0,))
    batch = _batch(cfg)
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    state, metrics2 = step(state, batch)
    assert float(metrics2["ce"]) < float(metrics["ce"]) + 1.0


DECODE_ARCHS = [a for a in ARCHS if not C.get_config(a).is_encoder]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_reduced_decode_matches_prefill(arch):
    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    if cfg.moe.num_experts:
        # capacity *dropping* is batch-size dependent (GShard semantics):
        # batched prefill drops tokens a one-token decode step keeps. Use a
        # drop-free capacity here; drop behaviour is asserted in
        # test_moe.py::test_capacity_drops_tokens.
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=64.0))
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    b, s = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                              cfg.vocab_size)
    ref_logits, _, _ = lm_forward(params, lm, PLAN, tokens=toks)
    cache = lm_init_cache(lm, b, s, PLAN)
    outs = []
    for t in range(s):
        lg, cache = lm_decode_step(params, cache, toks[:, t:t + 1],
                                   jnp.int32(t), lm, PLAN)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, 1)
    tol = 2e-2 if cfg.moe.num_experts else 2e-4
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref_logits),
                               rtol=tol, atol=tol)


def test_tt_enabled_arch_compresses():
    cfg = C.get_reduced("internlm2-1.8b").replace(dtype="float32",
                                                  remat="none")
    cfg = C.with_tt(cfg, d=3, max_rank=8)
    cfg = cfg.replace(tt=cfg.tt.__class__(**{**cfg.tt.__dict__,
                                             "min_elements": 1024}))
    lm = build_lm(cfg)
    from repro.models import lm_param_counts
    params = init_lm(jax.random.PRNGKey(0), lm)
    counts = lm_param_counts(params, lm)
    assert counts["compression"] > 1.5, counts
    batch = _batch(cfg)
    logits, _, _ = lm_forward(params, lm, PLAN, tokens=batch["tokens"])
    assert not bool(jnp.isnan(logits).any())

"""Train-step numerics harness: the paper's memory-reduction table as an
executable test.

One full low-precision train step runs on (a) the FMNIST TT config (the
paper's own experiment) and (b) a small zoo LM through the unified step
factory, and every byte class of the training wire is accounted per
NumericsPolicy site:

- ``activation``        8-bit pow2 residual-stream edges (lm_forward scales)
- ``grad_edge``         16-bit pow2 weight-gradient rounding
- ``optimizer_moment``  blockwise-int8 Adam m/v QTensors
- ``dp_wire``           blockwise-int8 gradient wire (+ error feedback)
- ``tt_factor``         packed int4x2 deploy export (two codes per byte)

The acceptance claim: measured training memory (activations + tt_factor +
moments + wire) on the FMNIST TT config is >= 8x smaller than the fp32
dense baseline (the paper's Table-1 comparison; it reports 292x counting
parameters alone).
"""
import importlib.util
import os
import pathlib
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import numerics as N
from repro.ckpt import export_tt_deploy, load_tt_deploy
from repro.configs.base import (ModelConfig, QuantConfig, TTConfig,
                                TrainConfig)

# the bench module is the single owner of the FMNIST step construction and
# the per-site byte accounting — the executable test asserts the SAME
# numbers the BENCH_train_wire.json artifact reports (no drift possible)
_BENCH_PATH = (pathlib.Path(__file__).resolve().parent.parent
               / "benchmarks" / "train_wire.py")
_spec = importlib.util.spec_from_file_location("train_wire_bench",
                                               _BENCH_PATH)
TW = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(TW)

BATCH = 64


def test_fmnist_low_precision_step_trains():
    r = TW.fmnist_low_precision_step(BATCH)
    assert np.isfinite(float(r["loss"]))
    moved = [np.abs(np.asarray(r["new_params"]["l1"][f"core_{n}"])
                    - np.asarray(r["params"]["l1"][f"core_{n}"])).max()
             for n in range(r["d"].spec1.d)]
    assert max(moved) > 0
    # the int8 optimizer state really is QTensors after the step
    qts = [m for m in r["opt"].m if m is not None]
    assert qts and all(isinstance(m, N.QTensor) for m in qts)


def test_fmnist_train_wire_memory_table():
    """The executable Table-1: per-site measured bytes vs the fp32 dense
    baseline; >= 8x total reduction is the acceptance bar (measured is far
    higher — the paper reports 292x on parameters alone)."""
    r = TW.fmnist_low_precision_step(BATCH)
    path = os.path.join(tempfile.mkdtemp(), "deploy.ckpt")
    sites, baseline, _ = TW.fmnist_site_table(r, deploy_path=path)

    low = sum(sites.values())
    base = sum(baseline.values())
    reduction = base / low
    print(f"\ntrain-wire bytes: {sites} -> {low} "
          f"(fp32 dense baseline {base}, reduction {reduction:.1f}x)")
    assert reduction >= 8.0, (sites, baseline, reduction)

    # each site individually beats its fp32 counterpart by ~the bit ratio
    assert sites["activation"] * 3.5 < baseline["activation"]
    assert sites["tt_factor"] * 7 < baseline["tt_factor"]
    assert sites["dp_wire"] * 3.5 < baseline["dp_wire"]

    # deploy export round-trips onto the 4-bit grid
    loaded, _ = load_tt_deploy(path)
    new_params = r["new_params"]
    steps = new_params["l1"]["wscale_log2"]
    ref = N.decode(N.encode(new_params["l1"]["core_0"],
                            N.QuantSpec("pow2", 4),
                            steps[0].astype(jnp.float32)))
    np.testing.assert_array_equal(np.asarray(loaded["l1"]["core_0"]),
                                  np.asarray(ref))


# ---------------------------------------------------------------------------
# zoo LM: the unified step factory with the policy-owned activation site
# ---------------------------------------------------------------------------

def _tiny_tt_lm():
    cfg = ModelConfig(name="t", num_layers=2, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64,
                      remat="none", dtype="float32",
                      tt=TTConfig(enable=True, d=3, max_rank=4,
                                  min_elements=1024),
                      quant=QuantConfig(enable=True))
    from repro.models import build_lm, init_lm
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    return cfg, lm, params


def _lm_batch(b=2, s=16, vocab=64):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(1), (b, s), 0,
                                         vocab),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (b, s), 0,
                                         vocab)}


def test_lm_train_step_runs_activation_site():
    """The zoo-LM half of the ROADMAP gap: quant edges live in lm_forward,
    scale state carried in TrainState.scales and advanced by the step."""
    from repro.launch.steps import init_train_state, make_train_step
    from repro.sharding import ShardPlan
    cfg, lm, params = _tiny_tt_lm()
    tcfg = TrainConfig(total_steps=5, warmup_steps=1, grad_compress=True,
                       opt_state_dtype="int8")
    state = init_train_state(params, tcfg, policy=cfg.quant.policy())
    assert set(state.scales) == {"activation", "grad_edge"}
    step = jax.jit(make_train_step(lm, ShardPlan(mesh=None), tcfg))
    batch = _lm_batch()
    s0_mean = float(state.scales["activation"].mean_abs)
    for _ in range(3):
        state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    # the §3.3 manager observed the forward activations and moved its stat
    assert float(state.scales["activation"].mean_abs) != s0_mean
    # error feedback is live alongside
    assert any(np.abs(np.asarray(r)).max() > 0
               for r in state.residual if r is not None)


def test_lm_activation_edges_quantize_forward():
    """With scales, the residual stream is actually fake-quantized (logits
    differ from the unquantized forward and coarsen with the scale), and
    the obs statistic is returned for the manager."""
    from repro.models.lm import lm_forward
    from repro.numerics.policy import ScaleState
    from repro.sharding import ShardPlan
    cfg, lm, params = _tiny_tt_lm()
    plan = ShardPlan(mesh=None)
    batch = _lm_batch()
    scales = cfg.quant.policy().init_scales()
    lq, _, _, obs = lm_forward(params, lm, plan, tokens=batch["tokens"],
                               scales=scales)
    lf, _, _ = lm_forward(params, lm, plan, tokens=batch["tokens"])
    assert np.abs(np.asarray(lq) - np.asarray(lf)).max() > 0
    assert float(obs["activation"][0]) > 0
    # an absurdly coarse activation scale crushes the stream to zero —
    # proof the edge sits ON the forward values, not beside them
    dead = dict(scales)
    dead["activation"] = ScaleState(jnp.asarray(30, jnp.int32),
                                    scales["activation"].mean_abs)
    ld, _, _, _ = lm_forward(params, lm, plan, tokens=batch["tokens"],
                             scales=dead)
    assert np.abs(np.asarray(ld)).max() < np.abs(np.asarray(lq)).max()


def test_lm_grad_accum_carries_activation_scales():
    """n_micro=1 grad-accum matches the plain step INCLUDING the new scale
    updates (extends the PR-2 residual-semantics contract)."""
    from repro.launch.steps import (init_train_state,
                                    make_grad_accum_train_step,
                                    make_train_step)
    from repro.sharding import ShardPlan
    cfg, lm, params = _tiny_tt_lm()
    tcfg = TrainConfig(total_steps=5, warmup_steps=1, grad_compress=True)
    plan = ShardPlan(mesh=None)
    batch = _lm_batch()
    s0 = init_train_state(params, tcfg, policy=cfg.quant.policy())
    s1, m1 = jax.jit(make_train_step(lm, plan, tcfg))(s0, batch)
    s2, m2 = jax.jit(make_grad_accum_train_step(lm, plan, tcfg, 1))(
        s0, jax.tree.map(lambda a: a[None], batch))
    for a, b in zip(jax.tree_util.tree_leaves(s1.scales),
                    jax.tree_util.tree_leaves(s2.scales)):
        # rtol 1e-4: the observed-|activation| stat is reassociated
        # differently by XLA across the two compiled programs
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_lm_train_wire_byte_table():
    """Per-site accounting for the zoo LM config: every byte class of one
    train step is policy-governed and smaller than its fp32 shadow."""
    from repro.launch.steps import init_train_state, make_train_step
    from repro.sharding import ShardPlan
    cfg, lm, params = _tiny_tt_lm()
    policy = cfg.quant.policy()
    tcfg = TrainConfig(total_steps=5, warmup_steps=1, grad_compress=True,
                       opt_state_dtype="int8")
    state = init_train_state(params, tcfg, policy=policy)
    step = jax.jit(make_train_step(lm, ShardPlan(mesh=None), tcfg))
    state, _ = step(state, _lm_batch())

    b, s, dm = 2, 16, cfg.d_model
    n_edges = cfg.num_layers + 1            # embed + per-sublayer edges
    table = {}
    table["activation"] = n_edges * policy.nbytes("activation", (b, s, dm))
    fp32_act = n_edges * b * s * dm * 4
    table["optimizer_moment"] = sum(
        m.nbytes() for m in (*state.opt.m, *state.opt.v)
        if isinstance(m, N.QTensor))
    float_param_bytes = sum(
        l.nbytes for l in jax.tree_util.tree_leaves(state.params)
        if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.floating))
    table["dp_wire"] = sum(
        policy.nbytes("dp_wire", (int(l.size),))
        for l in jax.tree_util.tree_leaves(state.params)
        if hasattr(l, "dtype") and jnp.issubdtype(l.dtype, jnp.floating))
    path = os.path.join(tempfile.mkdtemp(), "lm_deploy.ckpt")
    stats = export_tt_deploy(path, state.params, policy=policy)
    table["tt_factor"] = stats["packed_bytes"]

    assert table["activation"] * 3.5 < fp32_act
    # tiny TT cores clamp the moment block to the trailing rank (4), so one
    # f32 scale amortizes over only 4 codes — 2x is the honest bound here
    # (production-size leaves hit the full 256-block ~3.9x)
    assert table["optimizer_moment"] * 2 < 2 * float_param_bytes
    assert table["dp_wire"] * 3.5 < float_param_bytes
    assert table["tt_factor"] * 7 < stats["fp32_bytes"]
    print(f"\nlm train-wire bytes: {table}")

"""Prefix-cache acceptance tests (serve/prefix.py + scheduler/engine wiring):

(a) radix-tree mechanics in isolation — match/insert/split, the
    len(prompt)-1 cap, refcount pinning vs LRU eviction,
(b) the COW/scale pool primitives carry codes bitwise,
(c) engine decode with the prefix cache enabled is token-identical to
    cache-disabled decode — fp32 and int8, including COW divergence
    mid-page, eviction under page pressure, and preempt/resume,
(d) an int8 cache hit is exactly a cache-off run with a chunk boundary at
    the resume position (the bitwise-recompute contract),
(e) stateful archs (recurrent sublayers) bypass the cache entirely,
(f) the bounded compile cache evicts jitted prefill shapes without
    changing tokens; MoE chunked-prefill capacity parity routes chunks
    like whole-prompt at capacity-bound loads.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import ModelConfig, MoEConfig
from repro.models import build_lm, init_lm
from repro.models import moe as M
from repro.serve import (CompileCache, Engine, EngineConfig, PoolConfig,
                         RadixPrefixCache, bucket_len)
from repro.serve import kv_cache as KC
from repro.sharding import ShardPlan

PLAN = ShardPlan(mesh=None)


def _setup(arch="internlm2-1.8b"):
    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    return cfg, lm, params


def _run(lm, params, prompts, pcfg, gens, **ekw):
    """One engine over ``prompts`` (submitted in order); returns the token
    lists in submission order plus the summary."""
    eng = Engine(lm, params, EngineConfig(pool=pcfg, **ekw), PLAN)
    rids = [eng.submit(p, max_new_tokens=g) for p, g in zip(prompts, gens)]
    res = eng.run()
    return [res[r].tokens for r in rids], eng.summary()


# ---------------------------------------------------------------------------
# (a) radix-tree mechanics, no engine
# ---------------------------------------------------------------------------

def test_radix_match_insert_split():
    pc = RadixPrefixCache(page_size=4, num_pages=16)
    A = list(range(100, 112))               # 12 tokens = 3 pages
    assert pc.match(A) is None              # empty tree
    assert pc.insert(A, [0, 1, 2], scales=None) == [0, 1, 2]
    # extension of the cached path: all 3 pages shared, no fork
    m = pc.match(A + [1, 2])
    assert (m.shared_pages, m.fork_src, m.resume) == ([0, 1, 2], None, 12)
    # the exact cached prompt: capped at len-1, so the last page forks
    m2 = pc.match(A)
    assert m2.shared_pages == [0, 1] and m2.resume == 11
    assert (m2.fork_src, m2.fork_tokens) == (2, 3)
    # mid-page divergence at position 6: one shared page + a 2-token fork
    B = A[:6] + [999, 998] + A[8:]
    mb = pc.match(B)
    assert mb.shared_pages == [0] and (mb.fork_src, mb.fork_tokens) == (1, 2)
    assert mb.resume == 6
    # inserting the diverging path splits the edge at the page boundary:
    # page 0 stays shared, pages 3,4 are newly donated
    assert pc.insert(B, [0, 3, 4], scales=None) == [3, 4]
    assert pc.num_nodes() == 3 and pc.owned_pages == {0, 1, 2, 3, 4}
    # both paths still match in full after the split
    assert pc.match(A + [7]).shared_pages == [0, 1, 2]
    assert pc.match(B + [7]).shared_pages == [0, 3, 4]


def test_radix_refcounts_pin_against_eviction():
    pc = RadixPrefixCache(page_size=4, num_pages=16)
    A = list(range(50, 62))
    pc.insert(A, [5, 6, 7], scales=None)
    m = pc.match(A)                         # shared [5,6], fork 7
    pc.acquire(m)
    # every owned page is either shared or the fork source: nothing to evict
    assert pc.evict(99) == []
    pc.release(m.shared_pages + [m.fork_src])
    freed = pc.evict(99)
    assert sorted(freed) == [5, 6, 7]
    assert pc.owned_pages == set() and pc.num_nodes() == 0
    assert pc.evictions >= 1 and pc.pages_evicted == 3
    assert pc.match(A + [1]) is None


def test_radix_lru_evicts_coldest_leaf_first():
    pc = RadixPrefixCache(page_size=2, num_pages=16)
    pc.insert([1, 2, 3, 4], [0, 1], scales=None)
    pc.insert([1, 2, 9, 9], [0, 2], scales=None)    # splits; leaves [1],[2]
    pc.match([1, 2, 3, 4, 5])               # warm the [3,4] branch
    freed = pc.evict(1)
    assert freed == [2]                     # the colder [9,9] leaf goes first


# ---------------------------------------------------------------------------
# (b) pool primitives: COW copy and scale adoption are bitwise
# ---------------------------------------------------------------------------

def test_fork_page_and_adopt_scales_bitwise():
    _, lm, _ = _setup()
    pcfg = PoolConfig(num_slots=2, page_size=4, pages_per_slot=2,
                      quantized=True)
    pool = KC.init_pool(lm, pcfg)
    k = jax.random.PRNGKey(3)
    fill = {"data": {}, "scale_log2": {}}
    for key in pool["data"]:
        fill["data"][key], fill["scale_log2"][key] = {}, {}
        for name, arr in pool["data"][key].items():
            k, k1, k2 = jax.random.split(k, 3)
            fill["data"][key][name] = jax.random.randint(
                k1, arr.shape, -128, 128, jnp.int32).astype(arr.dtype)
            sarr = pool["scale_log2"][key][name]
            fill["scale_log2"][key][name] = jax.random.randint(
                k2, sarr.shape, -6, 3).astype(sarr.dtype)
    before = jax.tree.map(np.asarray, fill)
    forked = KC.fork_page(fill, jnp.int32(1), jnp.int32(3))
    for key in forked["data"]:
        for name, arr in forked["data"][key].items():
            arr = np.asarray(arr)
            old = before["data"][key][name]
            np.testing.assert_array_equal(arr[:, 3], old[:, 1])   # verbatim
            keep = [p for p in range(arr.shape[1]) if p != 3]
            np.testing.assert_array_equal(arr[:, keep], old[:, keep])
            np.testing.assert_array_equal(       # scales: fork leaves alone
                np.asarray(forked["scale_log2"][key][name]),
                before["scale_log2"][key][name])
    snap = KC.snapshot_scales(forked, 0)
    dev = {key: {n: jnp.asarray(v) for n, v in kinds.items()}
           for key, kinds in snap.items()}
    adopted = KC.adopt_scales(forked, jnp.int32(1), dev)
    for key in adopted["scale_log2"]:
        for name, arr in adopted["scale_log2"][key].items():
            arr = np.asarray(arr)
            np.testing.assert_array_equal(arr[:, 1], arr[:, 0])


# ---------------------------------------------------------------------------
# (c) engine: prefix-on decode == prefix-off decode, token for token
# ---------------------------------------------------------------------------

def _shared_prefix_prompts(cfg, seed=7):
    """Four prompts over one 20-token base: a full-path reuse, a divergence
    at 20 (mid-page COW on page 2 of an 8-token page), and a divergence at
    18 (mid-page COW inside the base itself)."""
    rng = np.random.RandomState(seed)
    v = cfg.vocab_size
    base = rng.randint(0, v, 20).tolist()
    sfx = [rng.randint(0, v, 6).tolist() for _ in range(3)]
    return [base + sfx[0],
            base + sfx[1],
            base[:18] + sfx[2],
            base + sfx[0][:3] + sfx[1][:3]]


@pytest.mark.parametrize("quantized", [False, True])
def test_prefix_on_matches_off(quantized):
    cfg, lm, params = _setup()
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                      quantized=quantized)
    prompts = _shared_prefix_prompts(cfg)
    gens = [6, 6, 6, 6]
    off, s_off = _run(lm, params, prompts, pcfg, gens)
    on, s_on = _run(lm, params, prompts, pcfg, gens, prefix_cache=True)
    assert on == off
    assert s_off["prefix_hit_tokens"] == 0
    assert s_on["prefix_hit_tokens"] > 0
    assert s_on["cow_forks"] > 0            # both mid-page divergences
    assert s_on["pages_saved"] > 0
    assert 0.0 < s_on["prefix_hit_rate"] < 1.0
    # the hit tokens were NOT recomputed
    assert s_on["prefill_tokens"] == (s_on["prompt_tokens"]
                                      - s_on["prefix_hit_tokens"])


def test_prefix_on_matches_off_chunked_prefill():
    """Suffix recompute through the chunked path (prefill_chunk > 0) is
    still token-identical."""
    cfg, lm, params = _setup()
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                      quantized=False)
    prompts = _shared_prefix_prompts(cfg)
    gens = [5, 5, 5, 5]
    off, _ = _run(lm, params, prompts, pcfg, gens, prefill_chunk=8)
    on, s_on = _run(lm, params, prompts, pcfg, gens, prefill_chunk=8,
                    prefix_cache=True)
    assert on == off
    assert s_on["prefix_hit_tokens"] > 0


def test_prefix_eviction_under_pressure_matches_off():
    """A pool too small to cache every base forces LRU leaf eviction; the
    decode stream stays identical to the cache-off engine."""
    cfg, lm, params = _setup()
    rng = np.random.RandomState(11)
    v = cfg.vocab_size
    bases = [rng.randint(0, v, 8).tolist() for _ in range(4)]
    prompts = [bases[i % 4] + rng.randint(0, v, 4).tolist()
               for i in range(10)]
    gens = [4] * 10
    pcfg = PoolConfig(num_slots=2, page_size=4, pages_per_slot=6,
                      quantized=True, num_pages=14)
    off, _ = _run(lm, params, prompts, pcfg, gens)
    on, s_on = _run(lm, params, prompts, pcfg, gens, prefix_cache=True)
    assert on == off
    assert s_on["prefix_hit_tokens"] > 0
    assert s_on["prefix_evictions"] > 0


def test_prefix_preempt_resume_matches_off():
    """Pool exhaustion mid-decode preempts the youngest slot (releasing its
    refs); on re-admission its folded prompt hits the cache again. Tokens
    stay identical to the cache-off engine (which serializes instead)."""
    cfg, lm, params = _setup()
    rng = np.random.RandomState(13)
    v = cfg.vocab_size
    base = rng.randint(0, v, 8).tolist()
    prompts = [base + rng.randint(0, v, 2).tolist() for _ in range(2)]
    gens = [5, 5]
    pcfg = PoolConfig(num_slots=2, page_size=4, pages_per_slot=4,
                      quantized=False, num_pages=5)
    off, _ = _run(lm, params, prompts, pcfg, gens)
    on, s_on = _run(lm, params, prompts, pcfg, gens, prefix_cache=True)
    assert on == off
    assert s_on["prefix_hit_tokens"] > 0
    assert s_on["preemptions"] > 0


# ---------------------------------------------------------------------------
# (d) int8 hit == cache-off run with a chunk boundary at resume (bitwise
#     recompute contract: shared codes verbatim + adopted donor scales)
# ---------------------------------------------------------------------------

def test_quantized_hit_equals_chunk_boundary_recompute():
    cfg, lm, params = _setup()
    rng = np.random.RandomState(17)
    v = cfg.vocab_size
    donor = rng.randint(0, v, 16).tolist()          # exactly 2 full pages
    follower = donor + rng.randint(0, v, 7).tolist()
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                      quantized=True)
    # cache-off reference: chunked prefill with a boundary at 16, so the
    # follower's first 16 positions quantize on scales chosen from exactly
    # those 16 tokens — the same grid the donor's whole-prompt prefill chose
    eng_off = Engine(lm, params,
                     EngineConfig(pool=pcfg, prefill_chunk=16), PLAN)
    r_off = eng_off.submit(follower, max_new_tokens=5)
    ref = eng_off.run()[r_off].tokens

    eng_on = Engine(lm, params,
                    EngineConfig(pool=pcfg, prefill_chunk=16,
                                 prefix_cache=True), PLAN)
    eng_on.submit(donor, max_new_tokens=1)
    eng_on.run()
    r_on = eng_on.submit(follower, max_new_tokens=5)
    got = eng_on.run()[r_on].tokens
    assert got == ref
    s = eng_on.summary()
    assert s["prefix_hit_tokens"] == 16 and s["cow_forks"] == 0


# ---------------------------------------------------------------------------
# (e) stateful archs bypass: no cache is constructed, requests take the
#     ordinary full-prefill miss path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "jamba-1.5-large"])
def test_stateful_arch_bypasses_prefix_cache(arch):
    cfg, lm, params = _setup(arch)
    rng = np.random.RandomState(19)
    v = cfg.vocab_size
    base = rng.randint(0, v, 12).tolist()
    prompts = [base + rng.randint(0, v, 3).tolist() for _ in range(2)]
    gens = [3, 3]
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                      quantized=False)
    eng = Engine(lm, params,
                 EngineConfig(pool=pcfg, prefix_cache=True), PLAN)
    assert eng._prefix is None              # documented miss path
    rids = [eng.submit(p, max_new_tokens=g) for p, g in zip(prompts, gens)]
    res = eng.run()
    on = [res[r].tokens for r in rids]
    off, s_off = _run(lm, params, prompts, pcfg, gens)
    assert on == off
    s = eng.summary()
    assert s["prefix_hit_tokens"] == 0 and s["cow_forks"] == 0
    assert s["prefill_tokens"] == s["prompt_tokens"]


# ---------------------------------------------------------------------------
# (f) satellites: bounded compile cache; MoE chunked-prefill capacity parity
# ---------------------------------------------------------------------------

def test_bucket_len_and_compile_cache_lru():
    assert bucket_len(7, 0) == 7 and bucket_len(7, 8) == 8
    assert bucket_len(8, 8) == 8 and bucket_len(9, 8) == 16
    calls = []
    cc = CompileCache(lambda k: calls.append(k) or f"fn{k}", max_live=2)
    assert cc.get(1) == "fn1" and cc.get(2) == "fn2" and cc.get(1) == "fn1"
    assert calls == [1, 2] and cc.evictions == 0
    cc.get(3)                               # evicts 2 (1 was touched last)
    assert cc.evictions == 1 and sorted(cc.keys) == [1, 3]
    cc.get(2)                               # rebuild: factory again, evicts 1
    assert calls == [1, 2, 3, 2] and cc.evictions == 2
    unbounded = CompileCache(lambda k: k, max_live=0)
    for i in range(8):
        unbounded.get(i)
    assert unbounded.evictions == 0 and len(unbounded) == 8


def test_compile_cache_eviction_in_engine():
    """max_prefill_shapes=1 with three distinct prompt lengths forces
    evictions; tokens match the unbounded engine."""
    cfg, lm, params = _setup()
    rng = np.random.RandomState(23)
    prompts = [rng.randint(0, cfg.vocab_size, n).tolist()
               for n in (9, 11, 13)]
    gens = [3, 3, 3]
    pcfg = PoolConfig(num_slots=1, page_size=8, pages_per_slot=4,
                      quantized=False)
    free, s_free = _run(lm, params, prompts, pcfg, gens)
    tight, s_tight = _run(lm, params, prompts, pcfg, gens,
                          max_prefill_shapes=1)
    assert tight == free
    assert s_free["compile_evictions"] == 0
    assert s_tight["compile_evictions"] > 0


def test_moe_capacity_parity_unit():
    """Chunked routing == whole-prompt routing iff capacity derives from
    the full token count. Construction: top_k=1 with 5 prototype rows whose
    top-1 experts are distinct, demands sized so the whole-prompt capacity
    (16) covers every expert but the legacy per-chunk capacity (8) does
    not."""
    cfg = ModelConfig(name="m", d_model=32, d_ff=64, dtype="float32",
                      moe=MoEConfig(num_experts=8, top_k=1,
                                    capacity_factor=2.0))
    mdef = M.make_moe(cfg)
    params = M.init_moe(jax.random.PRNGKey(0), mdef, cfg)
    cand = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    top1 = np.asarray(M._route(params, cand, mdef, cfg)[0][:, 0])
    protos, used = [], set()
    for i in range(64):
        if int(top1[i]) not in used:
            used.add(int(top1[i]))
            protos.append(np.asarray(cand[i]))
        if len(protos) == 5:
            break
    assert len(protos) == 5, "need 5 distinct top-1 experts"
    a, b, c, d, e = protos
    # chunk 1 routes 12 tokens to expert(a): > chunk cap 8, <= whole cap 16
    rows = [a] * 12 + [b] * 4 + [c] * 16 + [d] * 16 + [e] * 16
    x = jnp.asarray(np.stack(rows))[None]           # (1, 64, D)
    whole, _ = M.moe_forward(params, x, mdef, cfg)
    pieces = [x[:, i:i + 16] for i in range(0, 64, 16)]
    legacy = jnp.concatenate(
        [M.moe_forward(params, p, mdef, cfg)[0] for p in pieces], axis=1)
    parity = jnp.concatenate(
        [M.moe_forward(params, p, mdef, cfg, capacity_tokens=64)[0]
         for p in pieces], axis=1)
    np.testing.assert_allclose(np.asarray(parity), np.asarray(whole),
                               rtol=2e-5, atol=2e-5)
    assert np.abs(np.asarray(legacy) - np.asarray(whole)).max() > 1e-3
    # the legacy chunk dropped exactly the capacity-overflow rows (ties
    # break by token order: tokens 8..11 of the 12-token run lose)
    dropped = np.linalg.norm(np.asarray(legacy)[0, 8:12], axis=-1)
    kept = np.linalg.norm(np.asarray(whole)[0, 8:12], axis=-1)
    assert (dropped < 1e-6).all() and (kept > 1e-6).all()


def test_moe_engine_chunked_parity_flag():
    """Engine-level: with moe_capacity_by_prompt on, chunked prefill and
    whole-prompt prefill produce identical tokens on an MoE arch (the
    static capacity key threads through both compiled paths)."""
    cfg, lm, params = _setup("moonshot-v1-16b")
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                      quantized=False)
    rng = np.random.RandomState(29)
    prompt = rng.randint(0, cfg.vocab_size, 24).tolist()
    outs = []
    for chunk in (0, 8):
        eng = Engine(lm, params,
                     EngineConfig(pool=pcfg, prefill_chunk=chunk,
                                  moe_capacity_by_prompt=True), PLAN)
        rid = eng.submit(prompt, max_new_tokens=6)
        outs.append(eng.run()[rid].tokens)
    assert outs[0] == outs[1]

"""repro.numerics acceptance tests:

(a) reference and Pallas codec backends are BIT-IDENTICAL (codes, decode,
    fake-quant) on pow2 and blockwise specs, with no caller-side padding,
(b) NumericsPolicy round-trips through JSON (incl. the QuantConfig
    back-compat constructor),
(c) grad-accum with grad_compress=True has the same residual semantics as
    the non-accum step (the bug this PR fixed),
(d) MoE router masking: masked (inactive-slot) tokens cannot consume
    expert capacity.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import numerics as N
from repro.configs.base import MoEConfig, ModelConfig, QuantConfig, TrainConfig

# ---------------------------------------------------------------------------
# (a) cross-backend bit-identity
# ---------------------------------------------------------------------------

POW2_SHAPES = [(7,), (37, 130), (3, 5, 33)]


@pytest.mark.parametrize("shape", POW2_SHAPES)
@pytest.mark.parametrize("bits", [4, 8, 16])
def test_pow2_backends_bit_identical(shape, bits):
    spec = N.QuantSpec("pow2", bits, 0, "int8" if bits <= 8 else "int16",
                       "fixed")
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 5
    step = jnp.asarray(-3.0)
    qr = N.encode(x, spec, step, backend="reference")
    qp = N.encode(x, spec, step, backend="pallas")
    np.testing.assert_array_equal(np.asarray(qr.codes), np.asarray(qp.codes))
    np.testing.assert_array_equal(np.asarray(N.decode(qr)),
                                  np.asarray(N.decode(qp, backend="pallas")))
    fr = N.fake_quant(x, spec, step, backend="reference")
    fp = N.fake_quant(x, spec, step, backend="pallas")
    np.testing.assert_array_equal(np.asarray(fr), np.asarray(fp))


@pytest.mark.parametrize("shape,block", [((1000,), 256), ((5, 777), 256),
                                         ((2, 3, 50), 16), ((4096,), 1024)])
def test_blockwise_backends_bit_identical(shape, block):
    spec = N.QuantSpec("blockwise", 8, block, "int8", "per_tensor_max")
    x = jax.random.normal(jax.random.PRNGKey(1), shape) * 9
    qr = N.encode(x, spec, backend="reference")
    qp = N.encode(x, spec, backend="pallas")
    np.testing.assert_array_equal(np.asarray(qr.codes), np.asarray(qp.codes))
    np.testing.assert_array_equal(np.asarray(qr.scale), np.asarray(qp.scale))
    np.testing.assert_array_equal(np.asarray(N.decode(qr)),
                                  np.asarray(N.decode(qp, backend="pallas")))


# Every (data, scale) layout the KV pool feeds the pow2 codec (see
# serve/kv_cache.py): write_prefill (L, S, *feat) w/ per-layer (L, 1)
# scales, append_token (B, *feat) w/ (B, 1...) scales, gather_slots
# (B, max_len, *feat) w/ (B, 1...) scales; feat is (Hkv, Dh) for GQA and
# (rank,) / (rope,) for MLA. Plus a (L, S) per-(layer, slot) grid.
KV_POOL_SCALE_SHAPES = [
    ((3, 24, 2, 8), (3, 1)),            # write_prefill, GQA feat
    ((3, 24, 16), (3, 1)),              # write_prefill, MLA c_kv feat
    ((4, 2, 8), (4, 1, 1)),             # append_token, GQA feat
    ((4, 16), (4, 1)),                  # append_token, MLA feat
    ((4, 32, 2, 8), (4, 1, 1, 1)),      # gather/decode, GQA feat
    ((4, 32, 16), (4, 1, 1)),           # gather/decode, MLA feat
    ((3, 5, 2, 8, 4), (3, 5)),          # per-(layer, slot) scale grid
]


@pytest.mark.parametrize("xshape,sshape", KV_POOL_SCALE_SHAPES)
def test_pow2_multiscale_bit_identity_no_fallback(xshape, sshape):
    """The vectorized multi-scale Pallas pow2 kernels are BIT-identical to
    the reference for every KV-pool scale layout — and none of these calls
    may take the reference fallback (the gap this closes: non-scalar scales
    used to silently drop to the reference codec)."""
    from repro.numerics import pallas_backend as PB
    spec = N.QuantSpec("pow2", 8, 0, "int8", "per_tensor_max")
    x = jax.random.normal(jax.random.PRNGKey(5), xshape) * 4
    sc = jnp.asarray(np.random.RandomState(6).randint(-6, 2, sshape),
                     jnp.float32)
    PB.reset_fallback_count()
    qr = N.encode(x, spec, sc, backend="reference")
    qp = N.encode(x, spec, sc, backend="pallas")
    np.testing.assert_array_equal(np.asarray(qr.codes), np.asarray(qp.codes))
    np.testing.assert_array_equal(np.asarray(N.decode(qr)),
                                  np.asarray(N.decode(qp, backend="pallas")))
    assert PB.fallback_count() == 0, \
        "KV-pool-shaped scales must run the vectorized kernel natively"


# int4x2 packed storage: two codes per byte along the trailing dim (the
# tt_factor deploy format). Odd trailing dims carry one zero pad nibble.
INT4X2_CASES = [
    ((7,), None),                        # 1-D, odd
    ((6,), None),                        # 1-D, even
    ((5, 9), None),                      # odd trailing
    ((4, 130), (4, 1)),                  # per-row scales
    ((3, 4, 11), (3, 1)),                # per-layer scales, odd trailing
    ((2, 3, 6), (2, 3)),                 # per-(layer, slot) grid
]


@pytest.mark.parametrize("shape,sshape", INT4X2_CASES)
def test_int4x2_roundtrip_bit_identity_no_fallback(shape, sshape):
    """Packed int4 pack/unpack round-trip: reference and Pallas backends
    bit-identical (codes AND decode), packed codes are exactly
    ceil(last/2) bytes per row, values identical to the unpacked int8
    4-bit spec, and no call drops to the reference fallback."""
    from repro.numerics import pallas_backend as PB
    spec = N.QuantSpec("pow2", 4, 0, "int4x2", "fixed")
    x = jax.random.normal(jax.random.PRNGKey(11), shape) * 0.5
    sc = jnp.asarray(-3.0) if sshape is None else jnp.asarray(
        np.random.RandomState(3).randint(-5, 0, sshape), jnp.float32)
    PB.reset_fallback_count()
    qr = N.encode(x, spec, sc, backend="reference")
    qp = N.encode(x, spec, sc, backend="pallas")
    assert qr.codes.dtype == jnp.int8
    assert qr.codes.shape == shape[:-1] + (-(-shape[-1] // 2),)
    np.testing.assert_array_equal(np.asarray(qr.codes), np.asarray(qp.codes))
    dr = N.decode(qr)
    np.testing.assert_array_equal(np.asarray(dr),
                                  np.asarray(N.decode(qp, backend="pallas")))
    assert PB.fallback_count() == 0, \
        "packed codec must run the Pallas kernels natively"
    # cross-spec: same VALUES as the unpacked int8-stored 4-bit spec
    unpacked = N.QuantSpec("pow2", 4, 0, "int8", "fixed")
    np.testing.assert_array_equal(
        np.asarray(dr), np.asarray(N.decode(N.encode(x, unpacked, sc))))
    # nbytes halves (modulo the scale metadata)
    assert qr.nbytes() <= N.encode(x, unpacked, sc).nbytes() // 2 + 4 + \
        np.asarray(sc).nbytes


def test_int4x2_pack_unpack_exact():
    """pack/unpack primitives: exact inverse over the full nibble range,
    pad nibble lands in the high half of the last byte."""
    from repro.numerics.codecs import pack_int4, unpack_int4
    q = jnp.asarray([[-8, -1, 0, 7, 3], [1, 2, -3, 4, -5]], jnp.int32)
    p = pack_int4(q)
    assert p.shape == (2, 3) and p.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(unpack_int4(p, 5)),
                                  np.asarray(q))
    # odd trailing dim: high nibble of the last byte is the zero pad
    assert (np.asarray(p)[:, -1].astype(np.int32) & 0xF0 == 0).all()


def test_int4x2_spec_validation():
    with pytest.raises(ValueError):
        N.QuantSpec("pow2", 8, 0, "int4x2")          # nibble can't hold 8 bits
    with pytest.raises(ValueError):
        N.QuantSpec("blockwise", 4, 64, "int4x2")    # pow2 only
    spec = N.QuantSpec("pow2", 4, 0, "int4x2")
    assert spec.packed and spec.jnp_storage == jnp.dtype(jnp.int8)
    assert N.QuantSpec.from_json_dict(spec.to_json_dict()) == spec
    # analytic accounting counts two codes per byte
    assert N.spec_nbytes(spec, (4, 9)) == 4 * 5 + 4
    # 0-d tensors pack as one nibble + one pad nibble on both backends
    for backend in N.BACKENDS:
        qt = N.encode(jnp.asarray(0.5), spec, jnp.asarray(-3.0),
                      backend=backend)
        assert qt.codes.shape == (1,) and qt.shape == ()
        assert float(N.decode(qt)) == 0.5      # 4 * 2^-3: exact on the grid


def test_int4x2_hypothesis_roundtrip():
    """Property form of the round-trip over random shapes (odd/even
    trailing dims) and scale layouts."""
    hyp = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st
    from repro.numerics import pallas_backend as PB
    spec = N.QuantSpec("pow2", 4, 0, "int4x2", "fixed")

    @settings(max_examples=25, deadline=None)
    @given(lead=st.integers(1, 5), last=st.integers(1, 17),
           per_row=st.booleans(), seed=st.integers(0, 2 ** 16))
    def check(lead, last, per_row, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (lead, last)) * 0.5
        sc = jnp.asarray(
            np.random.RandomState(seed).randint(-5, 0, (lead, 1)),
            jnp.float32) if per_row else jnp.asarray(-3.0)
        PB.reset_fallback_count()
        qr = N.encode(x, spec, sc, backend="reference")
        qp = N.encode(x, spec, sc, backend="pallas")
        np.testing.assert_array_equal(np.asarray(qr.codes),
                                      np.asarray(qp.codes))
        np.testing.assert_array_equal(
            np.asarray(N.decode(qr)),
            np.asarray(N.decode(qp, backend="pallas")))
        assert qr.codes.shape == (lead, -(-last // 2))
        assert PB.fallback_count() == 0

    check()


def test_pow2_fake_quant_shares_leading_dim_convention():
    """One scale convention across all three codec ops: a per-layer (L, 1)
    scale means the same thing to fake_quant as to encode/decode (leading-
    dim broadcast), on both backends. Before the fix fake_quant applied
    numpy trailing-dim alignment and raised (or silently mis-scaled) on
    exactly the shapes encode accepts."""
    spec = N.QuantSpec("pow2", 8)
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 6, 4)) * 2
    sc = jnp.asarray([[-3.0], [-2.0], [0.0]])               # (L, 1)
    fq = N.fake_quant(x, spec, sc)
    rt = N.decode(N.encode(x, spec, sc), jnp.float32)
    np.testing.assert_array_equal(np.asarray(fq), np.asarray(rt))
    np.testing.assert_array_equal(
        np.asarray(fq), np.asarray(N.fake_quant(x, spec, sc,
                                                backend="pallas")))


def test_pow2_nonconforming_scale_still_falls_back():
    """A scale that does not follow the leading-dim broadcast convention is
    routed to the reference codec and the fallback counter records it (the
    differential harness relies on the counter to prove native coverage)."""
    from repro.numerics import pallas_backend as PB
    spec = N.QuantSpec("pow2", 8)
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 6))
    PB.reset_fallback_count()
    with pytest.raises(Exception):
        # (3,) matches no leading dim of (4, 6): the reference cannot
        # broadcast it either — but the fallback must be taken (counted)
        # before the reference raises
        N.encode(x, spec, jnp.zeros((3,)), backend="pallas")
    assert PB.fallback_count() == 1


def test_kv_cache_pool_quant_no_fallback(monkeypatch):
    """serve/kv_cache quantize/dequantize with pool-shaped per-slot scales
    route through the native multi-scale kernels when the pallas backend is
    selected, bit-identical to the default reference path."""
    from repro.numerics import pallas_backend as PB
    from repro.serve import kv_cache as KC
    x = jax.random.normal(jax.random.PRNGKey(8), (3, 8, 2, 4)) * 2
    sc = KC.choose_scale_log2(x, jnp.ones((8,), bool), 8)       # (3,)
    # reference side must really be the reference backend, even when the
    # whole process runs under the CI kernel-validation env
    monkeypatch.delenv("JAX_PALLAS_INTERPRET", raising=False)
    assert KC.codec_backend() == "reference" or \
        jax.default_backend() == "tpu"
    ref_codes = KC.quantize(x, sc[:, None], 8)
    ref_deq = KC.dequantize(ref_codes, sc[:, None], jnp.float32)
    monkeypatch.setenv("JAX_PALLAS_INTERPRET", "1")
    assert KC.codec_backend() == "pallas"
    PB.reset_fallback_count()
    codes = KC.quantize(x, sc[:, None], 8)
    deq = KC.dequantize(codes, sc[:, None], jnp.float32)
    assert PB.fallback_count() == 0
    np.testing.assert_array_equal(np.asarray(codes), np.asarray(ref_codes))
    np.testing.assert_array_equal(np.asarray(deq), np.asarray(ref_deq))


def test_pallas_fake_quant_has_clipped_ste():
    spec = N.QuantSpec("pow2", 4)
    x = jnp.asarray([-0.3, 0.0, 0.4, 50.0, -50.0])
    g = jax.grad(lambda v: jnp.sum(
        N.fake_quant(v, spec, jnp.asarray(-4.0), backend="pallas")))(x)
    # scale 2^-4: representable |x| <= 8*2^-4 = 0.5
    assert float(g[0]) == 1.0 and float(g[2]) == 1.0
    assert float(g[3]) == 0.0 and float(g[4]) == 0.0


def test_pallas_fake_quant_multiscale_no_fallback():
    """Non-scalar (rowwise-conforming) scales route fake_quant through the
    fused Pallas kernel — bit-identical values AND gradients (clipped STE)
    vs the reference, with zero reference fallbacks. Before the fix every
    non-scalar scale silently dropped to the reference codec."""
    from repro.numerics import pallas_backend as PB
    spec = N.QuantSpec("pow2", 8)
    x = jax.random.normal(jax.random.PRNGKey(12), (4, 6, 8)) * 6
    sc = jnp.asarray([[-3.0], [-1.0], [0.0], [2.0]])            # (L, 1)
    PB.reset_fallback_count()
    fp = N.fake_quant(x, spec, sc, backend="pallas")
    assert PB.fallback_count() == 0, \
        "leading-dim scales must run the fused rowwise kernel natively"
    fr = N.fake_quant(x, spec, sc, backend="reference")
    np.testing.assert_array_equal(np.asarray(fp), np.asarray(fr))
    # gradients: clipped straight-through mask, identical across backends
    gp = jax.grad(lambda v: jnp.sum(
        N.fake_quant(v, spec, sc, backend="pallas")))(x)
    gr = jax.grad(lambda v: jnp.sum(
        N.fake_quant(v, spec, sc, backend="reference")))(x)
    np.testing.assert_array_equal(np.asarray(gp), np.asarray(gr))
    assert set(np.unique(np.asarray(gp))) <= {0.0, 1.0}
    assert 0.0 in np.asarray(gp) and 1.0 in np.asarray(gp)


def test_pallas_kernel_pads_internally():
    """The old kernel asserted exact (bm, bn) multiples; any shape works now."""
    from repro.kernels.quantize import quantize
    x = jax.random.normal(jax.random.PRNGKey(2), (37, 130))
    out = quantize(x, jnp.asarray(-3.0), 8)
    assert out.shape == x.shape
    ref = N.fake_quant(x, N.QuantSpec("pow2", 8), jnp.asarray(-3.0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_qtensor_nbytes_and_pytree():
    spec = N.QuantSpec("blockwise", 8, 256)
    x = jax.random.normal(jax.random.PRNGKey(3), (16, 512))
    qt = N.encode(x, spec)
    assert qt.nbytes() == 16 * 512 + 16 * 2 * 4          # codes + scales
    assert qt.nbytes() < x.nbytes / 3.5
    # pytree: map/flatten preserve the container and its static aux
    qt2 = jax.tree.map(lambda a: a, qt)
    assert isinstance(qt2, N.QTensor) and qt2.spec == spec
    np.testing.assert_allclose(np.asarray(qt.dequantize()), np.asarray(x),
                               atol=float(qt.scale.max()) + 1e-6)


# ---------------------------------------------------------------------------
# (b) policy JSON round-trip
# ---------------------------------------------------------------------------

def test_policy_json_roundtrip():
    pol = N.NumericsPolicy(enable=True)
    assert N.NumericsPolicy.from_json(pol.to_json()) == pol
    # plain-dict path (what a config file would store)
    d = json.loads(json.dumps(pol.to_json_dict()))
    assert N.NumericsPolicy.from_json_dict(d) == pol


def test_quant_config_is_policy_constructor():
    qc = QuantConfig(enable=True, weight_bits=4, act_bits=8, grad_bits=16)
    pol = qc.policy()
    assert pol.enable
    assert pol.spec_for("tt_factor").bits == 4
    assert pol.spec_for("activation").bits == 8
    assert pol.spec_for("grad_edge").bits == 16
    assert pol.spec_for("optimizer_moment").kind == "blockwise"
    assert pol.spec_for("dp_wire").block == 1024
    assert pol.spec_for("kv_cache").scale_policy == "per_tensor_max"
    assert set(pol.managed_sites()) == {"activation", "grad_edge"}
    assert N.NumericsPolicy.from_json(pol.to_json()) == pol


def test_policy_sites_cover_all_known_sites():
    pol = N.NumericsPolicy()
    for site in N.SITES:
        assert pol.spec_for(site) is not None
    with pytest.raises(KeyError):
        pol.spec_for("nonexistent")


def test_all_sites_share_one_codec_registry():
    """The acceptance claim: every site's spec resolves to a registered
    codec on both backends."""
    pol = N.NumericsPolicy(enable=True)
    for site in N.SITES:
        for backend in N.BACKENDS:
            assert N.get_codec(pol.spec_for(site), backend) is not None


# ---------------------------------------------------------------------------
# (c) grad-accum residual semantics == non-accum step
# ---------------------------------------------------------------------------

def _tiny_lm():
    from repro.models import build_lm, init_lm
    cfg = ModelConfig(name="t", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64,
                      remat="none", dtype="float32")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    return cfg, lm, params


def test_grad_accum_matches_non_accum_with_compression():
    """n_micro=1 grad-accum must be the SAME update as the plain step:
    compression applied, residual carried (the fixed bug: it silently
    dropped both)."""
    from repro.launch.steps import (init_train_state,
                                    make_grad_accum_train_step,
                                    make_train_step)
    from repro.sharding import ShardPlan
    cfg, lm, params = _tiny_lm()
    tcfg = TrainConfig(total_steps=5, warmup_steps=1, grad_compress=True)
    plan = ShardPlan(mesh=None)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 64)}
    step = jax.jit(make_train_step(lm, plan, tcfg))
    astep = jax.jit(make_grad_accum_train_step(lm, plan, tcfg, 1))
    s0 = init_train_state(params, tcfg)
    s1, m1 = step(s0, batch)
    s2, m2 = astep(s0, jax.tree.map(lambda a: a[None], batch))
    assert s2.residual is not None
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    for a, b in zip(s1.residual, s2.residual):
        if a is None:
            assert b is None
            continue
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)


def test_grad_accum_error_feedback_accumulates():
    """Residual must change step over step (error feedback is live) and
    feed back into the next update."""
    from repro.launch.steps import init_train_state, make_grad_accum_train_step
    from repro.sharding import ShardPlan
    cfg, lm, params = _tiny_lm()
    tcfg = TrainConfig(total_steps=5, warmup_steps=1, grad_compress=True)
    astep = jax.jit(make_grad_accum_train_step(lm, ShardPlan(mesh=None),
                                               tcfg, 2))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (2, 2, 16), 0, 64),
             "labels": jax.random.randint(jax.random.PRNGKey(4), (2, 2, 16), 0, 64)}
    state = init_train_state(params, tcfg)
    state, _ = astep(state, batch)
    r1 = [np.asarray(r) for r in state.residual if r is not None]
    state, _ = astep(state, batch)
    r2 = [np.asarray(r) for r in state.residual if r is not None]
    assert any(np.abs(a - b).max() > 0 for a, b in zip(r1, r2))
    assert any(np.abs(r).max() > 0 for r in r2)


# ---------------------------------------------------------------------------
# (d) MoE router masking
# ---------------------------------------------------------------------------

def test_moe_mask_prevents_capacity_theft():
    """Junk (masked) tokens must not displace real tokens from expert
    capacity: with the mask on, the real tokens' outputs are independent
    of the junk tokens' content."""
    from repro.models.moe import make_moe, init_moe, moe_forward
    cfg = ModelConfig(name="m", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
                      # tight capacity (8 slots/expert, 16 tokens wanting
                      # k=2 experts each) so junk with extreme router
                      # weights CAN displace real tokens when unmasked
                      moe=MoEConfig(num_experts=2, top_k=2,
                                    capacity_factor=0.5))
    d = make_moe(cfg)
    p = init_moe(jax.random.PRNGKey(0), d, cfg)
    b, s = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, 32))
    # half the tokens are "inactive slots" carrying junk
    mask = jnp.asarray([True] * 8 + [False] * 8)[None]
    junk_a = x.at[:, 8:].set(100.0 * jax.random.normal(
        jax.random.PRNGKey(2), (b, 8, 32)))
    junk_b = x.at[:, 8:].set(50.0 * jax.random.normal(
        jax.random.PRNGKey(3), (b, 8, 32)))

    out_a, _ = moe_forward(p, junk_a, d, cfg, token_mask=mask)
    out_b, _ = moe_forward(p, junk_b, d, cfg, token_mask=mask)
    # real tokens: identical regardless of junk content
    np.testing.assert_allclose(np.asarray(out_a[:, :8]),
                               np.asarray(out_b[:, :8]),
                               rtol=1e-5, atol=1e-5)
    # masked tokens contribute nothing (zero combine weight)
    np.testing.assert_allclose(np.asarray(out_a[:, 8:]), 0.0, atol=1e-6)

    # sanity: WITHOUT the mask the big junk steals capacity -> real-token
    # outputs change with junk content (the pre-fix behavior)
    noma, _ = moe_forward(p, junk_a, d, cfg)
    nomb, _ = moe_forward(p, junk_b, d, cfg)
    assert np.abs(np.asarray(noma[:, :8]) - np.asarray(nomb[:, :8])).max() \
        > 1e-4


def test_moe_all_active_mask_is_identity():
    """An all-true mask must not change routing (serve fp32 parity)."""
    from repro.models.moe import make_moe, init_moe, moe_forward
    cfg = ModelConfig(name="m", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64, dtype="float32",
                      moe=MoEConfig(num_experts=4, top_k=2))
    d = make_moe(cfg)
    p = init_moe(jax.random.PRNGKey(0), d, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out0, aux0 = moe_forward(p, x, d, cfg)
    out1, aux1 = moe_forward(p, x, d, cfg,
                             token_mask=jnp.ones((2, 8), bool))
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(out1))
    np.testing.assert_allclose(float(aux0), float(aux1), rtol=1e-6)


# ---------------------------------------------------------------------------
# unified-site regression: the five migrated call sites hit the codecs
# ---------------------------------------------------------------------------

def test_adam_int8_state_is_qtensor():
    from repro.optim import adam as A
    p = {"w": jax.random.normal(jax.random.PRNGKey(1), (4, 300))}
    st = A.init_adam(p, TrainConfig(opt_state_dtype="int8"))
    (m,) = [m for m in st.m if m is not None]
    assert isinstance(m, N.QTensor)
    assert m.spec.kind == "blockwise" and m.spec.block == A.BLOCK
    # shape-preserving: leading dims match the param's
    assert m.codes.shape[:-1] == (4,)


def test_engine_pool_numerics_follow_policy():
    """EngineConfig.policy: the kv_cache site owns the pool's numerics."""
    import repro.configs as C
    from repro.models import build_lm, init_lm
    from repro.serve import Engine, EngineConfig, PoolConfig
    from repro.sharding import ShardPlan
    cfg = C.get_reduced("internlm2-1.8b").replace(dtype="float32",
                                                  remat="none")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    pol = N.NumericsPolicy(enable=True)
    eng = Engine(lm, params,
                 EngineConfig(pool=PoolConfig(num_slots=2, quantized=False),
                              policy=pol), ShardPlan(mesh=None))
    assert eng.pcfg.quantized and eng.pcfg.bits == \
        pol.spec_for("kv_cache").bits
    assert eng.pcfg.spec == pol.spec_for("kv_cache")
    leaf = next(iter(next(iter(eng.pool["data"].values())).values()))
    assert leaf.dtype == jnp.int8


def test_kv_cache_quant_routes_through_codec():
    from repro.serve import kv_cache as KC
    pcfg = KC.PoolConfig(num_slots=2, quantized=True)
    assert pcfg.spec == N.QuantSpec("pow2", 8, 0, "int8", "per_tensor_max")
    x = jax.random.normal(jax.random.PRNGKey(0), (3, 8, 4)) * 2
    valid = jnp.ones((8,), bool)
    sc = KC.choose_scale_log2(x, valid, 8)
    codes = KC.quantize(x, sc[:, None], 8)
    deq = KC.dequantize(codes, sc[:, None], jnp.float32)
    step = np.exp2(np.asarray(sc)).reshape(3, 1, 1)
    assert codes.dtype == jnp.int8
    assert (np.abs(np.asarray(deq) - np.asarray(x)) <= step / 2 + 1e-6).all()

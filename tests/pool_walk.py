"""Randomized scheduler/pool walker asserting the paged pool's isolation
invariants (shared by the hypothesis property test in test_property.py and
the deterministic CI sweep in test_serve.py).

The walker replays a random submit / admit / decode-append / retire /
preempt sequence against a real ``Scheduler`` plus a one-layer,
one-feature device pool, writing a unique per-request sentinel value at
every cache position a request owns.  After every op it checks:

- **page accounting**: slots' page lists are pairwise disjoint and disjoint
  from the free list; each page-table row maps only the slot's own pages or
  the trash page;
- **read isolation**: gathering a slot's view returns exactly its own
  sentinel at every written position — a slot can never read another slot's
  pages (sentinels are unique per request);
- **write isolation**: appends for inactive/retired slots land on the trash
  page only (no other physical page changes);
- **ledger conservation**: free + slot-private + tree-owned page bytes
  partition the pool exactly (a real ``repro.obs.MemoryLedger`` instance),
  and the logical/physical mapped-page stats reproduce the saved-bytes
  truth recomputed from the slot lists.
"""
import jax.numpy as jnp
import numpy as np

from repro.serve import kv_cache as KC
from repro.serve.kv_cache import PoolConfig
from repro.serve.prefix import RadixPrefixCache
from repro.serve.scheduler import Request, Scheduler


def _sentinel(rid: int) -> float:
    return float(rid % 10_000 + 1)


def _check_ledger(sched: Scheduler, pcfg: PoolConfig, data,
                  owned=frozenset()) -> None:
    """Ledger conservation (repro.obs.MemoryLedger): after every op the
    pool's bytes must partition exactly into free + slot-private +
    tree-owned pages — no leaks, no double counting — and the scheduler's
    ``mapped_page_stats`` must reproduce the saved-bytes truth recomputed
    directly from the slot lists (the ``prefix_bytes_saved`` verified
    figure the engine reports)."""
    from repro.obs import MemoryLedger

    pb = int(data.nbytes) // (pcfg.total_pages + 1)   # bytes per page
    free = len(sched.alloc._free)
    priv = sum(len(p) for p in sched.slot_pages)
    led = MemoryLedger()
    led.set("free_pages", free * pb)
    led.set("private_pages", priv * pb)
    led.set("tree_pages", len(owned) * pb)
    assert led.total() == pcfg.total_pages * pb, \
        (free, priv, len(owned), pcfg.total_pages)
    logical, physical = sched.mapped_page_stats()
    rows = [sched.slot_shared[s] + sched.slot_pages[s]
            for s in range(pcfg.num_slots) if sched.slots[s] is not None]
    assert logical == sum(len(r) for r in rows), (logical, rows)
    union = set().union(*map(set, rows)) if rows else set()
    assert physical == len(union), (physical, rows)
    counts: dict[int, int] = {}
    for r in rows:
        for p in r:
            counts[p] = counts.get(p, 0) + 1
    saved_pages = sum(c - 1 for c in counts.values())
    assert logical - physical == saved_pages, (logical, physical, counts)
    # the verified bytes figure: overlay sites never enter the total
    led.set("prefix_bytes_saved", saved_pages * pb, counted=False)
    assert led.total() == pcfg.total_pages * pb


def _check_accounting(sched: Scheduler, pcfg: PoolConfig) -> None:
    owned = [set(p) for p in sched.slot_pages]
    for i in range(len(owned)):
        for j in range(i + 1, len(owned)):
            assert not (owned[i] & owned[j]), (i, j, owned)
    free = set(sched.alloc._free)
    all_owned = set().union(*owned) if owned else set()
    assert not (free & all_owned), (free, all_owned)
    for s in range(pcfg.num_slots):
        row = set(int(p) for p in sched.page_table[s])
        assert row <= owned[s] | {pcfg.trash_page}, (s, row, owned[s])


def _check_read_isolation(sched, pcfg, data, scale, extent) -> None:
    view = np.asarray(KC.gather_slots(
        data, scale, jnp.asarray(sched.page_table), pcfg, jnp.float32))
    for s, st in enumerate(sched.slots):
        if st is None:
            continue
        e = extent[s]
        want = _sentinel(st.req.rid)
        got = view[s, :e, 0]
        assert (got == want).all(), (s, st.req.rid, got, want)


def _check_write_isolation(sched, pcfg, data, scale) -> None:
    """A write batch with every slot inactive must only touch the trash
    page (retired rows map to trash; the active mask redirects the rest)."""
    before = np.asarray(data)
    after = np.asarray(KC.append_token(
        data, scale, jnp.full((pcfg.num_slots, 1, 1), 999.0),
        jnp.asarray(sched.page_table),
        jnp.zeros((pcfg.num_slots,), jnp.int32),
        jnp.zeros((pcfg.num_slots,), bool), pcfg))
    assert (after[:pcfg.trash_page] == before[:pcfg.trash_page]).all()


def run_pool_walk(seed: int, steps: int = 40) -> None:
    rng = np.random.RandomState(seed)
    pcfg = PoolConfig(num_slots=3, page_size=4, pages_per_slot=4,
                      num_pages=int(rng.choice([8, 10, 12])),
                      quantized=False)
    sched = Scheduler(pcfg)
    data = jnp.zeros((pcfg.total_pages + 1, pcfg.page_size, 1), jnp.float32)
    scale = jnp.zeros((pcfg.num_slots,), jnp.float32)
    extent = [0] * pcfg.num_slots       # written positions per slot

    def retire_done(slot):
        if sched.slots[slot] is not None and sched.slots[slot].done():
            sched.retire(slot)
            extent[slot] = 0

    for _ in range(steps):
        op = rng.choice(["submit", "admit", "decode", "retire", "preempt"])
        if op == "submit" and len(sched.queue) < 4:
            sched.submit(Request(prompt=[1] * int(rng.randint(1, 9)),
                                 max_new_tokens=int(rng.randint(1, 6))))
        elif op == "admit":
            adm = sched.try_admit()
            if adm is not None:
                slot, st = adm
                # prefill: write the whole prompt, then sample one token
                # (mirrors the engine: the sampled token is not yet cached)
                vals = jnp.full((st.prompt_len, 1),
                                _sentinel(st.req.rid), jnp.float32)
                data, scale = KC.write_chunk(
                    data, scale, vals,
                    jnp.asarray(sched.page_table[slot]), jnp.int32(0),
                    jnp.int32(st.prompt_len), jnp.int32(slot), pcfg)
                extent[slot] = st.prompt_len
                st.generated.append(7)
                st.last_token = 7
                retire_done(slot)
        elif op == "decode":
            for slot in range(pcfg.num_slots):
                if sched.slots[slot] is None:
                    continue
                while not sched.ensure_page(slot):
                    evicted = sched.preempt_youngest()
                    assert evicted is not None, "pool exhausted"
                    extent[evicted] = 0
                    if evicted == slot:
                        break
            active = sched.active_mask()
            if not active.any():
                continue
            new = jnp.asarray([[[_sentinel(s.req.rid) if s else 0.0]]
                               for s in sched.slots], jnp.float32)
            data = KC.append_token(
                data, scale, new, jnp.asarray(sched.page_table),
                jnp.asarray(sched.lens_vector()), jnp.asarray(active), pcfg)
            for slot, st in enumerate(sched.slots):
                if st is None:
                    continue
                extent[slot] = st.next_pos + 1
                st.generated.append(7)
                st.last_token = 7
                retire_done(slot)
        elif op == "retire":
            live = [i for i, s in enumerate(sched.slots) if s is not None]
            if live:
                slot = int(rng.choice(live))
                sched.retire(slot)      # early EOS
                extent[slot] = 0
        elif op == "preempt":
            evicted = sched.preempt_youngest()
            if evicted is not None:
                extent[evicted] = 0

        _check_accounting(sched, pcfg)
        _check_ledger(sched, pcfg, data)
        _check_read_isolation(sched, pcfg, data, scale, extent)
    _check_write_isolation(sched, pcfg, data, scale)


# ---------------------------------------------------------------------------
# Prefix-sharing walker (serve/prefix.py): refcount / COW invariants
# ---------------------------------------------------------------------------
#
# With sharing, ``run_pool_walk``'s invariants change shape: slot page sets
# are no longer pairwise disjoint (that's the point), and per-request
# sentinels no longer work (a shared page holds the DONOR's writes).  The
# prefix walker instead writes token-derived values — value(position) is a
# pure function of the token at that position — so a cache hit must read
# exactly what a recompute would have written, and asserts:
#
# - **refcount truth**: every page's refcount equals the number of live
#   slots holding it acquired (shared span + COW-fork source + donated);
# - **ownership partition**: free list, tree-owned pages, and slots'
#   private pages are pairwise disjoint; each slot's shared list is
#   tree-owned; page-table rows map only the slot's own shared/private
#   pages or trash;
# - **shared pages never written through**: every tree-owned page's bytes
#   equal its snapshot taken at insertion, after every op;
# - **fork bit-exactness**: a COW copy equals the source page's snapshot
#   verbatim before the divergent suffix overwrites it;
# - **read correctness**: a slot's gathered view equals the token-derived
#   expectation over every written position (hit or miss path alike).


def _tok_val(tok: int) -> float:
    return float(tok + 1)


def _check_prefix_invariants(sched, prefix, pcfg, data, tree_content,
                             expected) -> None:
    owned = prefix.owned_pages
    # snapshots track ownership exactly
    assert set(tree_content) == owned, (set(tree_content), owned)
    # refcount truth
    held = []
    for refs in sched.slot_refs:
        held.extend(refs)
    for p in range(pcfg.total_pages):
        assert prefix.refs.count(p) == held.count(p), (
            p, prefix.refs.count(p), held.count(p))
    # ownership partition
    free = set(sched.alloc._free)
    priv = [set(p) for p in sched.slot_pages]
    for i in range(len(priv)):
        for j in range(i + 1, len(priv)):
            assert not (priv[i] & priv[j]), (i, j, priv)
    all_priv = set().union(*priv) if priv else set()
    assert not (free & all_priv), (free, all_priv)
    assert not (free & owned), (free, owned)
    assert not (all_priv & owned), (all_priv, owned)
    arr = np.asarray(data)
    for s in range(pcfg.num_slots):
        shared = set(sched.slot_shared[s])
        assert shared <= owned, (s, shared, owned)
        row = set(int(p) for p in sched.page_table[s])
        assert row <= shared | priv[s] | {pcfg.trash_page}, (s, row)
    # shared pages never written through
    for p in owned:
        np.testing.assert_array_equal(arr[p], tree_content[p], err_msg=f"{p}")
    # read correctness (token-derived expectation)
    view = np.asarray(KC.gather_slots(
        data, jnp.zeros((pcfg.num_slots,), jnp.float32),
        jnp.asarray(sched.page_table), pcfg, jnp.float32))
    for s, st in enumerate(sched.slots):
        if st is None:
            continue
        want = expected[s]
        got = view[s, :len(want), 0]
        assert (got == np.asarray(want)).all(), (s, got, want)


def run_prefix_walk(seed: int, steps: int = 40) -> None:
    rng = np.random.RandomState(seed)
    pcfg = PoolConfig(num_slots=3, page_size=4, pages_per_slot=4,
                      num_pages=int(rng.choice([8, 10, 12])),
                      quantized=False)
    prefix = RadixPrefixCache(pcfg.page_size, pcfg.total_pages)
    sched = Scheduler(pcfg, prefix=prefix)
    data = jnp.zeros((pcfg.total_pages + 1, pcfg.page_size, 1), jnp.float32)
    scale = jnp.zeros((pcfg.num_slots,), jnp.float32)
    tree_content: dict[int, np.ndarray] = {}    # page -> insertion snapshot
    expected: list[list[float]] = [[] for _ in range(pcfg.num_slots)]

    # a small base-prefix pool makes shared prefixes (and mid-page
    # divergences) likely; tokens are small ints, values derive from them
    bases = [rng.randint(1, 10, 8).tolist() for _ in range(3)]

    def make_prompt():
        base = bases[int(rng.randint(len(bases)))]
        keep = int(rng.randint(1, len(base) + 1))
        tail = rng.randint(1, 10, int(rng.randint(0, 4))).tolist()
        prompt = base[:keep] + tail
        return prompt[:pcfg.max_len - 6]

    def retire_done(slot):
        if sched.slots[slot] is not None and sched.slots[slot].done():
            sched.retire(slot)
            expected[slot] = []

    def check():
        # eviction (inside alloc_pages, under pressure) un-owns pages; their
        # snapshots retire with them — but a page may never leave the tree
        # while still snapshotted-as-owned un-freed (assert superset first)
        assert prefix.owned_pages <= set(tree_content)
        for p in list(tree_content):
            if p not in prefix.owned_pages:
                del tree_content[p]
        _check_prefix_invariants(sched, prefix, pcfg, data, tree_content,
                                 expected)
        _check_ledger(sched, pcfg, data, owned=prefix.owned_pages)

    for _ in range(steps):
        op = rng.choice(["submit", "admit", "decode", "retire", "preempt"])
        if op == "submit" and len(sched.queue) < 4:
            sched.submit(Request(prompt=make_prompt(),
                                 max_new_tokens=int(rng.randint(1, 6))))
        elif op == "admit":
            adm = sched.try_admit()
            if adm is not None:
                slot, st = adm
                resume = st.prefix_len
                if st.fork is not None:
                    src, dst = st.fork
                    data = data.at[dst].set(data[src])
                    # fork carries the source page verbatim
                    np.testing.assert_array_equal(np.asarray(data)[dst],
                                                  tree_content[src])
                # prefill computes only the suffix (the engine's hit path)
                toks = st.req.prompt[resume:]
                vals = jnp.asarray([[_tok_val(t)] for t in toks], jnp.float32)
                data, scale = KC.write_chunk(
                    data, scale, vals,
                    jnp.asarray(sched.page_table[slot]), jnp.int32(resume),
                    jnp.int32(len(toks)), jnp.int32(slot), pcfg)
                expected[slot] = [_tok_val(t) for t in st.req.prompt]
                donated = sched.commit_prefix(slot, None)
                arr = np.asarray(data)
                for p in donated:
                    tree_content[p] = arr[p].copy()
                st.generated.append(7)
                st.last_token = 7
                retire_done(slot)
        elif op == "decode":
            for slot in range(pcfg.num_slots):
                if sched.slots[slot] is None:
                    continue
                while not sched.ensure_page(slot):
                    evicted = sched.preempt_youngest()
                    assert evicted is not None, "pool exhausted"
                    expected[evicted] = []
                    if evicted == slot:
                        break
            active = sched.active_mask()
            if not active.any():
                continue
            new = jnp.asarray([[[_tok_val(s.last_token) if s else 0.0]]
                               for s in sched.slots], jnp.float32)
            data = KC.append_token(
                data, scale, new, jnp.asarray(sched.page_table),
                jnp.asarray(sched.lens_vector()), jnp.asarray(active), pcfg)
            for slot, st in enumerate(sched.slots):
                if st is None:
                    continue
                expected[slot].append(_tok_val(st.last_token))
                st.generated.append(7)
                st.last_token = 7
                retire_done(slot)
        elif op == "retire":
            live = [i for i, s in enumerate(sched.slots) if s is not None]
            if live:
                slot = int(rng.choice(live))
                sched.retire(slot)      # early EOS
                expected[slot] = []
        elif op == "preempt":
            evicted = sched.preempt_youngest()
            if evicted is not None:
                expected[evicted] = []

        check()
    # the walk must actually exercise sharing on most seeds; eviction runs
    # opportunistically (alloc_pages under pressure), covered by num_pages=8

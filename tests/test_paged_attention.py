"""Differential harness for the fused paged-attention decode kernel.

The jnp gather-then-attend path (``kv_cache.gather_slots`` +
``models/attention.py::gqa_attend``) is the numerics oracle; the fused
implementations (Pallas kernel in interpret mode, and the bit-locked jnp
page-scan the engine uses off-TPU) must agree with it:

(a) kernel vs oracle on synthetic pools: logits to float-roundoff over
    ragged ``cur_len``s, MHA/GQA/MQA head layouts, int8 + fp storage;
(b) kernel vs jnp page-scan (page_chunk=1): BIT-identical — same per-page
    online-softmax update order, so the two stay locked as kernels multiply;
(c) engine level: fused continuous-batched greedy decode is token-identical
    to the gather engine over staggered ragged requests (prompts and
    generations crossing page boundaries), in fp32 and int8 pools;
(d) preemption + resume under page pressure keeps fused == gather;
(e) MLA archs fall back to the gather reference and still match;
(f) q-block generalization (S query rows at positions lens..lens+S-1 with a
    per-row causal mask — chunked prefill / speculative verify): kernel vs
    oracle over S x heads x storage, BIT-locked to the jnp page-scan, and
    rank-3 decode == rank-4 S=1.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.kernels import paged_attention as PA
from repro.kernels.ops import paged_attention
from repro.models import build_lm, init_lm
from repro.models.attention import gqa_attend
from repro.serve import Engine, EngineConfig, PoolConfig
from repro.serve import kv_cache as KC
from repro.serve.kv_cache import PoolConfig as PC
from repro.sharding import ShardPlan

PLAN = ShardPlan(mesh=None)


# ---------------------------------------------------------------------------
# (a)+(b) kernel-level differential on synthetic pools
# ---------------------------------------------------------------------------

def _synthetic_pool(seed, *, b, pp, page, hkv, hq, dh, quantized):
    """Random paged pool + table + ragged lens; returns kernel args and the
    gather-reference args."""
    rng = np.random.RandomState(seed)
    total = b * pp                       # one page of slack per slot
    if quantized:
        kd = jnp.asarray(rng.randint(-128, 128, (total + 1, page, hkv, dh)),
                         jnp.int8)
        vd = jnp.asarray(rng.randint(-128, 128, (total + 1, page, hkv, dh)),
                         jnp.int8)
        ks = jnp.asarray(rng.randint(-6, 1, (b,)), jnp.float32)
        vs = jnp.asarray(rng.randint(-6, 1, (b,)), jnp.float32)
    else:
        kd = jnp.asarray(rng.randn(total + 1, page, hkv, dh), jnp.float32)
        vd = jnp.asarray(rng.randn(total + 1, page, hkv, dh), jnp.float32)
        ks = jnp.zeros((b,), jnp.float32)
        vs = jnp.zeros((b,), jnp.float32)
    table = jnp.asarray(rng.permutation(total).reshape(b, pp), jnp.int32)
    # ragged: first/mid/last positions incl. exact page boundaries
    lens = jnp.asarray(rng.randint(0, pp * page, (b,)), jnp.int32)
    lens = lens.at[0].set(0).at[-1].set(pp * page - 1)
    if b > 2:
        lens = lens.at[1].set(page)     # exactly one full page + boundary
    q = jnp.asarray(rng.randn(b, hq, dh), jnp.float32)
    return q, kd, vd, ks, vs, table, lens


def _gather_reference(q, kd, vd, ks, vs, table, lens, *, page, quantized):
    """The oracle: materialize every slot's dequantized view, full-softmax
    attend (gather_slots + gqa_attend semantics)."""
    from dataclasses import dataclass

    b, hq, dh = q.shape
    pp = table.shape[1]
    hkv = kd.shape[2]
    pcfg = PC(num_slots=b, page_size=page, pages_per_slot=pp,
              quantized=quantized)

    @dataclass
    class D:
        num_heads: int
        num_kv_heads: int
        head_dim: int
        real_heads: int

    k = KC.gather_slots(kd, ks, table, pcfg, jnp.float32)
    v = KC.gather_slots(vd, vs, table, pcfg, jnp.float32)
    out = gqa_attend(q[:, None], k, v, D(hq, hkv, dh, hq), lens[:, None])
    return out.reshape(b, hq, dh)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (6, 2), (3, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("quantized", [False, True])
def test_kernel_matches_gather_reference(hq, hkv, quantized):
    args = _synthetic_pool(0, b=4, pp=5, page=8, hkv=hkv, hq=hq, dh=16,
                           quantized=quantized)
    ref = _gather_reference(*args, page=8, quantized=quantized)
    out = PA.paged_attention_kernel(*args, page_size=8, quantized=quantized,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("quantized", [False, True])
def test_kernel_bit_locked_to_jnp_page_scan(quantized):
    """page_chunk=1 page-scan replays the kernel's exact update order —
    the two fused implementations must agree BITWISE."""
    args = _synthetic_pool(1, b=3, pp=4, page=8, hkv=2, hq=4, dh=16,
                           quantized=quantized)
    kout = PA.paged_attention_kernel(*args, page_size=8,
                                     quantized=quantized, interpret=True)
    jout = PA.paged_attention_jnp(*args, page_size=8, quantized=quantized,
                                  page_chunk=1)
    np.testing.assert_array_equal(np.asarray(kout), np.asarray(jout))


def test_chunked_page_scan_matches_reference():
    """Larger page_chunks (the off-TPU perf setting, incl. a non-dividing
    chunk that pads the logical page axis with trash pointers) stay within
    float-roundoff of the oracle."""
    args = _synthetic_pool(2, b=4, pp=5, page=8, hkv=2, hq=4, dh=16,
                           quantized=True)
    ref = _gather_reference(*args, page=8, quantized=True)
    for chunk in (2, 3, 5):
        out = PA.paged_attention_jnp(*args, page_size=8, quantized=True,
                                     page_chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


def test_ops_wrapper_impl_selection():
    args = _synthetic_pool(3, b=2, pp=3, page=8, hkv=2, hq=4, dh=16,
                           quantized=True)
    a = paged_attention(*args, page_size=8, quantized=True, impl="pallas")
    b = paged_attention(*args, page_size=8, quantized=True, impl="jnp",
                        page_chunk=1)
    c = paged_attention(*args, page_size=8, quantized=True, impl="auto")
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_allclose(np.asarray(c), np.asarray(a),
                               rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        paged_attention(*args, page_size=8, quantized=True, impl="nope")


def test_kernel_under_jit_and_scan():
    """The engine calls the kernel inside a jitted per-layer scan — the
    pallas_call must trace cleanly under both."""
    args = _synthetic_pool(4, b=2, pp=3, page=8, hkv=2, hq=4, dh=16,
                           quantized=True)
    q, kd, vd, ks, vs, table, lens = args
    f = jax.jit(functools.partial(PA.paged_attention_kernel, page_size=8,
                                  quantized=True, interpret=True))
    direct = f(q, kd, vd, ks, vs, table, lens)

    def body(carry, _):
        return carry, f(q, kd, vd, ks, vs, table, lens)

    _, scanned = jax.lax.scan(body, 0, jnp.arange(2))
    np.testing.assert_array_equal(np.asarray(direct), np.asarray(scanned[0]))
    np.testing.assert_array_equal(np.asarray(direct), np.asarray(scanned[1]))


# ---------------------------------------------------------------------------
# (f) q-block differential: S query rows per slot (chunked prefill /
#     speculative k-token verify) against the same oracles
# ---------------------------------------------------------------------------

def _synthetic_qblock(seed, *, b, pp, page, hkv, hq, dh, s, quantized):
    """Random paged pool + a (B, S, Hq, Dh) q-block whose rows sit at
    positions lens..lens+s-1 (every row within the slot horizon). lens
    still hits first/boundary/last-fitting positions."""
    q0, kd, vd, ks, vs, table, lens = _synthetic_pool(
        seed, b=b, pp=pp, page=page, hkv=hkv, hq=hq, dh=dh,
        quantized=quantized)
    rng = np.random.RandomState(seed + 100)
    hi = pp * page - s                  # last start where all rows fit
    lens = jnp.asarray(rng.randint(0, hi + 1, (b,)), jnp.int32)
    lens = lens.at[0].set(0).at[-1].set(hi)
    if b > 2:
        lens = lens.at[1].set(page - 1)     # rows straddle a page boundary
    q = jnp.asarray(rng.randn(b, s, hq, dh), jnp.float32)
    return q, kd, vd, ks, vs, table, lens


def _gather_reference_qblock(q, kd, vd, ks, vs, table, lens, *, page,
                             quantized):
    """Oracle: dequantized gather + full-softmax attend with per-row causal
    positions (row j of slot b attends cache positions <= lens[b]+j)."""
    from dataclasses import dataclass

    b, s, hq, dh = q.shape
    pp = table.shape[1]
    hkv = kd.shape[2]
    pcfg = PC(num_slots=b, page_size=page, pages_per_slot=pp,
              quantized=quantized)

    @dataclass
    class D:
        num_heads: int
        num_kv_heads: int
        head_dim: int
        real_heads: int

    k = KC.gather_slots(kd, ks, table, pcfg, jnp.float32)
    v = KC.gather_slots(vd, vs, table, pcfg, jnp.float32)
    positions = lens[:, None] + jnp.arange(s)[None]
    out = gqa_attend(q, k, v, D(hq, hkv, dh, hq), positions)
    return out.reshape(b, s, hq, dh)


@pytest.mark.parametrize("hq,hkv", [(4, 4), (6, 2), (3, 1)])  # MHA/GQA/MQA
@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("s", [1, 4, 8])    # decode / spec-verify / chunk
def test_qblock_kernel_matches_gather_reference(hq, hkv, quantized, s):
    args = _synthetic_qblock(5, b=4, pp=5, page=8, hkv=hkv, hq=hq, dh=16,
                             s=s, quantized=quantized)
    ref = _gather_reference_qblock(*args, page=8, quantized=quantized)
    out = PA.paged_attention_kernel(*args, page_size=8, quantized=quantized,
                                    interpret=True)
    assert out.shape == args[0].shape
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("s", [1, 4, 8])
def test_qblock_kernel_bit_locked_to_jnp_page_scan(quantized, s):
    """The q-block kernel and the page-chunk=1 jnp scan share one block
    update (``PA._block_update``) — BITWISE equal for every S."""
    args = _synthetic_qblock(6, b=3, pp=4, page=8, hkv=2, hq=4, dh=16,
                             s=s, quantized=quantized)
    kout = PA.paged_attention_kernel(*args, page_size=8,
                                     quantized=quantized, interpret=True)
    jout = PA.paged_attention_jnp(*args, page_size=8, quantized=quantized,
                                  page_chunk=1)
    np.testing.assert_array_equal(np.asarray(kout), np.asarray(jout))


@pytest.mark.parametrize("s", [3, 6])
def test_qblock_chunked_page_scan_matches_reference(s):
    args = _synthetic_qblock(7, b=4, pp=5, page=8, hkv=2, hq=4, dh=16,
                             s=s, quantized=True)
    ref = _gather_reference_qblock(*args, page=8, quantized=True)
    for chunk in (2, 3, 5):
        out = PA.paged_attention_jnp(*args, page_size=8, quantized=True,
                                     page_chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("quantized", [False, True])
def test_qblock_rank3_equals_rank4_s1(quantized):
    """Rank-3 (B, Hq, Dh) decode queries are the S=1 q-block squeezed:
    both fused impls must return bitwise-identical values for both ranks."""
    q, kd, vd, ks, vs, table, lens = _synthetic_pool(
        8, b=3, pp=4, page=8, hkv=2, hq=4, dh=16, quantized=quantized)
    for fn in (functools.partial(PA.paged_attention_kernel, interpret=True),
               functools.partial(PA.paged_attention_jnp, page_chunk=1)):
        r3 = fn(q, kd, vd, ks, vs, table, lens, page_size=8,
                quantized=quantized)
        r4 = fn(q[:, None], kd, vd, ks, vs, table, lens, page_size=8,
                quantized=quantized)
        assert r3.shape == q.shape
        assert r4.shape == (3, 1, 4, 16)
        np.testing.assert_array_equal(np.asarray(r3),
                                      np.asarray(r4[:, 0]))


def test_qblock_rows_match_sequential_single_token_calls():
    """Row j of a q-block call equals an S=1 call issued at lens+j — the
    property that makes ONE verify call equivalent to k+1 sequential decode
    steps over the same pool."""
    s = 4
    args = _synthetic_qblock(9, b=3, pp=5, page=8, hkv=2, hq=4, dh=16,
                             s=s, quantized=True)
    q, kd, vd, ks, vs, table, lens = args
    blk = PA.paged_attention_kernel(*args, page_size=8, quantized=True,
                                    interpret=True)
    for j in range(s):
        row = PA.paged_attention_kernel(q[:, j], kd, vd, ks, vs, table,
                                        lens + j, page_size=8,
                                        quantized=True, interpret=True)
        np.testing.assert_allclose(np.asarray(blk[:, j]), np.asarray(row),
                                   rtol=1e-6, atol=1e-6)


def test_qblock_ops_wrapper_rank4():
    args = _synthetic_qblock(10, b=2, pp=3, page=8, hkv=2, hq=4, dh=16,
                             s=3, quantized=True)
    a = paged_attention(*args, page_size=8, quantized=True, impl="pallas")
    b = paged_attention(*args, page_size=8, quantized=True, impl="jnp",
                        page_chunk=1)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# (c)-(e) engine-level differential
# ---------------------------------------------------------------------------

def _setup(arch="internlm2-1.8b"):
    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    return cfg, lm, params


def _prompts(cfg, n, lo, hi, seed=7):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size,
                        int(rng.randint(lo, hi + 1))).tolist()
            for _ in range(n)]


def _run_engine(lm, params, pcfg, prompts, gens, **ekw):
    eng = Engine(lm, params, EngineConfig(pool=pcfg, **ekw), PLAN)
    rids = [eng.submit(p, max_new_tokens=g) for p, g in zip(prompts, gens)]
    res = eng.run()
    return [res[r].tokens for r in rids], eng


@pytest.mark.parametrize("quantized", [False, True])
@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_fused_engine_token_identical_to_gather(quantized, impl):
    """Staggered ragged requests on 2 slots; page_size 4 so prompts and
    generations cross several page boundaries mid-request."""
    cfg, lm, params = _setup()
    pcfg = PoolConfig(num_slots=2, page_size=4, pages_per_slot=8,
                      quantized=quantized)
    prompts = _prompts(cfg, 4, 5, 15)
    gens = [8, 5, 7, 6]
    ref, _ = _run_engine(lm, params, pcfg, prompts, gens)
    out, _ = _run_engine(lm, params, pcfg, prompts, gens,
                         fused_attention=True, fused_impl=impl)
    assert out == ref, (impl, quantized, out, ref)


@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_fused_engine_after_preemption_and_resume(impl):
    """Shared pool smaller than slots*pages_per_slot forces preemption;
    the resumed (re-prefilled) requests must still match token-for-token."""
    cfg, lm, params = _setup()
    pcfg = PoolConfig(num_slots=3, page_size=4, pages_per_slot=10,
                      num_pages=12, quantized=False)
    prompts = _prompts(cfg, 3, 8, 10, seed=11)
    gens = [14, 14, 14]
    ref, ref_eng = _run_engine(lm, params, pcfg, prompts, gens)
    out, eng = _run_engine(lm, params, pcfg, prompts, gens,
                           fused_attention=True, fused_impl=impl)
    assert eng.summary()["preemptions"] >= 1
    assert ref_eng.summary()["preemptions"] >= 1
    assert out == ref


def test_mla_arch_falls_back_to_gather():
    """deepseek-v2 (MLA) with the fused flag on: every sublayer takes the
    gather reference path (the fallback matrix) and decode is unchanged."""
    cfg, lm, params = _setup("deepseek-v2-236b")
    assert any(sub.mixer_kind == "attn_mla" for sub in lm.period)
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                      quantized=False)
    prompts = _prompts(cfg, 2, 8, 12, seed=13)
    gens = [5, 6]
    ref, _ = _run_engine(lm, params, pcfg, prompts, gens)
    out, eng = _run_engine(lm, params, pcfg, prompts, gens,
                           fused_attention=True)
    assert not any(eng._fused_for(sub) for sub in lm.period
                   if sub.mixer_kind == "attn_mla")
    assert out == ref


def test_fused_chunked_prefill_matches_whole_prompt():
    """Chunked prefill writes + fused decode reads coexist on one pool."""
    cfg, lm, params = _setup()
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=6,
                      quantized=True)
    prompt = _prompts(cfg, 1, 24, 24, seed=17)[0]
    outs = []
    for chunk in (0, 8):
        eng = Engine(lm, params,
                     EngineConfig(pool=pcfg, prefill_chunk=chunk,
                                  fused_attention=True), PLAN)
        rid = eng.submit(prompt, max_new_tokens=6)
        outs.append(eng.run()[rid].tokens)
    assert outs[0] == outs[1]

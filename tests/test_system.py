"""End-to-end behaviour tests: the paper's FMNIST experiment (all five
Table-1 configurations), LM training loss decrease, TT-LM compression during
training, trainer resume."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import TrainConfig
from repro.data import fashion_like
from repro.models import mlp_tt as MLP
from repro.optim import adam as A


def _train_mlp(prior: bool, quantize: bool, steps: int = 250,
               batch: int = 64, lr: float = 3e-3, seed: int = 0):
    d = MLP.make_mlp(prior=prior, quantize=quantize)
    params = MLP.init_mlp(jax.random.PRNGKey(seed), d)
    tcfg = TrainConfig(learning_rate=lr, weight_decay=0.0)
    opt = A.init_adam(params, tcfg)
    xs, ys = fashion_like(batch * 64, seed=1)
    xq, yq = fashion_like(512, seed=2)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            MLP.mlp_loss, allow_int=True)(params, batch, d)
        params, opt = A.adam_update(params, grads, opt, jnp.asarray(lr), tcfg)
        if d.tt.rank_adapt:
            params = MLP.mlp_lambda_update(params, d)
        if d.qc.enable:
            params = MLP.mlp_scale_update(params, batch, grads, d)
        return params, opt, loss

    losses = []
    for i in range(steps):
        lo = (i * batch) % (len(ys) - batch)
        b = {"x": jnp.asarray(xs[lo:lo + batch]),
             "y": jnp.asarray(ys[lo:lo + batch])}
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    logits = MLP.mlp_forward(params, jnp.asarray(xq), d)
    acc = float((jnp.argmax(logits, -1) == jnp.asarray(yq)).mean())
    return params, d, losses, acc


def test_fmnist_float_with_prior_trains_and_compresses():
    params, d, losses, acc = _train_mlp(prior=True, quantize=False)
    assert losses[-1] < losses[0]
    assert acc > 0.55, acc     # synthetic 10-class: chance = 0.1
    eff1, eff2 = MLP.effective_ranks(params, d)
    assert sum(eff1) + sum(eff2) <= 16 * 4   # some shrink from init rank 16


def test_fmnist_fixed_with_prior_proposed_method():
    """The paper's proposed configuration: 4-bit cores + prior."""
    params, d, losses, acc = _train_mlp(prior=True, quantize=True)
    assert losses[-1] < losses[0]
    assert acc > 0.45, acc      # quantized: small degradation allowed
    counts = MLP.param_counts(d, *MLP.effective_ranks(params, d))
    # paper Table 1: fixed+prior ~5.11e4 bits, >=243x vs dense 1.49e7
    assert counts["fixed_bits"] <= 61264
    assert counts["dense_bits"] / counts["fixed_bits"] >= 240


def test_fmnist_quantized_close_to_float():
    _, _, lf, acc_f = _train_mlp(prior=False, quantize=False, steps=200)
    _, _, lq, acc_q = _train_mlp(prior=False, quantize=True, steps=200)
    assert acc_q > acc_f - 0.2, (acc_f, acc_q)   # small quantization gap


def test_table1_analytic_counts_match_paper():
    d = MLP.make_mlp()
    c = MLP.param_counts(d)
    assert c["tt_params"] == 14794                 # paper: 1.48e4
    assert c["float_bits"] == 473408               # paper: 4.74e5
    assert c["fixed_bits"] == 61264                # paper: 6.13e4
    assert abs(c["dense_bits"] - 1.49e7) / 1.49e7 < 0.01
    assert c["dense_bits"] / c["fixed_bits"] > 242  # paper: 243x


def test_lm_training_loss_decreases():
    from repro.launch.train import LM100M, train
    cfg = LM100M.replace(num_layers=2, d_model=128, num_heads=4,
                         num_kv_heads=4, d_ff=256, vocab_size=512)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=30, warmup_steps=3,
                       ckpt_dir="/tmp/repro_test_lm_ckpt", ckpt_every=0,
                       log_every=1000)
    import shutil
    shutil.rmtree(tcfg.ckpt_dir, ignore_errors=True)
    state, losses = train(cfg, "tp", tcfg, batch=8, seq=64, verbose=False)
    assert losses[-1] < losses[0] - 0.2, (losses[0], losses[-1])


def test_trainer_resume_from_checkpoint(tmp_path):
    from repro.launch.train import LM100M, train
    cfg = LM100M.replace(num_layers=1, d_model=64, num_heads=4,
                         num_kv_heads=4, d_ff=128, vocab_size=256)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=2,
                       ckpt_dir=str(tmp_path), ckpt_every=5, log_every=1000)
    train(cfg, "tp", tcfg, batch=4, seq=32, verbose=False)
    tcfg2 = TrainConfig(learning_rate=1e-3, total_steps=15, warmup_steps=2,
                        ckpt_dir=str(tmp_path), ckpt_every=5, log_every=1000)
    state, losses = train(cfg, "tp", tcfg2, batch=4, seq=32, verbose=False)
    assert int(state.step) == 15
    assert len(losses) == 5          # resumed at 10, ran 5 more

"""Low-precision numerics: pow-2 fake-quant, STE, scale manager (§3.3),
BinaryConnect semantics (Eq. 3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant as Q


def test_fake_quant_levels():
    x = jnp.linspace(-3, 3, 201)
    y = Q.fake_quant(x, jnp.asarray(-2.0), 4)
    levels = np.unique(np.asarray(y))
    assert len(levels) <= 16
    # grid spacing is the scale 2^-2
    diffs = np.diff(levels)
    np.testing.assert_allclose(diffs, 0.25, rtol=1e-6)


def test_fake_quant_clips_to_range():
    x = jnp.asarray([-1000.0, 1000.0])
    y = Q.fake_quant(x, jnp.asarray(0.0), 8)
    assert float(y[0]) == -128.0 and float(y[1]) == 127.0


def test_ste_passes_gradient_inside_range_only():
    x = jnp.asarray([-0.3, 0.0, 0.4, 50.0, -50.0])
    g = jax.grad(lambda v: jnp.sum(Q.fake_quant(v, jnp.asarray(-4.0), 4)))(x)
    # scale 2^-4: representable |x| <= 8*2^-4 = 0.5
    assert float(g[0]) == 1.0 and float(g[2]) == 1.0
    assert float(g[3]) == 0.0 and float(g[4]) == 0.0


def test_quantize_store_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,))
    q1 = Q.quantize_store(x, jnp.asarray(-3.0), 8)
    q2 = Q.quantize_store(q1, jnp.asarray(-3.0), 8)
    np.testing.assert_allclose(q1, q2)


@pytest.mark.parametrize("magnitude", [0.01, 1.0, 37.0, 1000.0])
def test_scale_manager_converges_to_band(magnitude):
    """§3.3: mean |x/2^k| driven into [0.1, 0.3]."""
    s = Q.init_scale(0)
    for i in range(80):
        x = jax.random.normal(jax.random.PRNGKey(i), (256,)) * magnitude
        s = Q.update_scale(s, x)
    m = float(s.mean_abs)
    assert 0.05 < m < 0.5, (m, int(s.log2))


def test_quant_edge_bwd_quantizes_gradient():
    site = Q.init_act_quant()
    x = jax.random.normal(jax.random.PRNGKey(0), (64,))

    def f(x):
        return jnp.sum(Q.quant_edge(x, site, 8, 16) * 0.3)

    g = jax.grad(f)(x)
    # gradient values lie on the 16-bit grid with step 2^{0-(16-1)}
    # (up to f32 representation error of the product grid_value * step)
    step = 2.0 ** (0 - 15)
    ratio = np.asarray(g, np.float64) / step
    np.testing.assert_allclose(ratio, np.round(ratio), rtol=0, atol=1e-2)


def test_probe_carries_grad_stat():
    site = Q.init_act_quant()
    x = jax.random.normal(jax.random.PRNGKey(1), (64,))

    def f(probe):
        s = Q.ActQuant(site.act, site.grad, probe)
        return jnp.sum(Q.quant_edge(x, s, 8, 16) ** 2)

    stat = jax.grad(f)(site.probe)
    assert float(stat) > 0.0


def test_binaryconnect_buffer_semantics():
    """Eq. (3): gradient of loss(Q(w)) applied to the fp buffer; quantized
    view changes only when the buffer crosses a grid boundary."""
    w = jnp.asarray([0.10])         # buffer
    step = jnp.asarray(-2.0)        # grid 0.25
    lr = 0.01

    def loss(w):
        return jnp.sum(Q.fake_quant(w, step, 4) * 1.0)

    for _ in range(5):
        g = jax.grad(loss)(w)
        w = w - lr * g
    # buffer moved even while quantized value stayed on the same level
    assert float(w[0]) < 0.10
    assert float(Q.fake_quant(w, step, 4)[0]) == 0.0

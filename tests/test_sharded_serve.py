"""Multi-device serving + dp-only training on a forced 8-device CPU mesh.

Each case runs in a subprocess (device count must be set before jax
initializes) and asserts the tentpole contract: the sharded path is
TOKEN-IDENTICAL (serving) / loss-identical on step one (training) to the
mesh-less reference:

(a) engine decode, gather + fused paged-attention, on a TP mesh that shards
    the paged KV pool over KV heads — attention arch (1x8) and the jamba
    hybrid (4x2, state pool sharded over d_inner, MoE over the model axis),
(b) prefix-cache admission + COW forks on head-sharded pages,
(c) the dp-only shard_map train step: step-1 loss bitwise vs the mesh-less
    step, and a jaxpr walk proving the int8 gradient wire is the ONLY
    payload-sized collective in the step.
"""
import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
import repro.configs as C
from repro.models import build_lm, init_lm
from repro.serve import Engine, EngineConfig, PoolConfig
from repro.sharding import ShardPlan, make_plan

CASE = "%s"
assert len(jax.devices()) == 8


def setup(arch, **over):
    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none", **over)
    lm = build_lm(cfg)
    return cfg, lm, init_lm(jax.random.PRNGKey(0), lm)


def prompts_for(cfg, n=4, lo=8, hi=16, seed=3):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size,
                        int(rng.randint(lo, hi + 1))).tolist()
            for _ in range(n)]


def run_engine(lm, params, plan, prompts, pcfg, gen=12, **ecfg_kw):
    eng = Engine(lm, params, EngineConfig(pool=pcfg, **ecfg_kw), plan)
    rids = [eng.submit(p, max_new_tokens=gen) for p in prompts]
    res = eng.run()
    return [res[r].tokens for r in rids], eng.summary()


if CASE in ("engine_attn", "engine_jamba"):
    if CASE == "engine_attn":
        # 8 KV heads on a (1, 8) mesh: one KV head (2 query heads) per device
        cfg, lm, params = setup("internlm2-1.8b", d_model=256, num_heads=16,
                                num_kv_heads=8, d_ff=160)
        mesh = jax.make_mesh((1, 8), ("data", "model"))
    else:
        # hybrid: attn KV heads (2) and mamba d_inner (128) shard over
        # model=2; the 4-expert MoE rides the same mesh. All 8 devices used.
        cfg, lm, params = setup("jamba-1.5-large")
        mesh = jax.make_mesh((4, 2), ("data", "model"))
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                      quantized=True)
    prompts = prompts_for(cfg)
    ref, _ = run_engine(lm, params, ShardPlan(mesh=None), prompts, pcfg)
    for fused in (False, True):
        got, _ = run_engine(lm, params, make_plan(mesh, "tp"), prompts, pcfg,
                            fused_attention=fused)
        assert got == ref, (fused, got, ref)
        print("OK", CASE, "fused" if fused else "gather", "token-identical")

elif CASE == "prefix":
    cfg, lm, params = setup("internlm2-1.8b", d_model=256, num_heads=16,
                            num_kv_heads=8, d_ff=160)
    mesh = jax.make_mesh((1, 8), ("data", "model"))
    # one 20-token base: full-path reuse + two mid-page divergences, so the
    # sharded path must take COW forks on head-sharded pages
    rng = np.random.RandomState(7)
    v = cfg.vocab_size
    base = rng.randint(0, v, 20).tolist()
    sfx = [rng.randint(0, v, 6).tolist() for _ in range(3)]
    prompts = [base + sfx[0], base + sfx[1], base[:18] + sfx[2],
               base + sfx[0][:3] + sfx[1][:3]]
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                      quantized=True)
    ref, _ = run_engine(lm, params, ShardPlan(mesh=None), prompts, pcfg,
                        gen=6)
    got, s = run_engine(lm, params, make_plan(mesh, "tp"), prompts, pcfg,
                        gen=6, prefix_cache=True)
    assert got == ref, (got, ref)
    assert s["prefix_hit_tokens"] > 0 and s["cow_forks"] > 0, s
    assert s["prefill_tokens"] == s["prompt_tokens"] - s["prefix_hit_tokens"]
    # memory ledger on the sharded path: reconciled totals and a per-device
    # breakdown covering all 8 forced devices, each holding at least the
    # pool bytes the engine reports for it
    mem = s["memory"]
    assert mem["reconcile"]["ok"], mem["reconcile"]
    assert mem["sites"]["prefix_bytes_saved"]["peak_bytes"] > 0, mem["sites"]
    per_dev = mem["per_device"]
    assert len(per_dev) == 8, per_dev
    assert sum(per_dev.values()) >= mem["sites"]["kv_pool"]["bytes"], per_dev
    print("OK prefix hits", s["prefix_hit_tokens"], "forks", s["cow_forks"],
          "ledger devices", len(per_dev))

elif CASE == "dp_train":
    from jax.sharding import PartitionSpec as P
    from repro.configs.base import TrainConfig
    from repro.launch.mesh import make_dp_mesh
    from repro.launch.steps import (init_dp_train_state, init_train_state,
                                    make_dp_train_step, make_train_step)

    cfg, lm, params = setup("internlm2-1.8b")
    tcfg = TrainConfig(total_steps=4, warmup_steps=1, grad_clip=1.0,
                       grad_compress=True)
    b1 = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0,
                                       cfg.vocab_size),
          "labels": jax.random.randint(jax.random.PRNGKey(2), (8, 16), 0,
                                       cfg.vocab_size)}
    b2 = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (8, 16), 0,
                                       cfg.vocab_size),
          "labels": jax.random.randint(jax.random.PRNGKey(4), (8, 16), 0,
                                       cfg.vocab_size)}
    # mesh-less reference: same int8 + error-feedback wire semantics,
    # quantized on one device (compress_decompress)
    s_ref = init_train_state(params, tcfg)
    ref_step = jax.jit(make_train_step(lm, ShardPlan(mesh=None), tcfg))
    s_ref, m1_ref = ref_step(s_ref, b1)
    _, m2_ref = ref_step(s_ref, b2)

    plan = make_plan(make_dp_mesh(8), "tp")
    state = init_dp_train_state(params, tcfg, plan)
    step = jax.jit(make_dp_train_step(lm, plan, tcfg))
    # step-1 loss is pre-update: forward math must be bitwise-stable
    # across shard_map, so it matches the mesh-less loss exactly
    state, m1 = step(state, b1)
    np.testing.assert_allclose(float(m1["loss"]), float(m1_ref["loss"]),
                               rtol=0, atol=1e-6)
    # step 2 sees wire-vs-single-device quantization differences in the
    # updated params; the losses stay close
    _, m2 = step(state, b2)
    np.testing.assert_allclose(float(m2["loss"]), float(m2_ref["loss"]),
                               rtol=2e-2)
    print("OK dp_train loss", float(m1["loss"]))

    # jaxpr walk: every payload-sized collective operand is the int8 wire's
    # all_gather — gradients cross the wire as int8 codes and NOTHING else
    # payload-sized moves between replicas (scale pmax rows and scalar
    # loss/metric pmeans are tens of bytes)
    COLL = ("all_gather", "psum", "pmax", "pmin", "pmean", "all_to_all",
            "reduce_scatter", "ppermute", "all_reduce")
    jx = jax.make_jaxpr(make_dp_train_step(lm, plan, tcfg))(state, b1)

    def walk(j, found):
        for eqn in j.eqns:
            if any(c in eqn.primitive.name for c in COLL):
                a = eqn.invars[0].aval
                found.append((eqn.primitive.name, a.dtype,
                              a.size * a.dtype.itemsize))
            for v in eqn.params.values():
                inner = getattr(v, "jaxpr", v)
                if hasattr(inner, "eqns"):
                    walk(inner, found)
        return found

    colls = walk(jx.jaxpr, [])
    big = [c for c in colls if c[2] >= 2048]
    assert big, colls
    assert all(n == "all_gather" and d == jnp.dtype(jnp.int8)
               for n, d, _ in big), big
    print("OK dp_train wire:", len(big), "payload collectives, all int8",
          len(colls) - len(big), "small")
"""

CASES = ["engine_attn", "engine_jamba", "prefix", "dp_train"]


@pytest.mark.parametrize("case", CASES)
def test_sharded_serve(case):
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT % case],
        capture_output=True, text=True, timeout=600,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root",
             # pin the platform: the forced 8-device mesh is a CPU
             # construct (see test_distributed.py)
             "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")},
        cwd="/root/repo")
    assert r.returncode == 0, f"stdout={r.stdout}\nstderr={r.stderr[-3000:]}"
    assert "OK" in r.stdout

"""Rank adaptation (Eq. 2/4): closed-form λ is the stationary point of g,
training shrinks ranks, pruning round-trips."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rank_adapt as RA
from repro.core import ttm


def _setup(key=0):
    spec = ttm.make_spec(24, 30, 3, 8)
    cores = ttm.init_cores(jax.random.PRNGKey(key), spec)
    return spec, cores


def test_lambda_update_is_stationary_point():
    """Eq. (4) solves dg/dλ = 0 exactly."""
    spec, cores = _setup()
    lambdas = RA.update_lambdas(cores, spec)

    def g_of_lambda(lams):
        total = 0.0
        for n in range(spec.d - 1):
            sq = RA.slice_sqnorms(cores[n])
            c = 0.5 * RA.group_size(spec, n)
            total = total + jnp.sum(sq / lams[n] + c * jnp.log(lams[n]))
        return total

    grads = jax.grad(g_of_lambda)(lambdas)
    for g, lam in zip(grads, lambdas):
        np.testing.assert_allclose(g / jnp.abs(lam), 0.0, atol=1e-3)


def test_prior_gradient_shrinks_small_slices():
    spec, cores = _setup()
    # make slice 0 of core 0 tiny -> its lambda small -> gradient pressure
    cores[0] = cores[0].at[..., 0].multiply(1e-3)
    lambdas = RA.update_lambdas(cores, spec)

    def loss(cores):
        return RA.prior_loss(cores, lambdas, spec)

    g = jax.grad(loss)(cores)
    # gradient on the small slice is proportionally much larger
    g0 = jnp.abs(g[0][..., 0]).mean() / jnp.abs(cores[0][..., 0]).mean()
    g1 = jnp.abs(g[0][..., 1]).mean() / jnp.abs(cores[0][..., 1]).mean()
    assert float(g0) > float(g1)


def test_training_with_prior_reduces_rank():
    """A true TT-rank-(2,2) target learned with init ranks (4,8) should
    shrink ranks one-shot during training (paper §3.1)."""
    spec, cores = _setup()
    true_spec = ttm.make_spec(24, 30, 3, 2)
    tc = ttm.init_cores(jax.random.PRNGKey(42), true_spec, scale=1.0)
    x = jax.random.normal(jax.random.PRNGKey(5), (128, 30))
    y = ttm.ttm_matvec(tc, x, true_spec)
    lambdas = RA.init_lambdas(spec)

    def loss(cores, lambdas):
        pred = ttm.ttm_matvec(cores, x, spec)
        return (jnp.mean(jnp.square(pred - y))
                + 0.005 * RA.prior_loss(cores, lambdas, spec))

    lr = 0.03
    grad_fn = jax.jit(jax.grad(loss))
    for i in range(1500):
        g = grad_fn(cores, lambdas)
        cores = [c - lr * gc for c, gc in zip(cores, g)]
        lambdas = RA.update_lambdas(cores, spec)
    eff = RA.effective_ranks(lambdas, threshold=1e-2)
    assert sum(eff) < sum(spec.ranks[1:-1]), eff     # shrank from (4, 8)
    pred = ttm.ttm_matvec(cores, x, spec)
    rel = float(jnp.linalg.norm(pred - y) / jnp.linalg.norm(y))
    assert rel < 0.5, rel
    assert all(np.isfinite(np.asarray(l)).all() for l in lambdas)


def test_compress_cores_roundtrip():
    spec, cores = _setup()
    # zero two slices to make them prunable
    cores[0] = cores[0].at[..., :3].multiply(1e-6)
    lambdas = RA.update_lambdas(cores, spec)
    masked = RA.apply_masks(cores, RA.rank_masks(lambdas, 1e-2))
    small, new_spec = RA.compress_cores(cores, lambdas, spec, 1e-2)
    assert new_spec.ranks[1] == spec.ranks[1] - 3
    x = jax.random.normal(jax.random.PRNGKey(9), (4, 30))
    np.testing.assert_allclose(ttm.ttm_matvec(masked, x, spec),
                               ttm.ttm_matvec(small, x, new_spec),
                               rtol=1e-4, atol=1e-4)


def test_memory_bits_accounting():
    spec = ttm.make_spec(512, 896, 4, 16, j_dims=(4, 4, 2, 16),
                         i_dims=(7, 4, 2, 16))
    assert RA.tt_memory_bits(spec, 4) == 9664 * 4    # paper layer-1 cores
    assert RA.tt_memory_bits(spec, 4, eff_ranks=[8, 8, 8]) < 9664 * 4

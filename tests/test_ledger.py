"""Unit tests for the live memory ledger (``repro.obs.ledger``), the
bounded ServeMetrics timeline, and the bench-history regression gate
(``benchmarks/history.py``) — plus the live-vs-analytic Table-1
cross-check the CI telemetry gate asserts on the train-wire bench."""
import importlib.util
import json
import pathlib

import jax.numpy as jnp
import numpy as np
import pytest

from repro.obs import MemoryLedger, device_breakdown


def _load_bench(name: str):
    p = (pathlib.Path(__file__).resolve().parent.parent
         / "benchmarks" / f"{name}.py")
    spec = importlib.util.spec_from_file_location(f"{name}_bench", p)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestMemoryLedger:
    def test_counted_and_overlay_sites(self):
        led = MemoryLedger()
        led.set("a", 100, fp32=400)
        led.set("b", 50)
        led.set("overlay", 30, counted=False)
        assert led.total() == 150            # overlay never counted
        assert led.fp32_total() == 450       # shadow defaults to own bytes
        assert led.reduction_vs_fp32() == 3.0
        assert led.total(("a",)) == 100
        assert led.reduction_vs_fp32(("a",)) == 4.0
        led.set("a", 80, fp32=400)           # idempotent overwrite
        assert led.total() == 130 and led.get("a") == 80
        s = led.summary()
        assert s["sites"]["a"]["peak_bytes"] == 100   # peak survives shrink
        assert s["sites"]["overlay"]["counted"] is False
        led.drop("b")
        assert led.total() == 80
        json.dumps(led.summary())            # JSON-friendly throughout

    def test_phase_watermarks(self):
        led = MemoryLedger()
        led.set("a", 100)
        assert led.watermark("init")["total_bytes"] == 100
        # entering a phase records a watermark even with no set() after
        led.set_phase("decode")
        assert led.watermark("decode")["total_bytes"] == 100
        led.set("a", 40)                     # shrink: watermark holds
        assert led.watermark("decode")["total_bytes"] == 100
        led.set("a", 300)
        wm = led.watermark("decode")
        assert wm["total_bytes"] == 300 and wm["sites"]["a"] == 300
        # earlier phase untouched
        assert led.watermark("init")["total_bytes"] == 100
        assert led.watermark("prefill") is None

    def test_reconcile_one_sided(self):
        led = MemoryLedger()
        led.set("a", 100)
        rec = led.reconcile(live_bytes=100)
        assert rec["ok"] and rec["coverage_frac"] == 1.0
        # claiming more than live means a stale/double-counted site
        assert not led.reconcile(live_bytes=50)["ok"]
        # overlays never tip the reconcile
        led.set("overlay", 10**9, counted=False)
        assert led.reconcile(live_bytes=100)["ok"]

    def test_device_breakdown(self):
        x = jnp.zeros((4, 8), jnp.float32)
        per = device_breakdown({"x": x}, [x])
        assert len(per) >= 1
        assert sum(per.values()) == 2 * x.nbytes


class TestMetricsTimeline:
    def test_ring_bounded_aggregates_exact(self):
        from repro.serve.metrics import ServeMetrics
        m = ServeMetrics(clock=lambda: 0.0, timeline_capacity=4)
        fills = [1, 2, 3, 4, 3, 2, 1, 4]
        for n in fills:
            m.decode_step(n, free_pages=8 - n)
        # the ring is bounded and counts its drops...
        assert len(m.timeline) == 4
        assert m.timeline_dropped == len(fills) - 4
        # ...while the aggregates stay exact over ALL steps
        s = m.summary()
        assert s["batch_fill_mean"] == pytest.approx(float(np.mean(fills)))
        assert s["free_pages_min"] == 8 - max(fills)
        assert s["decode_steps"] == len(fills)
        assert s["timeline_dropped"] == 4
        assert "trace_dropped" in s and "counter_totals" in s
        json.dumps(s)


class TestHistoryGate:
    DOC = {"bench": "train_wire", "reduction_x": 20.0,
           "step_ms_low_precision": 50.0,
           "memory": {"table1_live_reduction_x": 20.0}}

    def test_append_and_gate(self, tmp_path):
        H = _load_bench("history")
        path = str(tmp_path / "hist.jsonl")
        e1 = H.append_entry(self.DOC, path, sha="aaa", timestamp="t0")
        assert e1["metrics"]["reduction_x"] == 20.0
        e2 = H.append_entry(self.DOC, path, sha="bbb", timestamp="t1")
        assert H.check_regression(e2, [e1]) == []
        assert H.gate(path) == []
        # 5% band on the deterministic memory metric: a 15% drop fails
        bad = dict(self.DOC, reduction_x=17.0,
                   memory={"table1_live_reduction_x": 17.0})
        e3 = H.append_entry(bad, path, sha="ccc", timestamp="t2")
        fails = H.check_regression(e3, [e1, e2])
        assert any("reduction_x" in f for f in fails)
        assert H.gate(path) != []            # newest entry regressed

    def test_throughput_band_is_loose(self):
        H = _load_bench("history")
        e_ok = {"bench": "train_wire",
                "metrics": H.extract_metrics(
                    dict(self.DOC, step_ms_low_precision=90.0))}
        prior = [{"bench": "train_wire",
                  "metrics": H.extract_metrics(self.DOC)}]
        # +80% step time sits inside the 2x wall-clock band...
        assert H.check_regression(e_ok, prior) == []
        # ...a >2x blowup does not
        e_bad = {"bench": "train_wire",
                 "metrics": H.extract_metrics(
                     dict(self.DOC, step_ms_low_precision=150.0))}
        fails = H.check_regression(e_bad, prior)
        assert any("step_ms_low_precision" in f for f in fails)


def test_train_wire_live_matches_analytic():
    """The ISSUE's CI cross-check: the live ledger built from the step's
    actual artifacts must agree with the analytic site table within 10%
    and clear the paper's 8x floor."""
    TW = _load_bench("train_wire")
    low = TW.fmnist_low_precision_step(32)
    sites, baseline, deploy = TW.fmnist_site_table(low)
    led = TW.live_memory_ledger(low, deploy, baseline)
    live = led.reduction_vs_fp32(TW.TABLE1_SITES)
    analytic = sum(baseline.values()) / sum(sites.values())
    assert live >= 8
    assert abs(live - analytic) <= 0.1 * analytic
    assert led.total(TW.TABLE1_SITES) == sum(sites.values())
    assert led.reconcile()["ok"]
    assert led.watermark("train_step")["total_bytes"] == led.total()

"""TTM algebra: matvec vs dense reconstruction, PE routing, FLOP model,
gradient equivalence of the paper's What-path vs autodiff."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ttm

CASES = [
    (512, 896, 4, 16),     # paper layer 1
    (16, 512, 2, 16),      # paper layer 2
    (120, 84, 3, 8),
    (64, 64, 2, 4),
    (7, 5, 1, 4),          # d=1 degenerates to dense
]


@pytest.mark.parametrize("j,i,d,r", CASES)
def test_matvec_matches_dense(j, i, d, r):
    spec = ttm.make_spec(j, i, d, r)
    cores = ttm.init_cores(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, i))
    w = ttm.ttm_to_dense(cores, spec)
    np.testing.assert_allclose(ttm.ttm_matvec(cores, x, spec), x @ w.T,
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("j,i,d,r", CASES)
def test_pe_routed_matvec(j, i, d, r):
    spec = ttm.make_spec(j, i, d, r)
    cores = ttm.init_cores(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (6, i))
    np.testing.assert_allclose(ttm.ttm_matvec_pe(cores, x, spec),
                               ttm.ttm_matvec(cores, x, spec),
                               rtol=2e-4, atol=2e-4)


def test_batched_shapes():
    spec = ttm.make_spec(120, 84, 3, 8)
    cores = ttm.init_cores(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3, 84))
    assert ttm.ttm_matvec(cores, x, spec).shape == (2, 3, 120)


def test_param_count_and_compression():
    spec1 = ttm.make_spec(512, 896, 4, 16, j_dims=(4, 4, 2, 16),
                          i_dims=(7, 4, 2, 16))
    spec2 = ttm.make_spec(16, 512, 2, 16, j_dims=(1, 16), i_dims=(32, 16))
    # paper: 1.48e4 params incl 522 biases -> cores alone 14272
    assert spec1.num_params == 9664
    assert spec2.num_params == 4608
    assert spec1.num_params + spec2.num_params == 14272
    assert spec1.dense_params == 512 * 896
    assert spec1.compression > 30


def test_flops_model_counts_every_step():
    spec = ttm.make_spec(512, 896, 4, 16)
    f = ttm.ttm_flops_matvec(spec, batch=64)
    assert f > 0
    # linear in batch
    assert ttm.ttm_flops_matvec(spec, batch=128) == 2 * f
    # NOTE: TTM matvec FLOPs are NOT necessarily below dense — middle-core
    # cost scales with R^2 (EXPERIMENTS.md §Perf Cell C). At rank 4 the
    # chain is cheaper than dense; at rank 16 it is not.
    small = ttm.make_spec(512, 896, 4, 4)
    assert ttm.ttm_flops_matvec(small, batch=64) < 2 * 64 * 896 * 512


def test_grads_via_what_path_match_autodiff():
    """Paper Appendix A.2: core grads via the full-weight gradient What
    (PE3 outer product + Eqs. 14-19 contractions) equal autodiff through
    the contraction chain."""
    spec = ttm.make_spec(24, 30, 3, 6)
    cores = ttm.init_cores(jax.random.PRNGKey(0), spec)
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 30))
    ybar = jax.random.normal(jax.random.PRNGKey(2), (16, 24))

    def loss(cores):
        y = ttm.ttm_matvec(cores, x, spec)
        return jnp.sum(y * ybar)

    auto = jax.grad(loss)(cores)
    what = ttm.pe3_outer(x, ybar)          # (J, I)
    manual = ttm.core_grads_from_what(what, cores, spec)
    for a, m in zip(auto, manual):
        np.testing.assert_allclose(a, m, rtol=1e-3, atol=1e-3)


def test_auto_factorize_balanced():
    j, i = ttm.auto_factorize(7168, 20480, 3)
    assert int(np.prod(j)) == 7168 and int(np.prod(i)) == 20480
    assert max(j) / min(j) < 16

"""MoE routing/dispatch semantics (single-shard path; the EP shard_map path
is covered by test_distributed.py on a forced multi-device CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, MoEConfig
from repro.models import moe as M


def _setup(e=8, k=2, d=32, f=64, shared=0, cf=2.0):
    cfg = ModelConfig(name="m", d_model=d, d_ff=f, dtype="float32",
                      moe=MoEConfig(num_experts=e, top_k=k,
                                    num_shared=shared, capacity_factor=cf))
    mdef = M.make_moe(cfg)
    params = M.init_moe(jax.random.PRNGKey(0), mdef, cfg)
    return cfg, mdef, params


def test_routing_topk_normalized():
    cfg, mdef, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    idx, w, aux = M._route(params, x, mdef, cfg)
    assert idx.shape == (64, 2) and w.shape == (64, 2)
    np.testing.assert_allclose(np.sum(np.asarray(w), -1), 1.0, rtol=1e-3)
    assert float(aux) >= 1.0 - 1e-3   # switch aux lower bound at balance


def test_moe_forward_matches_dense_dispatch():
    """Capacity-unconstrained dispatch == explicit per-token expert sum."""
    cfg, mdef, params = _setup(cf=100.0)    # no drops
    b, s = 2, 16
    x = jax.random.normal(jax.random.PRNGKey(2), (b, s, cfg.d_model))
    out, aux = M.moe_forward(params, x, mdef, cfg)
    # reference: run every token through its top-k experts explicitly
    x2 = x.reshape(-1, cfg.d_model)
    idx, w, _ = M._route(params, x2, mdef, cfg)
    ref = np.zeros_like(x2)
    for e in range(cfg.moe.num_experts):
        ep = {kk: jax.tree.map(lambda a: a[e], params[kk])
              for kk in ("gate", "up", "down")}
        h = M.silu(x2 @ ep["gate"]["w"]) * (x2 @ ep["up"]["w"])
        ye = h @ ep["down"]["w"]
        sel = np.asarray((idx == e) * w).sum(-1)
        ref += np.asarray(ye) * sel[:, None]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               ref, rtol=2e-3, atol=2e-3)


def test_capacity_drops_tokens():
    cfg, mdef, params = _setup(cf=0.1)      # tiny capacity
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, cfg.d_model))
    out, _ = M.moe_forward(params, x, mdef, cfg)
    # some tokens must have been dropped (zero output rows)
    norms = np.linalg.norm(np.asarray(out[0]), axis=-1)
    assert (norms < 1e-6).any()


def test_shared_experts_always_active():
    cfg, mdef, params = _setup(shared=1, cf=0.01)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 32, cfg.d_model))
    out, _ = M.moe_forward(params, x, mdef, cfg)
    norms = np.linalg.norm(np.asarray(out[0]), axis=-1)
    assert (norms > 1e-6).all()     # shared path fires for every token


def test_moe_grads_flow_to_experts_and_router():
    cfg, mdef, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, cfg.d_model))

    def loss(p):
        out, aux = M.moe_forward(p, x, mdef, cfg)
        return jnp.sum(out ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]["w"]).sum()) > 0
    assert float(jnp.abs(g["gate"]["w"]).sum()) > 0

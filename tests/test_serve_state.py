"""repro.serve.state_cache acceptance tests — SSM/hybrid archs in the
continuous-batching engine:

(a) fp32 engine decode of staggered rwkv6/jamba requests is token-identical
    to the static scan-carried loop (admission, decode, retirement, refill),
    including preemption + re-prefill resume;
(b) chunked prefill carries recurrent state across chunk boundaries exactly
    (token-identical to whole-prompt prefill on capacity-free configs);
(c) the int8 state cache stays within the pow-2 quantization tolerance and
    cuts state bytes >= 3.5x vs fp32;
(d) slot isolation: a pool-walk-style sweep of reset/write/snapshot/restore
    shows one slot's state can never leak into another's.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.configs.base import MoEConfig
from repro.launch.steps import make_prefill_step
from repro.models import build_lm, init_lm, lm_decode_step
from repro.numerics import NumericsPolicy, QuantSpec
from repro.serve import Engine, EngineConfig, PoolConfig
from repro.serve import state_cache as SC
from repro.sharding import ShardPlan

PLAN = ShardPlan(mesh=None)


def _setup(arch, **over):
    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none", **over)
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    return cfg, lm, params


def _prompts(cfg, n, lo, hi, seed=3):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size,
                        int(rng.randint(lo, hi + 1))).tolist()
            for _ in range(n)]


def _static_greedy(lm, params, prompt, gen_len, max_len):
    """Per-request reference: whole-prompt prefill + scalar-cur_len greedy
    decode carrying SSM state through the cache tree."""
    prefill = jax.jit(make_prefill_step(lm, PLAN))
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = prefill(params, {"tokens": toks})
    p = len(prompt)

    # grow only the per-token attention leaves (keyed by name: recurrent
    # state axes can coincide with the prompt length)
    def pad_seq(path, a):
        leaf = path[-1].key if hasattr(path[-1], "key") else None
        if leaf in ("k", "v", "c_kv", "k_rope") and a.shape[2] == p:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, max_len - p)
            return jnp.pad(a, pad)
        return a

    cache = jax.tree_util.tree_map_with_path(pad_seq, cache)
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    for j in range(gen_len - 1):
        lg, cache = lm_decode_step(params, cache,
                                   jnp.asarray([[tok]], jnp.int32),
                                   jnp.int32(p + j), lm, PLAN)
        tok = int(jnp.argmax(lg[0, -1]))
        out.append(tok)
    return out


# ---------------------------------------------------------------------------
# (a) fp32 continuous batching == static reference, token for token
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "jamba-1.5-large"])
def test_ssm_continuous_batching_matches_static_decode(arch):
    cfg, lm, params = _setup(arch)
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                      quantized=False)
    eng = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
    # staggered: 4 requests on 2 slots with different prompt/gen lengths
    prompts = _prompts(cfg, 4, 8, 16)
    gens = [8, 5, 7, 6]
    rids = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(prompts, gens)]
    res = eng.run()
    assert sorted(res) == sorted(rids)
    for rid, prompt, g in zip(rids, prompts, gens):
        ref = _static_greedy(lm, params, prompt, g, pcfg.max_len)
        assert res[rid].tokens == ref, (
            f"{arch} req {rid}: engine {res[rid].tokens} != static {ref}")
    s = eng.summary()
    assert s["state_bytes"] > 0
    if arch.startswith("rwkv6"):
        assert s["cache_bytes"] == 0        # pure-SSM: no KV pool at all


@pytest.mark.slow
def test_jamba_preemption_under_page_pressure_matches_static():
    """Hybrid: attn-page exhaustion preempts the youngest slot; its state
    is rebuilt by re-prefill and the resumed request still matches the
    static reference token-for-token."""
    cfg, lm, params = _setup("jamba-1.5-large")
    pcfg = PoolConfig(num_slots=3, page_size=4, pages_per_slot=10,
                      num_pages=12, quantized=False)
    eng = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
    prompts = _prompts(cfg, 3, 8, 10)
    rids = [eng.submit(p, max_new_tokens=14) for p in prompts]
    res = eng.run()
    assert eng.summary()["preemptions"] >= 1
    for rid, prompt in zip(rids, prompts):
        ref = _static_greedy(lm, params, prompt, 14, pcfg.max_len)
        assert res[rid].tokens == ref


def test_rwkv6_forced_preemption_resumes_token_identical():
    """Pure-SSM archs never exhaust pages (scheduler runs unpaged), so
    preemption is driven explicitly: evict mid-decode, the request
    re-queues with its generated prefix, reset-on-admit + re-prefill
    rebuild the state, and the final tokens still match the reference."""
    cfg, lm, params = _setup("rwkv6-1.6b")
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                      quantized=False)
    eng = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
    prompts = _prompts(cfg, 2, 8, 12, seed=7)
    rids = [eng.submit(p, max_new_tokens=10) for p in prompts]
    for _ in range(4):
        eng.step()
    assert eng.sched.preempt_youngest() is not None
    eng.metrics.preempted()
    res = eng.run()
    assert eng.summary()["preemptions"] == 1
    for rid, prompt in zip(rids, prompts):
        ref = _static_greedy(lm, params, prompt, 10, pcfg.max_len)
        assert res[rid].tokens == ref, (res[rid].tokens, ref)


# ---------------------------------------------------------------------------
# (b) chunked prefill carries state across chunks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,over", [
    ("rwkv6-1.6b", {}),
    # capacity-based MoE routing depends on the visible token count, so
    # chunk-size parity needs the dense-FFN variant (the same caveat holds
    # for attention MoE archs; see README fallback matrix)
    ("jamba-1.5-large", {"moe": MoEConfig(num_experts=0)}),
])
def test_ssm_chunked_prefill_matches_whole_prompt(arch, over):
    cfg, lm, params = _setup(arch, **over)
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=6,
                      quantized=False)
    prompt = _prompts(cfg, 1, 24, 24)[0]
    outs = []
    for chunk in (0, 8, 7):     # 7: ragged tail chunk, exact-length shapes
        eng = Engine(lm, params,
                     EngineConfig(pool=pcfg, prefill_chunk=chunk), PLAN)
        rid = eng.submit(prompt, max_new_tokens=6)
        outs.append(eng.run()[rid].tokens)
    assert outs[0] == outs[1] == outs[2], outs


# ---------------------------------------------------------------------------
# (c) quantized state cache: bytes + tolerance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["rwkv6-1.6b", "jamba-1.5-large"])
def test_quantized_state_bytes_and_first_token(arch):
    cfg, lm, params = _setup(arch)
    prompt = _prompts(cfg, 1, 16, 16)[0]
    res = {}
    for q in (False, True):
        pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                          quantized=q)
        eng = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
        rid = eng.submit(prompt, max_new_tokens=3)
        res[q] = (eng.run()[rid].tokens, eng.summary())
    # >= 3.5x state-byte reduction (int8 payload + tiny scale vectors)
    fp_b = res[False][1]["state_bytes"]
    q_b = res[True][1]["state_bytes"]
    assert fp_b / q_b >= 3.5, (fp_b, q_b)
    assert res[True][1]["state_reduction"] >= 3.5
    # first token comes from the (unquantized) prefill logits: always equal
    assert res[True][0][0] == res[False][0][0]


def test_quantized_state_within_pow2_tolerance():
    """Dequantized slot state after prefill is within half a grid step of
    the fp state elementwise (round-to-nearest on the pow-2 grid, clip
    allowed at the symmetric range edge)."""
    cfg, lm, params = _setup("rwkv6-1.6b")
    prompt = _prompts(cfg, 1, 16, 16)[0]
    pools = {}
    for q in (False, True):
        pcfg = PoolConfig(num_slots=1, page_size=8, pages_per_slot=4,
                          quantized=q)
        eng = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
        eng.submit(prompt, max_new_tokens=1)   # prefill + retire: state is
        eng.run()                              # the post-prompt snapshot
        pools[q] = (eng.spool, eng.scfg)
    from repro.numerics import qrange
    _, hi = qrange(8)
    for key in pools[False][0]["data"]:
        for name, fp_leaf in pools[False][0]["data"][key].items():
            fp = np.asarray(fp_leaf[:, 0], np.float32)          # (L, *feat)
            codes = pools[True][0]["data"][key][name][:, 0]
            sc = pools[True][0]["scale_log2"][key][name][:, 0]  # (L,)
            deq = np.asarray(SC.read_layer(codes, sc, jnp.float32,
                                           pools[True][1]))
            step = np.exp2(np.asarray(sc)).reshape(
                (-1,) + (1,) * (fp.ndim - 1))
            clipped = np.abs(fp) >= step * hi
            err = np.abs(deq - fp)
            assert (err <= step / 2 + 1e-6)[~clipped].all(), (
                key, name, float(err.max()))


def test_policy_ssm_state_site_owns_state_numerics():
    """EngineConfig.policy: the ssm_state site drives the state cache the
    way kv_cache drives the KV pool."""
    _, lm, params = _setup("rwkv6-1.6b")
    pol = NumericsPolicy(enable=True).with_spec(
        "ssm_state", QuantSpec("pow2", 4, 0, "int8", "per_tensor_max"))
    pcfg = PoolConfig(num_slots=1, page_size=8, pages_per_slot=2,
                      quantized=False)    # policy overrides the pool knob
    eng = Engine(lm, params, EngineConfig(pool=pcfg, policy=pol), PLAN)
    assert eng.scfg.quantized and eng.scfg.bits == 4
    assert eng.scfg.spec == pol.spec_for("ssm_state")


# ---------------------------------------------------------------------------
# (d) slot isolation walk (the state-cache analogue of tests/pool_walk.py)
# ---------------------------------------------------------------------------

def _mini_pool(num_slots, L=2, feat=(3,), quantized=False):
    scfg = SC.StateCacheConfig(quantized=quantized)
    pool = {"data": {"sub_0": {"h": jnp.zeros(
                (L, num_slots) + feat,
                jnp.int8 if quantized else jnp.float32)}},
            "scale_log2": {"sub_0": {"h": jnp.zeros((L, num_slots),
                                                    jnp.float32)}}}
    return pool, scfg


@pytest.mark.parametrize("quantized", [False, True])
def test_state_cache_slot_isolation_walk(quantized):
    """Random reset / per-slot write / batched write / snapshot / restore
    sequence: every slot always reads back exactly its own sentinel."""
    num_slots, L = 3, 2
    pool, scfg = _mini_pool(num_slots, L=L, quantized=quantized)
    rng = np.random.RandomState(0)
    expect = np.zeros((num_slots,), np.float32)      # sentinel per slot
    snaps: dict[int, tuple] = {}

    def check():
        for layer in range(L):
            got = np.asarray(SC.read_layer(
                pool["data"]["sub_0"]["h"][layer],
                pool["scale_log2"]["sub_0"]["h"][layer],
                jnp.float32, scfg))
            for s in range(num_slots):
                want = expect[s]
                # pow-2 8-bit grid represents 2^k exactly; sentinel values
                # are powers of two so quantized mode stays exact
                assert (got[s] == want).all(), (layer, s, got[s], want)

    for step in range(60):
        op = rng.choice(["reset", "write_slot", "write_batch",
                         "snapshot", "restore"])
        slot = int(rng.randint(num_slots))
        if op == "reset":
            pool = SC.reset_slot(pool, jnp.int32(slot))
            expect[slot] = 0.0
        elif op == "write_slot":
            val = float(2.0 ** rng.randint(-3, 4))
            for layer in range(L):
                d = pool["data"]["sub_0"]["h"]
                sc = pool["scale_log2"]["sub_0"]["h"]
                nd, ns = SC.write_slot(d[layer], sc[layer],
                                       jnp.full((3,), val), jnp.int32(slot),
                                       scfg)
                pool["data"]["sub_0"]["h"] = d.at[layer].set(nd)
                pool["scale_log2"]["sub_0"]["h"] = sc.at[layer].set(ns)
            expect[slot] = val
        elif op == "write_batch":
            active = rng.rand(num_slots) < 0.5
            vals = 2.0 ** rng.randint(-3, 4, num_slots).astype(np.float32)
            new = jnp.asarray(np.repeat(vals[:, None], 3, axis=1))
            for layer in range(L):
                d = pool["data"]["sub_0"]["h"]
                sc = pool["scale_log2"]["sub_0"]["h"]
                nd, ns = SC.write_layer(d[layer], sc[layer], new,
                                        jnp.asarray(active), scfg)
                pool["data"]["sub_0"]["h"] = d.at[layer].set(nd)
                pool["scale_log2"]["sub_0"]["h"] = sc.at[layer].set(ns)
            expect[active] = vals[active]
        elif op == "snapshot":
            snaps[slot] = (SC.snapshot_slot(pool, slot), expect[slot])
        elif op == "restore" and slot in snaps:
            snap, val = snaps[slot]
            pool = SC.restore_slot(pool, snap, jnp.int32(slot))
            expect[slot] = val
        check()


def test_state_pool_reset_on_admit_isolates_recycled_slots():
    """A slot recycled across requests starts from zero state: two engines
    — one fresh, one that already served a different request on the same
    slot — produce identical tokens for the same prompt."""
    cfg, lm, params = _setup("rwkv6-1.6b")
    pcfg = PoolConfig(num_slots=1, page_size=8, pages_per_slot=4,
                      quantized=False)
    prompts = _prompts(cfg, 2, 8, 12, seed=11)

    fresh = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
    rid = fresh.submit(prompts[1], max_new_tokens=6)
    want = fresh.run()[rid].tokens

    used = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
    used.submit(prompts[0], max_new_tokens=6)
    used.run()                                   # dirties slot 0's state
    rid2 = used.submit(prompts[1], max_new_tokens=6)
    assert used.run()[rid2].tokens == want

"""Data pipeline: determinism, resumability, sharding disjointness,
prefetcher liveness."""
import numpy as np

from repro.data import Prefetcher, fashion_like, lm_batch


def test_lm_batch_deterministic():
    a = lm_batch(3, batch=8, seq=32, vocab=100)
    b = lm_batch(3, batch=8, seq=32, vocab=100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_lm_batch_step_varies():
    a = lm_batch(3, batch=8, seq=32, vocab=100)
    b = lm_batch(4, batch=8, seq=32, vocab=100)
    assert (a["tokens"] != b["tokens"]).any()


def test_lm_batch_labels_are_next_tokens():
    a = lm_batch(0, batch=4, seq=16, vocab=50)
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])


def test_shards_disjoint_and_partition():
    full = [lm_batch(5, batch=8, seq=16, vocab=100, shard=s, num_shards=4)
            for s in range(4)]
    assert all(f["tokens"].shape[0] == 2 for f in full)
    stack = np.concatenate([f["tokens"] for f in full])
    assert stack.shape[0] == 8


def test_learnable_structure():
    """The markov mixing makes next-token partially predictable."""
    b = lm_batch(0, batch=64, seq=128, vocab=97)
    t = b["tokens"]
    a1, a2, c = 6364136223846793005, 1442695040888963407, 1013904223
    pred = (t[:, 1:-1].astype(np.int64) * a1
            + t[:, :-2].astype(np.int64) * a2 + c) % 97
    hit = (pred == t[:, 2:]).mean()
    assert hit > 0.3, hit


def test_fashion_like_shapes_and_classes():
    x, y = fashion_like(256, seed=0)
    assert x.shape == (256, 28 * 32)
    assert set(np.unique(y)) <= set(range(10))
    # padded columns are zero
    img = x.reshape(-1, 28, 32)
    assert np.abs(img[:, :, :2]).max() == 0


def test_prefetcher_orders_steps():
    fetched = []
    pf = Prefetcher(lambda s: {"step": s}, start_step=5, depth=2)
    for step, batch in pf:
        fetched.append(step)
        if len(fetched) >= 4:
            break
    pf.close()
    assert fetched == [5, 6, 7, 8]

"""repro.serve acceptance tests:

(a) continuous-batched fp32 decode of staggered requests is token-identical
    to the per-request static-batch reference,
(b) the int8 KV pool stays within the pow-2 quantization tolerance and cuts
    cache bytes >= 3.5x vs fp32,
(c) slots are recycled (N > num_slots requests complete), lazily-paged pools
    preempt and still finish every request.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as C
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import build_lm, init_lm, lm_decode_step
from repro.serve import (Engine, EngineConfig, PoolConfig, SamplingParams,
                         Scheduler, Request)
from repro.serve import kv_cache as KC
from repro.serve.sampling import sample_tokens
from repro.sharding import ShardPlan

PLAN = ShardPlan(mesh=None)


def _setup(arch="internlm2-1.8b"):
    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    return cfg, lm, params


def _prompts(cfg, n, lo, hi, seed=3):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size,
                        int(rng.randint(lo, hi + 1))).tolist()
            for _ in range(n)]


def _static_greedy(lm, params, prompt, gen_len, max_len):
    """Per-request reference: whole-prompt prefill + scalar-cur_len greedy
    decode on the non-paged cache path."""
    prefill = jax.jit(make_prefill_step(lm, PLAN))
    toks = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = prefill(params, {"tokens": toks})
    p = len(prompt)

    def pad_seq(a):
        if a.ndim >= 3 and a.shape[2] == p:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, max_len - p)
            return jnp.pad(a, pad)
        return a

    cache = jax.tree.map(pad_seq, cache)
    tok = int(jnp.argmax(logits[0, -1]))
    out = [tok]
    for j in range(gen_len - 1):
        lg, cache = lm_decode_step(params, cache,
                                   jnp.asarray([[tok]], jnp.int32),
                                   jnp.int32(p + j), lm, PLAN)
        tok = int(jnp.argmax(lg[0, -1]))
        out.append(tok)
    return out


# ---------------------------------------------------------------------------
# (a) fp32 continuous batching == static reference, token for token
# ---------------------------------------------------------------------------

@pytest.mark.slow
@pytest.mark.parametrize("arch", ["internlm2-1.8b", "deepseek-v2-236b"])
def test_continuous_batching_matches_static_decode(arch):
    cfg, lm, params = _setup(arch)
    page = 8
    pcfg = PoolConfig(num_slots=2, page_size=page, pages_per_slot=4,
                      quantized=False)
    eng = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
    # staggered: 4 requests on 2 slots with different prompt/gen lengths
    prompts = _prompts(cfg, 4, 8, 16)
    gens = [8, 5, 7, 6]
    rids = [eng.submit(p, max_new_tokens=g)
            for p, g in zip(prompts, gens)]
    res = eng.run()
    assert sorted(res) == sorted(rids)
    for rid, prompt, g in zip(rids, prompts, gens):
        ref = _static_greedy(lm, params, prompt, g, pcfg.max_len)
        assert res[rid].tokens == ref, (
            f"{arch} req {rid}: engine {res[rid].tokens} != static {ref}")


def test_chunked_prefill_matches_whole_prompt():
    cfg, lm, params = _setup()
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=6,
                      quantized=False)
    prompt = _prompts(cfg, 1, 24, 24)[0]
    outs = []
    for chunk in (0, 8):
        eng = Engine(lm, params,
                     EngineConfig(pool=pcfg, prefill_chunk=chunk), PLAN)
        rid = eng.submit(prompt, max_new_tokens=6)
        outs.append(eng.run()[rid].tokens)
    assert outs[0] == outs[1]


def test_vectorized_serve_step_matches_scalar():
    """Per-slot cur_len vector on the NON-paged path: two rows decoding at
    different positions match the per-request scalar steps."""
    cfg, lm, params = _setup()
    b, max_len = 2, 32
    lens = [7, 13]
    rng = np.random.RandomState(0)
    toks = rng.randint(0, cfg.vocab_size, (b, 1))
    step_v = jax.jit(make_serve_step(lm, PLAN))

    # build per-row caches from real prefills so the comparison is live data
    prefill = jax.jit(make_prefill_step(lm, PLAN))
    caches, scalar_logits = [], []
    for r in range(b):
        prompt = rng.randint(0, cfg.vocab_size, (1, lens[r]))
        _, cache = prefill(params, {"tokens": jnp.asarray(prompt)})

        def pad_seq(a, p=lens[r]):
            if a.ndim >= 3 and a.shape[2] == p:
                pad = [(0, 0)] * a.ndim
                pad[2] = (0, max_len - p)
                return jnp.pad(a, pad)
            return a

        cache = jax.tree.map(pad_seq, cache)
        lg, _ = lm_decode_step(params, cache,
                               jnp.asarray(toks[r:r + 1], jnp.int32),
                               jnp.int32(lens[r]), lm, PLAN)
        caches.append(cache)
        scalar_logits.append(np.asarray(lg[0]))
    batched_cache = jax.tree.map(
        lambda *xs: jnp.concatenate(xs, axis=1), *caches)
    lg_v, _ = step_v(params, batched_cache, jnp.asarray(toks, jnp.int32),
                     jnp.asarray(lens, jnp.int32))
    np.testing.assert_allclose(np.asarray(lg_v), np.stack(scalar_logits),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# (b) quantized pool: tolerance + bytes reduction
# ---------------------------------------------------------------------------

def test_quantized_pool_bytes_and_tolerance():
    cfg, lm, params = _setup()
    mk = lambda q: PoolConfig(num_slots=2, page_size=8, pages_per_slot=4,
                              quantized=q)
    engines = {q: Engine(lm, params, EngineConfig(pool=mk(q)), PLAN)
               for q in (False, True)}
    # >= 3.5x cache-byte reduction (int8 payload + tiny scale vectors)
    fp_bytes = engines[False].metrics.cache_bytes
    q_bytes = engines[True].metrics.cache_bytes
    assert fp_bytes / q_bytes >= 3.5, (fp_bytes, q_bytes)
    assert engines[True].summary()["cache_reduction"] >= 3.5

    # dequantized K/V within the pow-2 step tolerance of the fp values:
    # run the same prompt through both pools and compare slot 0's *prompt*
    # pages (decode pages may hold different greedy continuations)
    prompt = _prompts(cfg, 1, 16, 16)[0]
    for q, eng in engines.items():
        eng.submit(prompt, max_new_tokens=4)
        eng.run()
    npages = len(prompt) // 8          # fully-written prompt pages
    for key in engines[False].pool["data"]:
        for name in engines[False].pool["data"][key]:
            # slot 0 was admitted first -> owns the low page indices
            fp = np.asarray(
                engines[False].pool["data"][key][name][:, :npages])
            qd = engines[True].pool["data"][key][name][:, :npages]
            sc = engines[True].pool["scale_log2"][key][name][:, 0]
            deq = np.asarray(KC.dequantize(
                qd, sc[:, None, None], jnp.float32))
            step = np.exp2(np.asarray(sc))
            # |dequant - fp| <= step/2 elementwise (round-to-nearest grid),
            # allowing clip at the symmetric range edge
            err = np.abs(deq - fp)
            bound = (step / 2 + 1e-6).reshape(-1, 1, 1, *([1] * (fp.ndim - 3)))
            _, hi = KC.qrange(8)
            clipped = np.abs(fp) >= np.exp2(
                np.asarray(sc)).reshape(bound.shape) * hi
            assert (err <= bound)[~clipped].all(), (key, name, err.max())


def test_quantized_decode_close_to_fp32():
    """End-to-end: greedy tokens from the int8 pool agree with fp32 for the
    first steps (STE-style tolerance, not exactness)."""
    cfg, lm, params = _setup()
    prompt = _prompts(cfg, 1, 16, 16)[0]
    outs = {}
    for q in (False, True):
        pcfg = PoolConfig(num_slots=1, page_size=8, pages_per_slot=4,
                          quantized=q)
        eng = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
        rid = eng.submit(prompt, max_new_tokens=3)
        outs[q] = eng.run()[rid].tokens
    # first token comes from the (unquantized) prefill logits: always equal
    assert outs[True][0] == outs[False][0]


# ---------------------------------------------------------------------------
# (c) slot recycling / continuous admission
# ---------------------------------------------------------------------------

def test_slot_recycling_completes_more_requests_than_slots():
    cfg, lm, params = _setup()
    pcfg = PoolConfig(num_slots=2, page_size=8, pages_per_slot=3,
                      quantized=True)
    eng = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
    prompts = _prompts(cfg, 5, 6, 12)
    rids = [eng.submit(p, max_new_tokens=5) for p in prompts]
    res = eng.run()
    assert sorted(res) == sorted(rids)
    assert all(len(res[r].tokens) == 5 for r in rids)
    s = eng.summary()
    assert s["requests_completed"] == 5
    assert s["ttft_p95_s"] >= s["ttft_p50_s"] >= 0


def test_preemption_under_page_pressure():
    cfg, lm, params = _setup()
    # shared pool with fewer pages than slots*pages_per_slot forces eviction
    pcfg = PoolConfig(num_slots=3, page_size=4, pages_per_slot=10,
                      num_pages=12, quantized=False)
    eng = Engine(lm, params, EngineConfig(pool=pcfg), PLAN)
    rids = [eng.submit(p, max_new_tokens=14)
            for p in _prompts(cfg, 3, 8, 10)]
    res = eng.run()
    assert all(len(res[r].tokens) == 14 for r in rids)
    assert eng.summary()["preemptions"] >= 1


# ---------------------------------------------------------------------------
# unit: scheduler + sampling
# ---------------------------------------------------------------------------

def test_scheduler_page_accounting():
    pcfg = PoolConfig(num_slots=2, page_size=4, pages_per_slot=4)
    sched = Scheduler(pcfg)
    sched.submit(Request(prompt=[1] * 6, max_new_tokens=4))
    slot, st = sched.try_admit()
    assert sched.alloc.free_pages == pcfg.total_pages - 2  # 7 tokens -> 2 pages
    st.generated.append(1)
    st.last_token = 1
    while st.cur_len < 10:
        assert sched.ensure_page(slot)
        st.generated.append(1)
    sched.retire(slot)
    assert sched.alloc.free_pages == pcfg.total_pages
    assert (sched.page_table == pcfg.trash_page).all()


def test_pool_invariants_random_walks():
    """Deterministic seed sweep of the pool-isolation walker (the
    hypothesis property test in test_property.py drives the same walker
    with generated seeds; this keeps it exercised on bare environments)."""
    from pool_walk import run_pool_walk
    for seed in range(10):
        run_pool_walk(seed, steps=40)


def test_sampling_modes():
    key = jax.random.PRNGKey(0)
    logits = jnp.asarray(np.random.RandomState(0).randn(4, 50) * 3,
                         jnp.float32)
    # greedy rows (temp<=0) equal argmax regardless of other knobs
    toks = sample_tokens(logits, key,
                         jnp.zeros(4), jnp.zeros(4, jnp.int32), jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))
    # top_k=1 is argmax even at high temperature
    toks = sample_tokens(logits, key, jnp.full((4,), 5.0),
                         jnp.ones(4, jnp.int32), jnp.ones(4))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))
    # tiny top_p keeps only the head of the distribution
    toks = sample_tokens(logits, key, jnp.full((4,), 1.0),
                         jnp.zeros(4, jnp.int32), jnp.full((4,), 1e-6))
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))
    # samples stay in-vocab and per-slot streams differ from each other
    toks = sample_tokens(jnp.zeros((4, 50)), key, jnp.full((4,), 1.0),
                         jnp.zeros(4, jnp.int32), jnp.ones(4))
    assert ((np.asarray(toks) >= 0) & (np.asarray(toks) < 50)).all()

"""Optimizer: Adam math, int8 blockwise states, grad compression with error
feedback, schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import TrainConfig
from repro.optim import adam as A
from repro.optim import grad_compress as GC
from repro.optim.schedule import lr_at


def _params():
    k = jax.random.PRNGKey(0)
    return {"layer": {"w": jax.random.normal(k, (32, 16)),
                      "b": jnp.zeros((16,))},
            "lambda_0": jnp.ones((4,)),
            "wscale_log2": jnp.zeros((3,), jnp.int32)}


def test_adam_skips_lambda_and_int_leaves():
    p = _params()
    cfg = TrainConfig()
    st = A.init_adam(p, cfg)
    g = jax.tree.map(lambda a: jnp.ones_like(a)
                     if jnp.issubdtype(a.dtype, jnp.floating) else a, p)
    p2, st2 = A.adam_update(p, g, st, jnp.asarray(1e-2), cfg)
    np.testing.assert_allclose(p2["lambda_0"], p["lambda_0"])   # untouched
    np.testing.assert_allclose(p2["wscale_log2"], p["wscale_log2"])
    assert not np.allclose(p2["layer"]["w"], p["layer"]["w"])   # updated


def test_adam_descends_quadratic():
    p = {"w": jnp.asarray([5.0, -3.0])}
    cfg = TrainConfig(weight_decay=0.0)
    st = A.init_adam(p, cfg)
    lr = jnp.asarray(0.1)
    for _ in range(200):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        p, st = A.adam_update(p, g, st, lr, cfg)
    assert float(jnp.abs(p["w"]).max()) < 0.05


def test_int8_state_tracks_f32_state():
    p = {"w": jax.random.normal(jax.random.PRNGKey(1), (512,))}
    cfg32 = TrainConfig(weight_decay=0.0, opt_state_dtype="float32")
    cfg8 = TrainConfig(weight_decay=0.0, opt_state_dtype="int8")
    s32, s8 = A.init_adam(p, cfg32), A.init_adam(p, cfg8)
    p32, p8 = p, p
    lr = jnp.asarray(0.05)
    for i in range(30):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (512,))}
        p32, s32 = A.adam_update(p32, g, s32, lr, cfg32)
        p8, s8 = A.adam_update(p8, g, s8, lr, cfg8)
    # int8 states track f32 within a few percent of the travelled distance
    dist = float(jnp.linalg.norm(p32["w"] - p["w"]))
    err = float(jnp.linalg.norm(p32["w"] - p8["w"]))
    assert err < 0.15 * dist, (err, dist)


def test_q8_roundtrip():
    v = jax.random.normal(jax.random.PRNGKey(2), (1000,)) * 7
    st = A._q8_encode(v)
    back = A._q8_decode(st, v.shape, v.size)
    assert float(jnp.abs(back - v).max()) < 7 * 2 / 127


def test_grad_clip():
    g = {"a": jnp.full((100,), 10.0)}
    clipped, gn = A.clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(A.global_norm(clipped)), 1.0, rtol=1e-4)


def test_grad_compress_error_feedback_unbiased():
    """Error feedback: sum of compressed grads converges to sum of true
    grads (residual carries the quantization error)."""
    true_sum = jnp.zeros((256,))
    comp_sum = jnp.zeros((256,))
    res = None
    for i in range(50):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (256,))}
        cg, res = GC.compress_decompress(g, res)
        true_sum = true_sum + g["w"]
        comp_sum = comp_sum + cg["w"]
    # residual is bounded -> averages match closely
    diff = float(jnp.abs(true_sum - comp_sum).max())
    assert diff < 0.2, diff


def test_lr_schedule_shape():
    cfg = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100)
    assert float(lr_at(0, cfg)) < 0.2
    assert float(lr_at(10, cfg)) > 0.9
    assert float(lr_at(99, cfg)) < 0.2


def test_grad_compress_train_step_wired():
    """grad_compress=True threads the error-feedback residual through
    TrainState and still trains."""
    import jax
    from repro.configs.base import ModelConfig
    from repro.launch.steps import init_train_state, make_train_step
    from repro.sharding import ShardPlan

    from repro.models import build_lm, init_lm
    cfg = ModelConfig(name="t", num_layers=1, d_model=32, num_heads=2,
                      num_kv_heads=2, d_ff=64, vocab_size=64,
                      remat="none", dtype="float32")
    lm = build_lm(cfg)
    tcfg = TrainConfig(total_steps=5, warmup_steps=1, grad_compress=True)
    params = init_lm(jax.random.PRNGKey(0), lm)
    state = init_train_state(params, tcfg)
    assert state.residual is not None
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 64),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, 64)}
    step = jax.jit(make_train_step(lm, ShardPlan(mesh=None), tcfg))
    l0 = None
    for _ in range(5):
        state, m = step(state, batch)
        l0 = l0 or float(m["loss"])
    assert float(m["loss"]) < l0

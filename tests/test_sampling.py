"""Sampling-correctness suite for ``serve/sampling.py``.

(a) processed distributions vs a numpy oracle over a temperature x top-k x
    top-p grid (vLLM knob order: top-k truncates FIRST, the nucleus is
    computed over the renormalized survivors);
(b) the regression pins for the three bugs the speculative-decoding accept
    math would otherwise inherit: knob-order disagreement, ``top_p = 0``
    masking every logit, and greedy rows overflowing ``logits / 1e-6``;
(c) distributional checks: empirical frequencies of ``sample_tokens`` match
    the oracle distribution; per-slot fold-in makes a slot's draws
    independent of who shares the batch;
(d) ``spec_accept``: the emitted token of a k=1 speculative step is
    distributed exactly as a direct sample of the processed target
    distribution, for any draft distribution (Leviathan et al. 2023) — and
    greedy slots accept iff draft argmax == target argmax.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve.sampling import (processed_probs, sample_from_probs,
                                  sample_tokens, spec_accept)


# ---------------------------------------------------------------------------
# numpy oracle (independent reimplementation of the knob semantics)
# ---------------------------------------------------------------------------

def np_processed(logits, temp, top_k, top_p):
    """Processed sampling distribution of one row, in float64 numpy."""
    logits = np.asarray(logits, np.float64)
    v = logits.shape[-1]
    if temp <= 0.0:
        p = np.zeros(v)
        p[int(np.argmax(logits))] = 1.0
        return p
    scaled = logits / max(temp, 1e-6)
    order = np.argsort(-scaled, kind="stable")
    desc = scaled[order]
    keep_k = np.ones(v, bool) if top_k <= 0 else (np.arange(v) < top_k)
    desc_k = np.where(keep_k, desc, -np.inf)
    ex = np.exp(desc_k - desc_k.max())
    probs = ex / ex.sum()
    cum = np.cumsum(probs)
    keep = ((cum - probs) < top_p) & keep_k
    keep[0] = True
    cutoff = desc[keep].min()
    masked = np.where(scaled < cutoff, -np.inf, scaled)
    ex = np.exp(masked - masked.max())
    return ex / ex.sum()


GRID = [(0.0, 0, 1.0), (1.0, 0, 1.0), (0.7, 3, 1.0), (1.3, 0, 0.8),
        (0.9, 4, 0.6), (2.0, 2, 0.3), (0.5, 1, 1.0), (1.0, 0, 0.0)]


@pytest.mark.parametrize("temp,top_k,top_p", GRID)
def test_processed_probs_matches_numpy_oracle(temp, top_k, top_p):
    rng = np.random.RandomState(0)
    logits = rng.randn(6, 12).astype(np.float32) * 2.0
    got = np.asarray(processed_probs(
        jnp.asarray(logits),
        jnp.full((6,), temp, jnp.float32),
        jnp.full((6,), top_k, jnp.int32),
        jnp.full((6,), top_p, jnp.float32)))
    want = np.stack([np_processed(r, temp, top_k, top_p) for r in logits])
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_processed_probs_qblock_rank():
    """(B, S, V) logits: one request's knobs govern every position."""
    rng = np.random.RandomState(1)
    logits = rng.randn(3, 4, 10).astype(np.float32)
    temp = jnp.asarray([0.0, 0.8, 1.5], jnp.float32)
    k = jnp.asarray([0, 3, 0], jnp.int32)
    p = jnp.asarray([1.0, 0.7, 0.4], jnp.float32)
    got = np.asarray(processed_probs(jnp.asarray(logits), temp, k, p))
    assert got.shape == (3, 4, 10)
    for b in range(3):
        for s in range(4):
            want = np_processed(logits[b, s], float(temp[b]), int(k[b]),
                                float(p[b]))
            np.testing.assert_allclose(got[b, s], want, rtol=1e-5,
                                       atol=1e-6)


# ---------------------------------------------------------------------------
# regression pins
# ---------------------------------------------------------------------------

def test_topk_before_topp_order_pin():
    """A case where the knob orders disagree: probs ~ [.5, .2, .2, .1],
    top_k=2, top_p=0.6. Correct (vLLM) order renormalizes the top-2 to
    [.714, .286] and the nucleus keeps ONLY token 0 (token 1's prefix mass
    .714 >= .6). Nucleus-over-the-full-distribution would keep token 1 too
    (its full-dist prefix is .5 < .6)."""
    probs = np.asarray([0.5, 0.2, 0.2, 0.1])
    logits = jnp.asarray(np.log(probs), jnp.float32)[None]
    dist = np.asarray(processed_probs(
        logits, jnp.asarray([1.0], jnp.float32),
        jnp.asarray([2], jnp.int32), jnp.asarray([0.6], jnp.float32)))[0]
    assert dist[0] == pytest.approx(1.0)
    assert dist[1:].max() == 0.0
    # and the sampler only ever emits token 0
    toks = np.asarray(sample_tokens(
        jnp.broadcast_to(logits, (64, 4)), jax.random.PRNGKey(0),
        jnp.full((64,), 1.0), jnp.full((64,), 2, jnp.int32),
        jnp.full((64,), 0.6)))
    assert (toks == 0).all()


def test_top_p_zero_keeps_argmax():
    """top_p = 0 used to -inf-mask EVERY logit (empty nucleus -> categorical
    over all -inf). It must degenerate to greedy-within-temperature."""
    rng = np.random.RandomState(2)
    logits = rng.randn(5, 16).astype(np.float32)
    dist = np.asarray(processed_probs(
        jnp.asarray(logits), jnp.full((5,), 1.0),
        jnp.zeros((5,), jnp.int32), jnp.zeros((5,), jnp.float32)))
    assert np.isfinite(dist).all()
    np.testing.assert_array_equal(np.argmax(dist, -1), np.argmax(logits, -1))
    np.testing.assert_allclose(dist.max(-1), 1.0)
    toks = np.asarray(sample_tokens(
        jnp.asarray(logits), jax.random.PRNGKey(1), jnp.full((5,), 1.0),
        jnp.zeros((5,), jnp.int32), jnp.zeros((5,), jnp.float32)))
    np.testing.assert_array_equal(toks, np.argmax(logits, -1))


def test_top_p_just_above_top_prob_keeps_two():
    """top_p = p(top1) + eps keeps exactly the top two tokens (the second's
    prefix mass p(top1) < top_p, the third's is >= top_p)."""
    probs = np.asarray([0.6, 0.3, 0.08, 0.02])
    logits = jnp.asarray(np.log(probs), jnp.float32)[None]
    dist = np.asarray(processed_probs(
        logits, jnp.asarray([1.0], jnp.float32),
        jnp.zeros((1,), jnp.int32), jnp.asarray([0.61], jnp.float32)))[0]
    assert dist[0] > 0 and dist[1] > 0
    assert dist[2] == 0 and dist[3] == 0
    np.testing.assert_allclose(dist[0] / dist[1], 2.0, rtol=1e-5)


def test_greedy_rows_never_divide_by_temperature_floor():
    """Greedy rows used to compute ``logits / 1e-6`` before the argmax
    select — large logits overflowed to inf and poisoned the processed
    probabilities the speculative accept path reads."""
    logits = jnp.asarray([[3e5, -3e5, 1e5, 0.0]], jnp.float32)
    dist = np.asarray(processed_probs(
        logits, jnp.zeros((1,), jnp.float32), jnp.zeros((1,), jnp.int32),
        jnp.ones((1,), jnp.float32)))[0]
    assert np.isfinite(dist).all()
    np.testing.assert_array_equal(dist, [1.0, 0.0, 0.0, 0.0])
    tok = np.asarray(sample_tokens(
        logits, jax.random.PRNGKey(2), jnp.zeros((1,), jnp.float32),
        jnp.zeros((1,), jnp.int32), jnp.ones((1,), jnp.float32)))
    assert tok[0] == 0


# ---------------------------------------------------------------------------
# distributional checks
# ---------------------------------------------------------------------------

def _freqs(toks, v):
    return np.bincount(np.asarray(toks).ravel(), minlength=v) / toks.size


@pytest.mark.parametrize("temp,top_k,top_p",
                         [(1.0, 0, 1.0), (0.8, 3, 1.0), (1.2, 0, 0.7),
                          (0.9, 4, 0.5)])
def test_sample_tokens_frequencies_match_oracle(temp, top_k, top_p):
    """Empirical frequency of each token over N independent rows stays
    within 5 sigma of the oracle probability (binomial std)."""
    rng = np.random.RandomState(3)
    v, n = 8, 4000
    logits = rng.randn(v).astype(np.float32)
    want = np_processed(logits, temp, top_k, top_p)
    toks = sample_tokens(
        jnp.broadcast_to(jnp.asarray(logits), (n, v)),
        jax.random.PRNGKey(4), jnp.full((n,), temp),
        jnp.full((n,), top_k, jnp.int32), jnp.full((n,), top_p))
    got = _freqs(toks, v)
    sigma = np.sqrt(want * (1 - want) / n) + 1e-9
    assert (np.abs(got - want) < 5 * sigma + 1e-3).all(), (got, want)
    # support exactness: zero-probability tokens never appear
    assert got[want == 0].sum() == 0.0


def test_mixed_batch_fold_in_independence():
    """Slot i's draw depends only on (key, i, its own logits/knobs) — not
    on which other requests share the batch."""
    rng = np.random.RandomState(5)
    logits = jnp.asarray(rng.randn(4, 10), jnp.float32)
    temp = jnp.asarray([0.0, 1.0, 0.7, 1.3])
    k = jnp.asarray([0, 0, 3, 2], jnp.int32)
    p = jnp.asarray([1.0, 0.9, 1.0, 0.6])
    key = jax.random.PRNGKey(6)
    mixed = np.asarray(sample_tokens(logits, key, temp, k, p))
    # same slots, different batch-mates: rows 0..1 with rows 2..3 replaced
    other = jnp.asarray(rng.randn(4, 10), jnp.float32)
    logits2 = jnp.concatenate([logits[:2], other[2:]], 0)
    mixed2 = np.asarray(sample_tokens(
        logits2, key, temp.at[2:].set(0.0), k, p))
    np.testing.assert_array_equal(mixed[:2], mixed2[:2])


def test_sample_from_probs_onehot_is_deterministic():
    probs = jnp.asarray([[0.0, 1.0, 0.0], [1.0, 0.0, 0.0]], jnp.float32)
    toks = np.asarray(sample_from_probs(probs, jax.random.PRNGKey(7)))
    np.testing.assert_array_equal(toks, [1, 0])


# ---------------------------------------------------------------------------
# speculative verify/accept
# ---------------------------------------------------------------------------

def test_spec_accept_greedy_is_argmax_comparison():
    """Greedy rows: accept iff draft token == target argmax; on rejection
    the replacement IS the target argmax (one-hot residual)."""
    rng = np.random.RandomState(8)
    v, k = 12, 3
    tlogits = jnp.asarray(rng.randn(2, k + 1, v), jnp.float32)
    targmax = np.argmax(np.asarray(tlogits), -1)
    # slot 0 drafts the argmax path (all accept); slot 1 diverges at pos 1
    d0 = targmax[0, :k]
    d1 = targmax[1, :k].copy()
    d1[1] = (d1[1] + 1) % v
    dtoks = jnp.asarray(np.stack([d0, d1]), jnp.int32)
    dprobs = jnp.asarray(
        jax.nn.one_hot(dtoks, v, dtype=jnp.float32))        # greedy Q
    zeros = jnp.zeros((2,), jnp.float32)
    acc, nxt = spec_accept(tlogits, dprobs, dtoks, jax.random.PRNGKey(9),
                           zeros, jnp.zeros((2,), jnp.int32),
                           jnp.ones((2,), jnp.float32))
    acc, nxt = np.asarray(acc), np.asarray(nxt)
    assert acc[0] == k and nxt[0] == targmax[0, k]      # bonus token
    assert acc[1] == 1 and nxt[1] == targmax[1, 1]      # replacement


def test_spec_accept_emitted_token_distribution():
    """k=1 rejection sampling: the first emitted token (draft if accepted,
    else residual replacement) is distributed exactly as the processed
    target distribution — for a DIFFERENT draft distribution Q."""
    rng = np.random.RandomState(10)
    v, n = 6, 6000
    tlog = rng.randn(v).astype(np.float32)
    qlog = rng.randn(v).astype(np.float32)          # independent draft
    temp, top_k, top_p = 1.0, 0, 1.0
    want = np_processed(tlog, temp, top_k, top_p)
    qdist = np_processed(qlog, temp, top_k, top_p)

    tlogits = jnp.broadcast_to(jnp.asarray(tlog), (n, 2, v))
    qprobs = jnp.broadcast_to(jnp.asarray(qdist, jnp.float32)[None, None],
                              (n, 1, v))
    dtoks = sample_from_probs(
        jnp.broadcast_to(jnp.asarray(qdist, jnp.float32), (n, v)),
        jax.random.PRNGKey(11))[:, None]
    acc, nxt = spec_accept(tlogits, qprobs, dtoks, jax.random.PRNGKey(12),
                           jnp.full((n,), temp), jnp.zeros((n,), jnp.int32),
                           jnp.full((n,), top_p))
    emitted = np.where(np.asarray(acc) >= 1, np.asarray(dtoks)[:, 0],
                       np.asarray(nxt))
    got = _freqs(emitted, v)
    sigma = np.sqrt(want * (1 - want) / n) + 1e-9
    assert (np.abs(got - want) < 5 * sigma + 1e-3).all(), (got, want)


def test_spec_accept_respects_target_support():
    """With a truncating target (top_k=2) the emitted token can never fall
    outside the target's processed support, whatever the draft proposes."""
    rng = np.random.RandomState(13)
    v, n, k = 8, 2000, 2
    tlog = rng.randn(v).astype(np.float32)
    want = np_processed(tlog, 0.9, 2, 1.0)
    tlogits = jnp.broadcast_to(jnp.asarray(tlog), (n, k + 1, v))
    # uniform draft proposes everything, incl. out-of-support tokens
    qprobs = jnp.full((n, k, v), 1.0 / v, jnp.float32)
    dtoks = jnp.asarray(
        np.random.RandomState(14).randint(0, v, (n, k)), jnp.int32)
    acc, nxt = spec_accept(tlogits, qprobs, dtoks, jax.random.PRNGKey(15),
                           jnp.full((n,), 0.9), jnp.full((n,), 2, jnp.int32),
                           jnp.ones((n,), jnp.float32))
    acc, nxt, dt = np.asarray(acc), np.asarray(nxt), np.asarray(dtoks)
    emitted = [dt[i, :acc[i]].tolist() + [int(nxt[i])] for i in range(n)]
    support = set(np.nonzero(want)[0].tolist())
    assert all(t in support for row in emitted for t in row)

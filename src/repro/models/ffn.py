"""Dense (Swi)GLU FFN — the standard block for every dense arch in the zoo.
Each matmul is a weight *site* and can be TT-factorized per config."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import SiteDef, apply_site, init_site, make_site, silu


@dataclass(frozen=True)
class FFNDef:
    gate: SiteDef
    up: SiteDef
    down: SiteDef


def make_ffn(cfg: ModelConfig, d_ff: int | None = None) -> FFNDef:
    f = d_ff or cfg.d_ff
    return FFNDef(
        gate=make_site(cfg, "ffn", f, cfg.d_model),
        up=make_site(cfg, "ffn", f, cfg.d_model),
        down=make_site(cfg, "ffn", cfg.d_model, f),
    )


def init_ffn(key: jax.Array, d: FFNDef, cfg: ModelConfig) -> dict:
    kg, ku, kd = jax.random.split(key, 3)
    return {"gate": init_site(kg, d.gate, cfg), "up": init_site(ku, d.up, cfg),
            "down": init_site(kd, d.down, cfg)}


def ffn_forward(params: dict, x: jax.Array, d: FFNDef, cfg: ModelConfig) -> jax.Array:
    g = apply_site(params["gate"], x, d.gate, cfg)
    u = apply_site(params["up"], x, d.up, cfg)
    return apply_site(params["down"], silu(g) * u, d.down, cfg)

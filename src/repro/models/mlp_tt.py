"""The paper's exact experimental model (Appendix B): a two-layer tensorized
MLP for (Fashion)MNIST.

- input zero-padded 28×32 = 896, factorized (7,4,2,16)
- hidden 512, factorized (4,4,2,16); ReLU
- output 16 (10 classes + padding), factorized (1,16); layer-2 input (32,16)
- initial TT-rank 16 everywhere → 14,794 params incl. biases (paper: 1.48e4)
- rank-adaptive prior (Eq. 2) + closed-form λ update (Eq. 4)
- low-precision: 4-bit cores (fixed pow-2 scales), 8-bit activations/bias,
  16-bit gradients, dynamic scale manager (§3.3), BinaryConnect + STE (§3.2)

Five Table-1 configurations are reproduced by toggling (quantize, prior).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs.base import QuantConfig, TTConfig
from ..core import quant as Q
from ..core import rank_adapt as RA
from ..core import tt_layer as TL
from ..core.ttm import TTMSpec, make_spec

L1_J = (4, 4, 2, 16)       # hidden 512
L1_I = (7, 4, 2, 16)       # input 896
L2_J = (1, 16)             # output 16 (10 used)
L2_I = (32, 16)            # hidden 512
INIT_RANK = 16
NUM_CLASSES = 10


@dataclass(frozen=True)
class MLPDef:
    spec1: TTMSpec
    spec2: TTMSpec
    tt: TTConfig
    qc: QuantConfig


def make_mlp(prior: bool = True, quantize: bool = True) -> MLPDef:
    tt = TTConfig(enable=True, d=4, max_rank=INIT_RANK, rank_adapt=prior,
                  prune_threshold=1e-2)
    qc = QuantConfig(enable=quantize)
    spec1 = make_spec(512, 896, 4, INIT_RANK, j_dims=L1_J, i_dims=L1_I)
    spec2 = make_spec(16, 512, 2, INIT_RANK, j_dims=L2_J, i_dims=L2_I)
    return MLPDef(spec1, spec2, tt, qc)


def init_mlp(key: jax.Array, d: MLPDef) -> dict:
    k1, k2 = jax.random.split(key)
    p1, _ = TL.tt_linear_init(k1, 512, 896, d.tt, j_dims=L1_J, i_dims=L1_I)
    p2, _ = TL.tt_linear_init(k2, 16, 512, d.tt, j_dims=L2_J, i_dims=L2_I)
    return {
        "l1": p1, "l2": p2,
        # activation/gradient quant sites (paper §3.3: per-tensor scales)
        "q_in": Q.init_act_quant(),
        "q_h": Q.init_act_quant(),
        "q_out": Q.init_act_quant(),
    }


def mlp_forward(params: dict, x: jax.Array, d: MLPDef) -> jax.Array:
    """x: (B, 896) -> logits (B, 10)."""
    qc = d.qc
    if qc.enable:
        x = Q.quant_edge(x, params["q_in"], qc.act_bits, qc.grad_bits)
    h = TL.tt_linear_apply(params["l1"], x, d.spec1, d.tt, qc)
    h = jax.nn.relu(h)
    if qc.enable:
        h = Q.quant_edge(h, params["q_h"], qc.act_bits, qc.grad_bits)
    out = TL.tt_linear_apply(params["l2"], h, d.spec2, d.tt, qc)
    if qc.enable:
        out = Q.quant_edge(out, params["q_out"], qc.act_bits, qc.grad_bits)
    return out[:, :NUM_CLASSES]


def mlp_loss(params: dict, batch: dict, d: MLPDef) -> jax.Array:
    logits = mlp_forward(params, batch["x"], d)
    ce = -jnp.mean(jnp.sum(
        jax.nn.one_hot(batch["y"], NUM_CLASSES)
        * jax.nn.log_softmax(logits.astype(jnp.float32)), axis=-1))
    prior = jnp.zeros((), jnp.float32)
    if d.tt.rank_adapt:
        # Eq. (1): mean CE + g(θ, λ) scaled by 1/|D| (paper trains MAP over
        # the dataset; per-batch we scale the prior by 1/dataset_size).
        prior = (TL.tt_prior_loss(params["l1"], d.spec1, d.tt)
                 + TL.tt_prior_loss(params["l2"], d.spec2, d.tt)) / 60000.0
    return ce + prior


def mlp_lambda_update(params: dict, d: MLPDef) -> dict:
    new = dict(params)
    new["l1"] = TL.tt_lambda_update(params["l1"], d.spec1, d.tt)
    new["l2"] = TL.tt_lambda_update(params["l2"], d.spec2, d.tt)
    return new


def mlp_scale_update(params: dict, batch: dict, grads: dict, d: MLPDef) -> dict:
    """§3.3 scale-manager step: activation stats from the forward values,
    gradient stats from the probe cotangents."""
    if not d.qc.enable:
        return params
    qc = d.qc
    x = batch["x"]
    h = jax.nn.relu(TL.tt_linear_apply(params["l1"], x, d.spec1, d.tt, d.qc))
    out = TL.tt_linear_apply(params["l2"], h, d.spec2, d.tt, d.qc)
    new = dict(params)
    for name, val in (("q_in", x), ("q_h", h), ("q_out", out)):
        gstat = grads[name].probe if name in grads else None
        new[name] = Q.update_act_quant(
            params[name], val, gstat, qc.target_lo, qc.target_hi, qc.ema)
    return new


# ---------------------------------------------------------------------------
# Table-1 accounting (analytic)
# ---------------------------------------------------------------------------

def param_counts(d: MLPDef, eff1: list[int] | None = None,
                 eff2: list[int] | None = None) -> dict:
    """Parameters + memory bits for the 5 Table-1 rows."""
    r1 = list(d.spec1.ranks) if eff1 is None else [1] + eff1 + [1]
    r2 = list(d.spec2.ranks) if eff2 is None else [1] + eff2 + [1]

    def count(spec, ranks):
        return sum(ranks[n] * spec.j_dims[n] * spec.i_dims[n] * ranks[n + 1]
                   for n in range(spec.d))

    tt_params = count(d.spec1, r1) + count(d.spec2, r2)
    biases = 512 + NUM_CLASSES
    dense_params = 896 * 512 + 512 * 10 + biases
    return {
        "tt_params": tt_params + biases,
        "dense_params": dense_params,
        "float_bits": (tt_params + biases) * 32,
        "fixed_bits": tt_params * 4 + biases * 8,
        "dense_bits": dense_params * 32,
    }


def effective_ranks(params: dict, d: MLPDef) -> tuple[list[int], list[int]]:
    th = d.tt.prune_threshold
    l1 = [params["l1"][f"lambda_{n}"] for n in range(d.spec1.d - 1)]
    l2 = [params["l2"][f"lambda_{n}"] for n in range(d.spec2.d - 1)]
    return (RA.effective_ranks(l1, th), RA.effective_ranks(l2, th))

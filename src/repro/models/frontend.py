"""Modality frontends — STUBS per the assignment: ``input_specs()`` provides
precomputed frame/patch embeddings; these helpers only document shapes and
create synthetic embeddings for smoke tests."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig


def audio_frames_spec(cfg: ModelConfig, batch: int, seq: int, dtype):
    """HuBERT-style CNN feature extractor output: (B, S, d_model)."""
    return jax.ShapeDtypeStruct((batch, seq, cfg.d_model), dtype)


def vision_patches_spec(cfg: ModelConfig, batch: int, n_patches: int, dtype):
    """LLaVA-NeXT anyres tiling output after the projector: (B, P, d_model)."""
    return jax.ShapeDtypeStruct((batch, n_patches, cfg.d_model), dtype)


def synth_audio_frames(key, cfg: ModelConfig, batch: int, seq: int, dtype):
    return (jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32)
            ).astype(dtype)


def synth_vision_patches(key, cfg: ModelConfig, batch: int, n: int, dtype):
    return (jax.random.normal(key, (batch, n, cfg.d_model), jnp.float32)
            ).astype(dtype)

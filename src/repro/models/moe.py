"""Mixture-of-Experts FFN with expert parallelism.

Routing: top-k softmax (optionally normalized over selected), capacity-based
token dropping (GShard semantics), switch-style load-balance aux loss.

Distribution: experts are sharded over the ``model`` mesh axis. The baseline
dispatch runs under ``shard_map``: tokens are data-sharded and replicated
across the model axis; each model shard gathers (top-C per local expert) only
the tokens routed to ITS experts, runs the expert GLU, scatter-adds into a
local output, and a single ``psum`` over the model axis combines. Collective
volume per MoE layer = one psum of the (tokens × d_model) activation — the
§Perf hillclimb replaces this with an index-based exchange (see
EXPERIMENTS.md).

Single-device (smoke-test) path: same math without shard_map.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from .common import SiteDef, apply_site, init_site, make_site, silu


@dataclass(frozen=True)
class MoEDef:
    router: SiteDef
    gate: SiteDef           # per-expert, stacked on axis 0
    up: SiteDef
    down: SiteDef
    shared: "FFNLike | None"
    num_experts: int
    top_k: int
    capacity_factor: float
    d_ff: int


@dataclass(frozen=True)
class FFNLike:
    gate: SiteDef
    up: SiteDef
    down: SiteDef


def make_moe(cfg: ModelConfig, d_ff: int | None = None) -> MoEDef:
    f = d_ff or cfg.d_ff
    m = cfg.moe
    shared = None
    if m.num_shared > 0:
        fs = f * m.num_shared
        shared = FFNLike(
            gate=make_site(cfg, "ffn", fs, cfg.d_model),
            up=make_site(cfg, "ffn", fs, cfg.d_model),
            down=make_site(cfg, "ffn", cfg.d_model, fs))
    return MoEDef(
        router=make_site(cfg, "ffn", m.num_experts, cfg.d_model),
        gate=make_site(cfg, "expert", f, cfg.d_model),
        up=make_site(cfg, "expert", f, cfg.d_model),
        down=make_site(cfg, "expert", cfg.d_model, f),
        shared=shared, num_experts=m.num_experts, top_k=m.top_k,
        capacity_factor=m.capacity_factor, d_ff=f)


def init_moe(key: jax.Array, d: MoEDef, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    e = d.num_experts

    def stack_init(k, site):
        return jax.vmap(lambda kk: init_site(kk, site, cfg))(
            jax.random.split(k, e))

    p = {
        "router": init_site(ks[0], d.router, cfg),
        "gate": stack_init(ks[1], d.gate),
        "up": stack_init(ks[2], d.up),
        "down": stack_init(ks[3], d.down),
    }
    if d.shared is not None:
        p["shared"] = {
            "gate": init_site(ks[4], d.shared.gate, cfg),
            "up": init_site(ks[5], d.shared.up, cfg),
            "down": init_site(ks[6], d.shared.down, cfg),
        }
    return p


def _route(params, x2d, d: MoEDef, cfg: ModelConfig, mask=None):
    """x2d: (T, D) -> (topk_idx (T,k), topk_w (T,k), aux_loss).

    ``mask``: optional (T,) bool of *real* tokens. Masked tokens (inactive
    serve slots, prefill padding) get zero combine weight — so they never
    win a capacity slot against a real token in ``_dispatch_local``'s
    top-C selection — and are excluded from the load-balance statistics.
    """
    logits = apply_site(params["router"], x2d.astype(jnp.float32),
                        d.router, cfg).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topk_w, topk_idx = jax.lax.top_k(probs, d.top_k)
    topk_w = topk_w / jnp.maximum(jnp.sum(topk_w, axis=-1, keepdims=True), 1e-9)
    # switch aux loss: E * sum_e f_e * p_e
    e = d.num_experts
    dispatch = jax.nn.one_hot(topk_idx[:, 0], e)     # count top-1 for f_e
    if mask is not None:
        mf = mask.astype(jnp.float32)[:, None]
        topk_w = topk_w * mf
        n = jnp.maximum(jnp.sum(mf), 1.0)
        f_e = jnp.sum(dispatch * mf, axis=0) / n
        p_e = jnp.sum(probs * mf, axis=0) / n
    else:
        f_e = jnp.mean(dispatch, axis=0)
        p_e = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(f_e * p_e)
    return topk_idx, topk_w.astype(x2d.dtype), aux


def _shard_map(f, mesh, in_specs, out_specs):
    """Version-compat shard_map (shared shim: see sharding.py)."""
    from ..sharding import compat_shard_map
    return compat_shard_map(f, mesh, in_specs, out_specs)


def _expert_glu(eparams, xe, d: MoEDef, cfg: ModelConfig):
    """xe: (E_loc, C, D) through per-expert GLU; eparams leaves stacked (E_loc, ...)."""
    def one(ep, xi):
        g = apply_site(ep["gate"], xi, d.gate, cfg)
        u = apply_site(ep["up"], xi, d.up, cfg)
        return apply_site(ep["down"], silu(g) * u, d.down, cfg)

    return jax.vmap(one)(eparams, xe)


def _dispatch_local(x2d, topk_idx, topk_w, eparams, d: MoEDef, cfg: ModelConfig,
                    e_start: jax.Array, e_local: int, capacity: int):
    """Gather top-C tokens for each of ``e_local`` experts starting at
    ``e_start``, run the expert GLU, scatter-add back. Pure function of
    local data — used both single-device and inside shard_map."""
    t = x2d.shape[0]
    # score of each token for each local expert (0 if not routed)
    eids = e_start + jnp.arange(e_local)                      # (E_loc,)
    # (T, k) routed-to-expert match -> weight, else 0
    match = (topk_idx[None, :, :] == eids[:, None, None])     # (E_loc, T, k)
    w_tok = jnp.sum(jnp.where(match, topk_w[None].astype(jnp.float32), 0.0),
                    axis=-1)                                  # (E_loc, T)
    # top-C tokens per expert (capacity dropping; ties broken by token order)
    cw, cidx = jax.lax.top_k(w_tok, capacity)                 # (E_loc, C)
    valid = cw > 0.0
    xe = x2d[cidx.reshape(-1)].reshape(e_local, capacity, -1) # (E_loc, C, D)
    ye = _expert_glu(eparams, xe, d, cfg)                     # (E_loc, C, D)
    ye = ye * (cw * valid)[..., None].astype(ye.dtype)
    out = jnp.zeros_like(x2d)
    out = out.at[cidx.reshape(-1)].add(
        ye.reshape(-1, ye.shape[-1]), mode="drop")
    return out


def moe_forward(params: dict, x: jax.Array, d: MoEDef, cfg: ModelConfig, *,
                mesh=None, dp_axes=("data",), ep_axis: str = "model",
                token_mask: jax.Array | None = None,
                capacity_tokens: int | None = None
                ) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out, aux_loss).

    If ``mesh`` has a >1-sized ``ep_axis``, runs the shard_map EP path;
    otherwise the single-shard path (same math, e_start=0, all experts local).

    ``token_mask``: optional (B, S) bool of real tokens; masked tokens
    (inactive serve slots, chunked-prefill padding) are dropped from the
    router so they cannot consume expert capacity (see ``_route``).

    ``capacity_tokens``: optional static token-count basis for expert
    capacity (serve chunked-prefill parity — see ``_capacity``). On the EP
    path it is the *global* basis applied per shard unscaled; the clamp to
    local tokens still bounds ``top_k``'s k.
    """
    b, s, dm = x.shape
    x2d = x.reshape(b * s, dm)
    mask = None if token_mask is None else token_mask.reshape(b * s)
    topk_idx, topk_w, aux = _route(params, x2d, d, cfg, mask)
    eparams = {"gate": params["gate"], "up": params["up"], "down": params["down"]}

    ep = 1
    if mesh is not None and ep_axis in mesh.shape:
        ep = mesh.shape[ep_axis]

    if ep == 1:
        cap = _capacity(b * s, d, capacity_tokens)
        out = _dispatch_local(x2d, topk_idx, topk_w, eparams, d, cfg,
                              jnp.int32(0), d.num_experts, cap)
    else:
        e_local = d.num_experts // ep
        # tokens per shard: the token block shards over dp_axes on whichever
        # of (batch, seq) divides (decode steps with batch < dp replicate);
        # each model shard sees its full local token block and only its
        # e_local experts — capacity is per (data-shard, expert).
        dp = 1
        for ax in dp_axes:
            dp *= mesh.shape.get(ax, 1)
        if b % dp == 0 and b >= dp:
            tok_spec = P(dp_axes, None, None)
            t_loc = (b // dp) * s
        elif s % dp == 0 and s >= dp:
            tok_spec = P(None, dp_axes, None)
            t_loc = b * (s // dp)
        else:
            tok_spec = P(None, None, None)
            t_loc = b * s
        cap = _capacity(t_loc, d, capacity_tokens)

        # combine: reduce-scatter the partial expert outputs along the seq
        # dim straight into the sequence-parallel layout (half the wire
        # bytes of an all-reduce, and the result already matches
        # plan.hidden's seq-sharding) — in bf16, not the f32 the
        # combine-weights produced.
        s_loc = x.shape[1]
        use_scatter = s_loc % ep == 0 and s_loc >= ep
        out_spec = tok_spec
        if use_scatter:
            out_spec = P(tok_spec[0], ep_axis, None) if tok_spec[1] is None \
                else tok_spec  # seq already sharded by dp: keep psum

        def shard_fn(x_loc, ti_loc, tw_loc, ep_loc):
            rank = jax.lax.axis_index(ep_axis)
            out_loc = _dispatch_local(
                x_loc.reshape(-1, dm), ti_loc.reshape(-1, d.top_k),
                tw_loc.reshape(-1, d.top_k), ep_loc, d, cfg,
                rank * e_local, e_local, cap)
            out_loc = out_loc.astype(x_loc.dtype).reshape(x_loc.shape)
            if use_scatter and out_spec is not tok_spec:
                return jax.lax.psum_scatter(out_loc, ep_axis,
                                            scatter_dimension=1, tiled=True)
            return jax.lax.psum(out_loc, ep_axis)

        out = _shard_map(
            shard_fn, mesh,
            (tok_spec, tok_spec, tok_spec,
             jax.tree.map(lambda _: P(ep_axis), eparams)),
            out_spec,
        )(x, topk_idx.reshape(b, s, d.top_k),
          topk_w.reshape(b, s, d.top_k), eparams)
        out = out.reshape(b * s, dm)

    out = out.reshape(b, s, dm)
    if d.shared is not None:
        sh = params["shared"]
        g = apply_site(sh["gate"], x, d.shared.gate, cfg)
        u = apply_site(sh["up"], x, d.shared.up, cfg)
        out = out + apply_site(sh["down"], silu(g) * u, d.shared.down, cfg)
    return out, aux


def _capacity(tokens_per_shard: int, d: MoEDef,
              capacity_tokens: int | None = None) -> int:
    """Per-expert capacity: cf * tokens * k / E, rounded up to 8, clamped to
    the local token count (decode steps have very few tokens).

    ``capacity_tokens`` overrides the token basis without changing the
    clamp — the serve engine's chunked-prefill capacity parity: capacity
    derives from the FULL prompt length, so a chunk never spuriously drops
    a token that whole-prompt routing would have kept (the clamp keeps
    ``top_k``'s k <= the visible token count; whenever the full-prompt
    capacity covers the chunk, per-chunk routing keeps everything, exactly
    like an un-capacity-bound whole-prompt pass)."""
    basis = capacity_tokens if capacity_tokens is not None else \
        tokens_per_shard
    cap = int(d.capacity_factor * basis * d.top_k / d.num_experts)
    cap = max(8, cap)
    cap = (cap + 7) // 8 * 8
    return min(cap, tokens_per_shard)

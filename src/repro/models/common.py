"""Shared model building blocks.

The central abstraction is the *weight site*: every matmul in every model in
the zoo goes through ``SiteDef`` + ``init_site`` + ``apply_site``, which
switch between a dense matrix and the paper's TT-factorized, rank-adaptive,
optionally-quantized layer purely by config (``TTConfig.apply_to``).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, QuantConfig, TTConfig
from ..core import quant as Q
from ..core import tt_layer as TL
from ..core.ttm import TTMSpec

@dataclass(frozen=True)
class SiteDef:
    """Static description of one weight site."""
    family: str              # one of configs.base.TT_SITES
    out_dim: int
    in_dim: int
    use_tt: bool
    spec: TTMSpec | None     # set when use_tt
    use_bias: bool = False


def make_site(cfg: ModelConfig, family: str, out_dim: int, in_dim: int,
              use_bias: bool = False) -> SiteDef:
    tt = cfg.tt
    use = (tt.enable and family in tt.apply_to
           and out_dim * in_dim >= tt.min_elements)
    spec = None
    if use:
        from ..core.ttm import make_spec
        spec = make_spec(out_dim, in_dim, tt.d, tt.max_rank)
    return SiteDef(family, out_dim, in_dim, use, spec, use_bias)


def init_site(key: jax.Array, site: SiteDef, cfg: ModelConfig) -> dict:
    dtype = jnp.dtype(cfg.dtype)
    if site.use_tt:
        params, _ = TL.tt_linear_init(
            key, site.out_dim, site.in_dim, cfg.tt, dtype=dtype,
            use_bias=site.use_bias,
            j_dims=site.spec.j_dims, i_dims=site.spec.i_dims,
            ranks=site.spec.ranks)
        return params
    sigma = (2.0 / (site.in_dim + site.out_dim)) ** 0.5
    p = {"w": (jax.random.normal(key, (site.in_dim, site.out_dim), jnp.float32)
               * sigma).astype(dtype)}
    if site.use_bias:
        p["b"] = jnp.zeros((site.out_dim,), dtype)
    return p


def apply_site(params: dict, x: jax.Array, site: SiteDef,
               cfg: ModelConfig) -> jax.Array:
    if site.use_tt:
        return TL.tt_linear_apply(params, x, site.spec, cfg.tt, cfg.quant)
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def site_prior_loss(params: dict, site: SiteDef, cfg: ModelConfig) -> jax.Array:
    """Rank-shrinkage prior g(θ,λ) for this site (0 for dense sites).

    Handles stacked (vmapped-over-layer) params: leading extra axes on the
    cores are folded into the Frobenius norms, which is exactly the sum of
    per-layer priors.
    """
    if not site.use_tt:
        return jnp.zeros((), jnp.float32)
    spec = site.spec
    if spec.d < 2 or not cfg.tt.rank_adapt:
        return jnp.zeros((), jnp.float32)
    from ..core.rank_adapt import LAMBDA_FLOOR, PRIOR_REL_FLOOR
    total = jnp.zeros((), jnp.float32)
    for n in range(spec.d - 1):
        core = params[f"core_{n}"].astype(jnp.float32)
        lam = jax.lax.stop_gradient(params[f"lambda_{n}"]).astype(jnp.float32)
        # fold any stacked leading axes into the slice norms
        core4 = core.reshape((-1,) + core.shape[-4:][-4:]) if core.ndim > 4 else core[None]
        lam2 = lam.reshape((-1, lam.shape[-1])) if lam.ndim > 1 else lam[None]
        # dead-slice pull saturates at the per-layer relative floor (see
        # core/rank_adapt.py::_prior_floor: an absolute floor alone lets
        # 2·G/λ blow past the SGD stability limit and revive pruned slices)
        lam2 = jnp.maximum(lam2, jnp.maximum(
            PRIOR_REL_FLOOR * jnp.max(lam2, axis=-1, keepdims=True),
            LAMBDA_FLOOR))
        sq = jnp.sum(jnp.square(core4), axis=(1, 2, 3))        # (stack, R_n)
        c = 0.5 * (1 + spec.ranks[n] * spec.i_dims[n] * spec.j_dims[n])
        total = total + jnp.sum(sq / lam2 + c * jnp.log(lam2))
    return cfg.tt.gamma * total


def site_lambda_update(params: dict, site: SiteDef, cfg: ModelConfig) -> dict:
    """Closed-form Eq.(4) λ update; supports stacked params."""
    if not site.use_tt or site.spec.d < 2 or not cfg.tt.rank_adapt:
        return params
    spec = site.spec
    new = dict(params)
    for n in range(spec.d - 1):
        core = params[f"core_{n}"].astype(jnp.float32)
        axes = tuple(range(core.ndim - 4, core.ndim - 1))  # (R,J,I) of the last 4
        sq = jnp.sum(jnp.square(core), axis=axes)          # (stack..., R_n)
        gs = 1 + spec.ranks[n] * spec.i_dims[n] * spec.j_dims[n]
        from ..core.rank_adapt import LAMBDA_FLOOR
        new[f"lambda_{n}"] = jnp.maximum(2.0 / gs * sq, LAMBDA_FLOOR).astype(
            params[f"lambda_{n}"].dtype)
    return new


# ---------------------------------------------------------------------------
# Norms / rotary / activations
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, D); positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) / half
                    * jnp.log(theta))
    ang = positions.astype(jnp.float32)[..., None] * freqs      # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


def quant_edge_maybe(x: jax.Array, qparams: dict | None, name: str,
                     cfg: ModelConfig) -> jax.Array:
    """Insert an (act_bits fwd, grad_bits bwd) quant point if QAT is on."""
    if not cfg.quant.enable or qparams is None or name not in qparams:
        return x
    site = Q.ActQuant(*[qparams[name][k] for k in ("act", "grad", "probe")]) \
        if isinstance(qparams[name], dict) else qparams[name]
    return Q.quant_edge(x, site, cfg.quant.act_bits, cfg.quant.grad_bits)

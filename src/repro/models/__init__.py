"""Model zoo: unified LM covering dense / GQA / MLA / MoE / Mamba / RWKV6 /
hybrid archs, with every weight site TT-factorizable (the paper's technique
as a first-class layer type)."""
from . import attention, common, ffn, frontend, lm, moe, ssm  # noqa: F401
from .lm import (LMDef, build_lm, init_lm, lm_decode_step, lm_forward,
                 lm_init_cache, lm_lambda_update, lm_param_counts,
                 lm_prior_loss)  # noqa: F401

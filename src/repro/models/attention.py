"""Attention: GQA/MQA/MHA (chunked online-softmax), DeepSeek-V2 MLA
(naive prefill + absorbed decode), and decode-with-cache paths.

Memory discipline: full (S×T) score matrices are never materialized for long
sequences — ``chunked_attention`` runs an online-softmax scan over KV chunks
inside a scan over Q chunks (flash-attention dataflow in pure JAX; XLA maps
the inner matmuls to the MXU).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import MLAConfig, ModelConfig
from . import common as C
from .common import SiteDef, apply_site, init_site, make_site, rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Core chunked attention
# ---------------------------------------------------------------------------

def _attn_one_qchunk(q, k, v, qpos, kpos, *, causal: bool, scale: float,
                     kv_chunk: int, plan=None):
    """Online softmax over KV chunks for one Q chunk.

    q: (B, Sq, Hq, D)   k/v: (B, T, Hkv, D)   qpos: (Sq,)  kpos: (T,)
    returns (B, Sq, Hq, D)

    KV heads are expanded to the full Hq inside the chunk loop so every
    einsum carries the full head dim — under TP the scores/probs buffers
    then shard over ``model`` on heads (GQA's folded (hkv, g) layout blocks
    that and replicates the O(S·ck) buffers on every shard — measured 5×
    memory-term regression; see EXPERIMENTS.md §Perf).
    """
    b, sq, hq, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    nchunks = t // kv_chunk

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc, kp = inputs                     # (B, ck, Hkv, D), (ck,)
        if g > 1:
            kc = jnp.repeat(kc, g, axis=2)      # (B, ck, Hq, D)
            vc = jnp.repeat(vc, g, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((sq, kv_chunk), bool)
        if causal:
            mask = qpos[:, None] >= kp[None, :]
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p.astype(vc.dtype), vc,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    a0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    cons = _chunk_constraint(plan, hq)
    ks = cons(k.reshape(b, nchunks, kv_chunk, hkv, d).swapaxes(0, 1))
    vs = cons(v.reshape(b, nchunks, kv_chunk, hkv, d).swapaxes(0, 1))
    kps = kpos.reshape(nchunks, kv_chunk)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (ks, vs, kps))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def _chunk_constraint(plan, hq: int):
    """Sharding constraint for chunk-stacked attention tensors
    (chunks, B, len, H, D). Without this the reshape+swapaxes around the
    online-softmax scans breaks head-sharding propagation and GSPMD
    replicates Q/K/V on every model shard (measured: 3.2 GB per-layer
    all-gathers on deepseek-v2 — EXPERIMENTS.md §Perf)."""
    if plan is None or plan.mesh is None:
        return lambda x: x
    from jax.sharding import PartitionSpec as P

    def f(x):
        dims = [None] * x.ndim
        dims[1] = plan.dp_axes
        # guard via the plan (dp-only meshes have no "model" axis at all)
        if plan.strategy == "tp" and plan.model_size() > 1 \
                and x.shape[3] % plan.model_size() == 0:
            dims[3] = "model"
        return plan.constrain(x, P(*dims))

    return f


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool, q_offset: jax.Array | int = 0,
                      q_chunk: int = 512, kv_chunk: int = 1024,
                      plan=None) -> jax.Array:
    """General attention. q: (B,S,Hq,D); k,v: (B,T,Hkv,D)."""
    b, s, hq, d = q.shape
    t = k.shape[1]
    scale = 1.0 / math.sqrt(d)
    q_chunk = min(q_chunk, s)
    kv_chunk = min(kv_chunk, t)
    # pad T to a multiple of kv_chunk (mask handles the tail via kpos >= t)
    t_pad = (-t) % kv_chunk
    if t_pad:
        k = jnp.pad(k, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, t_pad), (0, 0), (0, 0)))
    kpos = jnp.arange(t + t_pad)
    kpos = jnp.where(kpos < t, kpos, jnp.iinfo(jnp.int32).max)  # mask padding
    if s == q_chunk:
        qpos = q_offset + jnp.arange(s)
        return _attn_one_qchunk(q, k, v, qpos, kpos, causal=causal,
                                scale=scale, kv_chunk=kv_chunk, plan=plan)
    assert s % q_chunk == 0, (s, q_chunk)
    nq = s // q_chunk
    cons = _chunk_constraint(plan, hq)

    # Nested remat: without this, differentiating the scan-of-scans saves
    # every (q-chunk × kv-chunk) probability matrix — an O(S²/chunk²) stack
    # that dominated HBM traffic (1 TB/device/layer-loop on deepseek-v2;
    # EXPERIMENTS.md §Perf iteration 4). Recompute p per chunk instead
    # (flash-attention backward dataflow).
    @partial(jax.checkpoint,
             policy=jax.checkpoint_policies.nothing_saveable)
    def qbody(_, qc_and_idx):
        qc, i = qc_and_idx
        qpos = q_offset + i * q_chunk + jnp.arange(q_chunk)
        out = _attn_one_qchunk(qc, k, v, qpos, kpos, causal=causal,
                               scale=scale, kv_chunk=kv_chunk, plan=plan)
        return None, out

    qs = cons(q.reshape(b, nq, q_chunk, hq, d).swapaxes(0, 1))
    _, outs = jax.lax.scan(qbody, None, (qs, jnp.arange(nq)))
    return cons(outs).swapaxes(0, 1).reshape(b, s, hq, d)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class GQADef:
    q: SiteDef
    kv: SiteDef
    o: SiteDef
    num_heads: int          # padded head count used in the attention kernel
    num_kv_heads: int
    head_dim: int
    real_heads: int         # the arch's true head count (= num_heads unless
                            # padded for TP divisibility; pad rows are
                            # zero-init, their outputs are sliced before o,
                            # so their grads are exactly zero — arch-faithful)


def make_gqa(cfg: ModelConfig) -> GQADef:
    hd = cfg.resolved_head_dim
    hq = cfg.num_heads
    pad_to = getattr(cfg, "pad_heads_to", 0)
    hp = max(hq, pad_to) if pad_to else hq
    return GQADef(
        q=make_site(cfg, "attn_qkv", hp * hd, cfg.d_model),
        kv=make_site(cfg, "attn_qkv", 2 * cfg.num_kv_heads * hd, cfg.d_model),
        o=make_site(cfg, "attn_o", cfg.d_model, hq * hd),
        num_heads=hp, num_kv_heads=cfg.num_kv_heads, head_dim=hd,
        real_heads=hq)


def init_gqa(key: jax.Array, d: GQADef, cfg: ModelConfig) -> dict:
    kq, kkv, ko = jax.random.split(key, 3)
    return {"q": init_site(kq, d.q, cfg), "kv": init_site(kkv, d.kv, cfg),
            "o": init_site(ko, d.o, cfg)}


def gqa_qkv(params: dict, x: jax.Array, d: GQADef, cfg: ModelConfig,
            positions: jax.Array):
    b, s, _ = x.shape
    q = apply_site(params["q"], x, d.q, cfg).reshape(b, s, d.num_heads, d.head_dim)
    kv = apply_site(params["kv"], x, d.kv, cfg).reshape(
        b, s, 2, d.num_kv_heads, d.head_dim)
    k, v = kv[:, :, 0], kv[:, :, 1]
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_forward(params: dict, x: jax.Array, d: GQADef, cfg: ModelConfig,
                *, causal: bool, positions: jax.Array, plan=None) -> jax.Array:
    q, k, v = gqa_qkv(params, x, d, cfg, positions)
    out = chunked_attention(q, k, v, causal=causal, plan=plan)
    b, s = x.shape[:2]
    if d.real_heads != d.num_heads:
        out = out[:, :, :d.real_heads]
    return apply_site(params["o"], out.reshape(b, s, -1), d.o, cfg)


def len_positions(cur_len: jax.Array | int, b: int) -> jax.Array:
    """(B,1) query positions from a scalar or per-slot (B,) ``cur_len``."""
    cl = jnp.asarray(cur_len, jnp.int32)
    if cl.ndim == 0:
        return jnp.full((b, 1), cl, jnp.int32)
    return cl.reshape(b, 1)


def cache_append(cache_arr: jax.Array, new: jax.Array,
                 cur_len: jax.Array | int) -> jax.Array:
    """Write one new token at position ``cur_len`` along axis 1.

    cache_arr: (B, T, ...); new: (B, 1, ...). Scalar cur_len keeps the
    cheap dynamic_update_slice; a per-slot (B,) vector uses a one-hot
    scatter (each batch row writes at its own position)."""
    cl = jnp.asarray(cur_len, jnp.int32)
    new = new.astype(cache_arr.dtype)
    if cl.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(cache_arr, new, cl, axis=1)
    b = cache_arr.shape[0]
    return cache_arr.at[jnp.arange(b), cl].set(new[:, 0])


def causal_len_mask(qpos: jax.Array, t: int) -> jax.Array:
    """(B, S, T) mask: key position visible iff kpos <= qpos."""
    kpos = jnp.arange(t)
    return kpos[None, None, :] <= qpos[:, :, None]


def gqa_decode_qkv(params: dict, x: jax.Array, d: GQADef, cfg: ModelConfig,
                   positions: jax.Array):
    """Project q and the new k/v for decode / chunked prefill.

    x: (B,S,D); positions: (B,S). Returns q (B,S,Hq,Dh), k/v (B,S,Hkv,Dh)."""
    b, s = x.shape[:2]
    q = apply_site(params["q"], x, d.q, cfg).reshape(b, s, d.num_heads, d.head_dim)
    kv = apply_site(params["kv"], x, d.kv, cfg).reshape(
        b, s, 2, d.num_kv_heads, d.head_dim)
    k_new, v_new = kv[:, :, 0], kv[:, :, 1]
    q = rope(q, positions, cfg.rope_theta)
    k_new = rope(k_new, positions, cfg.rope_theta)
    return q, k_new, v_new


def gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array, d: GQADef,
               qpos: jax.Array) -> jax.Array:
    """Decode-style attention over a full cache with per-row lengths.

    q: (B,S,Hq,Dh); k,v: (B,T,Hkv,Dh); qpos: (B,S) absolute query positions
    (key position kpos attends iff kpos <= qpos). Returns (B,S,real*Dh)."""
    b, s = q.shape[:2]
    t = k.shape[1]
    scale = 1.0 / math.sqrt(d.head_dim)
    g = d.num_heads // d.num_kv_heads
    qg = q.reshape(b, s, d.num_kv_heads, g, d.head_dim)
    sc = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                    preferred_element_type=jnp.float32) * scale
    mask = causal_len_mask(qpos, t)                       # (B, S, T)
    sc = jnp.where(mask[:, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    out = out.reshape(b, s, d.num_heads, d.head_dim)[:, :, :d.real_heads]
    return out.reshape(b, s, d.real_heads * d.head_dim)


def gqa_decode(params: dict, x: jax.Array, cache: dict, d: GQADef,
               cfg: ModelConfig, cur_len: jax.Array) -> tuple[jax.Array, dict]:
    """One-token decode. x: (B,1,D). cache: {"k","v"}: (B,T,Hkv,Dh).
    ``cur_len``: scalar shared length, or (B,) per-slot lengths."""
    b = x.shape[0]
    positions = len_positions(cur_len, b)
    q, k_new, v_new = gqa_decode_qkv(params, x, d, cfg, positions)
    k = cache_append(cache["k"], k_new, cur_len)
    v = cache_append(cache["v"], v_new, cur_len)
    out = gqa_attend(q, k, v, d, positions)
    y = apply_site(params["o"], out, d.o, cfg)
    return y, {"k": k, "v": v}


def gqa_init_cache(d: GQADef, batch: int, max_len: int, dtype) -> dict:
    return {
        "k": jnp.zeros((batch, max_len, d.num_kv_heads, d.head_dim), dtype),
        "v": jnp.zeros((batch, max_len, d.num_kv_heads, d.head_dim), dtype),
    }


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2) block
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MLADef:
    q_down: SiteDef
    q_up: SiteDef
    kv_down: SiteDef        # -> kv_lora + rope dim
    k_up: SiteDef           # kv_lora -> H * qk_nope
    v_up: SiteDef           # kv_lora -> H * v_head
    o: SiteDef
    num_heads: int
    m: MLAConfig


def make_mla(cfg: ModelConfig) -> MLADef:
    m = cfg.mla
    h = cfg.num_heads
    return MLADef(
        q_down=make_site(cfg, "attn_qkv", m.q_lora_rank, cfg.d_model),
        q_up=make_site(cfg, "attn_qkv",
                       h * (m.qk_nope_head_dim + m.qk_rope_head_dim),
                       m.q_lora_rank),
        kv_down=make_site(cfg, "attn_qkv", m.kv_lora_rank + m.qk_rope_head_dim,
                          cfg.d_model),
        k_up=make_site(cfg, "attn_qkv", h * m.qk_nope_head_dim, m.kv_lora_rank),
        v_up=make_site(cfg, "attn_qkv", h * m.v_head_dim, m.kv_lora_rank),
        o=make_site(cfg, "attn_o", cfg.d_model, h * m.v_head_dim),
        num_heads=h, m=m)


def init_mla(key: jax.Array, d: MLADef, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 8)
    return {
        "q_down": init_site(ks[0], d.q_down, cfg),
        "q_norm": {"scale": jnp.ones((d.m.q_lora_rank,), jnp.float32)},
        "q_up": init_site(ks[1], d.q_up, cfg),
        "kv_down": init_site(ks[2], d.kv_down, cfg),
        "kv_norm": {"scale": jnp.ones((d.m.kv_lora_rank,), jnp.float32)},
        "k_up": init_site(ks[3], d.k_up, cfg),
        "v_up": init_site(ks[4], d.v_up, cfg),
        "o": init_site(ks[5], d.o, cfg),
    }


def _mla_q(params, x, d: MLADef, cfg, positions):
    b, s, _ = x.shape
    m = d.m
    cq = apply_site(params["q_down"], x, d.q_down, cfg)
    cq = C.rms_norm(cq, params["q_norm"]["scale"], cfg.norm_eps)
    q = apply_site(params["q_up"], cq, d.q_up, cfg).reshape(
        b, s, d.num_heads, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_latent(params, x, d: MLADef, cfg, positions):
    m = d.m
    ckv = apply_site(params["kv_down"], x, d.kv_down, cfg)
    c_kv, k_rope = ckv[..., :m.kv_lora_rank], ckv[..., m.kv_lora_rank:]
    c_kv = C.rms_norm(c_kv, params["kv_norm"]["scale"], cfg.norm_eps)
    k_rope = rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_forward(params: dict, x: jax.Array, d: MLADef, cfg: ModelConfig, *,
                causal: bool, positions: jax.Array, plan=None) -> jax.Array:
    """Prefill/train path: reconstruct per-head K/V, run chunked attention."""
    b, s, _ = x.shape
    m = d.m
    q_nope, q_rope = _mla_q(params, x, d, cfg, positions)
    c_kv, k_rope = _mla_kv_latent(params, x, d, cfg, positions)
    k_nope = apply_site(params["k_up"], c_kv, d.k_up, cfg).reshape(
        b, s, d.num_heads, m.qk_nope_head_dim)
    v = apply_site(params["v_up"], c_kv, d.v_up, cfg).reshape(
        b, s, d.num_heads, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (b, s, d.num_heads, m.qk_rope_head_dim))],
        axis=-1)
    if plan is not None:
        q = plan.heads_act(q)
        k = plan.heads_act(k)
        v = plan.heads_act(v)
    # pad v's head dim to match q/k for the shared kernel, slice after
    out = chunked_attention(q, k, jnp.pad(v, ((0, 0), (0, 0), (0, 0),
                                              (0, q.shape[-1] - v.shape[-1]))),
                            causal=causal, plan=plan)
    out = out[..., :m.v_head_dim].reshape(b, s, -1)
    return apply_site(params["o"], out, d.o, cfg)


def _absorb_weight(psite: dict, site, cfg: ModelConfig) -> jax.Array:
    """Dense (in, out) weight of a site, materializing TT factors if needed."""
    if "w" in psite:
        return psite["w"]
    from ..core import tt_layer as TL
    from ..core.ttm import ttm_to_dense
    cores = TL.effective_cores(psite, site.spec, cfg.tt, cfg.quant)
    return ttm_to_dense(cores, site.spec).T


def mla_decode_q(params: dict, x: jax.Array, d: MLADef, cfg: ModelConfig,
                 positions: jax.Array):
    """Absorbed decode queries. x: (B,S,D); positions (B,S).
    Returns q_abs (B,S,H,kv_lora) and q_rope (B,S,H,rope)."""
    m = d.m
    q_nope, q_rope = _mla_q(params, x, d, cfg, positions)
    # absorb k_up into q: q_abs = q_nope @ Wk^T per head
    wk = _absorb_weight(params["k_up"], d.k_up, cfg)  # (kv_lora, H*nope)
    wk = wk.reshape(m.kv_lora_rank, d.num_heads, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bqhd,lhd->bqhl", q_nope, wk.astype(q_nope.dtype))
    return q_abs, q_rope


def mla_attend(params: dict, q_abs: jax.Array, q_rope: jax.Array,
               ckv: jax.Array, kr: jax.Array, d: MLADef, cfg: ModelConfig,
               qpos: jax.Array) -> jax.Array:
    """Latent-space attention. ckv: (B,T,kv_lora); kr: (B,T,rope);
    qpos: (B,S). Returns (B,S,H*v_head) pre-o-proj."""
    m = d.m
    b, s = q_abs.shape[:2]
    t = ckv.shape[1]
    s_nope = jnp.einsum("bqhl,btl->bhqt", q_abs, ckv,
                        preferred_element_type=jnp.float32)
    s_rope = jnp.einsum("bqhd,btd->bhqt", q_rope, kr,
                        preferred_element_type=jnp.float32)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    sc = (s_nope + s_rope) * scale
    mask = causal_len_mask(qpos, t)                       # (B, S, T)
    sc = jnp.where(mask[:, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out_lat = jnp.einsum("bhqt,btl->bqhl", p.astype(ckv.dtype), ckv)
    wv = _absorb_weight(params["v_up"], d.v_up, cfg)
    wv = wv.reshape(m.kv_lora_rank, d.num_heads, m.v_head_dim)
    out = jnp.einsum("bqhl,lhd->bqhd", out_lat, wv.astype(out_lat.dtype))
    return out.reshape(b, s, -1)


def mla_decode(params: dict, x: jax.Array, cache: dict, d: MLADef,
               cfg: ModelConfig, cur_len: jax.Array) -> tuple[jax.Array, dict]:
    """Absorbed decode (beyond-paper efficiency, standard MLA practice):
    scores and values computed in the 512-d latent space; cache holds only
    (c_kv, k_rope) — the MLA memory win. ``cur_len``: scalar or (B,)."""
    b = x.shape[0]
    positions = len_positions(cur_len, b)
    q_abs, q_rope = mla_decode_q(params, x, d, cfg, positions)
    c_new, kr_new = _mla_kv_latent(params, x, d, cfg, positions)
    ckv = cache_append(cache["c_kv"], c_new, cur_len)
    kr = cache_append(cache["k_rope"], kr_new, cur_len)
    out = mla_attend(params, q_abs, q_rope, ckv, kr, d, cfg, positions)
    y = apply_site(params["o"], out, d.o, cfg)
    return y, {"c_kv": ckv, "k_rope": kr}


def mla_init_cache(d: MLADef, batch: int, max_len: int, dtype) -> dict:
    return {
        "c_kv": jnp.zeros((batch, max_len, d.m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, d.m.qk_rope_head_dim), dtype),
    }

"""Unified LM: every arch in the zoo is an instance of this module.

Structure: embed → scan over *periods* of sublayers → final norm → head.
A period is a fixed pattern of sublayers (1 for homogeneous stacks; 8 for
Jamba's 7-Mamba+1-attention interleave). Layer params are stacked on a
leading axis and consumed by ``lax.scan`` (compile time independent of
depth), with configurable remat.

Every weight matrix is a *site* (dense or TT-factorized per config); TT
sites contribute the rank-shrinkage prior and receive closed-form λ updates.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding import ShardPlan
from . import attention as A
from . import ffn as F
from . import moe as M
from . import ssm as S
from .common import (SiteDef, apply_site, init_site, make_site, rms_norm,
                     site_lambda_update, site_prior_loss)


@dataclass(frozen=True)
class SubDef:
    mixer_kind: str          # "attn_gqa" | "attn_mla" | "mamba" | "rwkv6"
    mixer: Any
    ffn_kind: str | None     # "ffn" | "moe" | None (rwkv has its own)
    ffn: Any


@dataclass(frozen=True)
class LMDef:
    cfg: ModelConfig
    embed: SiteDef | None    # None when frontend replaces token embedding
    head: SiteDef
    period: tuple[SubDef, ...]
    n_periods: int


# ---------------------------------------------------------------------------
# Construction
# ---------------------------------------------------------------------------

def build_lm(cfg: ModelConfig) -> LMDef:
    subs: list[SubDef] = []

    def mixer_for(kind: str) -> tuple[str, Any]:
        if kind == "attn":
            if cfg.attn_kind == "mla":
                return "attn_mla", A.make_mla(cfg)
            return "attn_gqa", A.make_gqa(cfg)
        if kind == "mamba":
            return "mamba", S.make_mamba(cfg)
        if kind == "rwkv6":
            return "rwkv6", S.make_rwkv6(cfg)
        raise ValueError(kind)

    def ffn_for(use_moe: bool) -> tuple[str | None, Any]:
        if use_moe and cfg.moe.num_experts > 0:
            return "moe", M.make_moe(cfg)
        return "ffn", F.make_ffn(cfg)

    if cfg.family == "ssm_rwkv6":
        mk, mx = mixer_for("rwkv6")
        subs.append(SubDef(mk, mx, None, None))
        n_periods = cfg.num_layers
    elif cfg.family == "hybrid_jamba":
        for pos in range(cfg.period):
            kind = "attn" if pos in cfg.attn_positions else "mamba"
            mk, mx = mixer_for(kind)
            fk, fd = ffn_for(pos in cfg.moe_positions)
            subs.append(SubDef(mk, mx, fk, fd))
        assert cfg.num_layers % cfg.period == 0
        n_periods = cfg.num_layers // cfg.period
    else:  # dense / moe / encoder
        mk, mx = mixer_for("attn")
        fk, fd = ffn_for(cfg.moe.num_experts > 0)
        subs.append(SubDef(mk, mx, fk, fd))
        n_periods = cfg.num_layers

    embed = None
    if cfg.frontend != "audio":
        embed = make_site(cfg, "embed", cfg.vocab_size, cfg.d_model)
    head = make_site(cfg, "head", cfg.vocab_size, cfg.d_model)
    return LMDef(cfg, embed, head, tuple(subs), n_periods)


def _init_sub(key: jax.Array, sub: SubDef, cfg: ModelConfig) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict = {"norm1": {"scale": jnp.ones((cfg.d_model,), jnp.float32)}}
    if sub.mixer_kind == "attn_gqa":
        p["mixer"] = A.init_gqa(k1, sub.mixer, cfg)
    elif sub.mixer_kind == "attn_mla":
        p["mixer"] = A.init_mla(k1, sub.mixer, cfg)
    elif sub.mixer_kind == "mamba":
        p["mixer"] = S.init_mamba(k1, sub.mixer, cfg)
    elif sub.mixer_kind == "rwkv6":
        p["mixer"] = S.init_rwkv6(k1, sub.mixer, cfg)
        p["norm2"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
        return p
    if sub.ffn_kind is not None:
        p["norm2"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
        if sub.ffn_kind == "moe":
            p["moe"] = M.init_moe(k2, sub.ffn, cfg)
        else:
            p["ffn"] = F.init_ffn(k2, sub.ffn, cfg)
    return p


def init_lm(key: jax.Array, lm: LMDef) -> dict:
    cfg = lm.cfg
    ke, kl, kh = jax.random.split(key, 3)
    params: dict = {}
    if lm.embed is not None:
        # embedding stored as (V, D) table (dense) or TT site
        if lm.embed.use_tt:
            params["embed"] = init_site(ke, lm.embed, cfg)
        else:
            sigma = 1.0 / math.sqrt(cfg.d_model)
            params["embed"] = {"w": (jax.random.normal(
                ke, (cfg.vocab_size, cfg.d_model), jnp.float32) * sigma
            ).astype(jnp.dtype(cfg.dtype))}

    def init_period(k):
        ks = jax.random.split(k, len(lm.period))
        return {f"sub_{i}": _init_sub(ks[i], sub, cfg)
                for i, sub in enumerate(lm.period)}

    params["layers"] = jax.vmap(init_period)(
        jax.random.split(kl, lm.n_periods))
    params["final_norm"] = {"scale": jnp.ones((cfg.d_model,), jnp.float32)}
    params["head"] = init_site(kh, lm.head, cfg)
    return params


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------

def embed_tokens(params: dict, tokens: jax.Array, lm: LMDef) -> jax.Array:
    cfg = lm.cfg
    if lm.embed is not None and lm.embed.use_tt:
        from ..core.tt_layer import effective_cores
        from ..core.ttm import ttm_matvec
        # TT embedding lookup: one-hot-free digit-select contraction
        return tt_embed_lookup(params["embed"], tokens, lm.embed, cfg)
    table = params["embed"]["w"]
    return table[tokens].astype(jnp.dtype(cfg.dtype))


def tt_embed_lookup(eparams: dict, tokens: jax.Array, site: SiteDef,
                    cfg: ModelConfig) -> jax.Array:
    """Row lookup in a TT-represented (V, D) table.

    V is factored over the cores' J dims; each token id is decomposed into
    mixed-radix digits (j_1..j_d); the row is the product of the selected
    core slices — O(Σ R I R) FLOPs per token instead of a (V·D) table in
    memory (the paper's technique applied to embeddings; cf. Khrulkov 2019).
    """
    from ..core.tt_layer import effective_cores
    spec = site.spec
    cores = effective_cores(eparams, spec, cfg.tt, cfg.quant)
    shape = tokens.shape
    ids = tokens.reshape(-1)
    # mixed-radix digits, most-significant first (row-major over j_dims)
    digits = []
    rem = ids
    for n in range(spec.d - 1, -1, -1):
        digits.append(rem % spec.j_dims[n])
        rem = rem // spec.j_dims[n]
    digits = digits[::-1]

    m = jnp.ones((ids.shape[0], 1, 1), jnp.float32)      # (T, prefix=1, R0=1)
    for n in range(spec.d):
        g = cores[n].astype(jnp.float32)                 # (R, J, I, R')
        gsel = g[:, digits[n]]                           # (R, T, I, R')
        gsel = jnp.moveaxis(gsel, 1, 0)                  # (T, R, I, R')
        m = jnp.einsum("tpr,trik->tpik", m, gsel)
        m = m.reshape(ids.shape[0], -1, g.shape[3])      # (T, prefix*I, R')
    out = m[..., 0]                                      # (T, D)
    return out.reshape(shape + (spec.in_dim,)).astype(jnp.dtype(cfg.dtype))


# ---------------------------------------------------------------------------
# Forward / decode bodies
# ---------------------------------------------------------------------------

def _sub_forward(pp: dict, x: jax.Array, sub: SubDef, cfg: ModelConfig,
                 plan: ShardPlan, positions: jax.Array, *,
                 return_cache: bool, token_mask: jax.Array | None = None,
                 capacity_tokens: int | None = None):
    """One sublayer (mixer + optional ffn). Returns (x, aux, cache_entry).

    ``token_mask``: optional (B, S) bool of real tokens — serve-prefill
    bucket padding is masked out of the MoE router so pad tokens never
    consume expert capacity (see ``moe_forward``)."""
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    h = rms_norm(x, pp["norm1"]["scale"], cfg.norm_eps)
    causal = not cfg.is_encoder
    if sub.mixer_kind == "attn_gqa":
        q, k, v = A.gqa_qkv(pp["mixer"], h, sub.mixer, cfg, positions)
        q = plan.heads_act(q)
        k = plan.kv_full(k)
        v = plan.kv_full(v)
        out = A.chunked_attention(q, k, v, causal=causal, plan=plan)
        b, s = h.shape[:2]
        if sub.mixer.real_heads != sub.mixer.num_heads:
            out = out[:, :, :sub.mixer.real_heads]
        out = apply_site(pp["mixer"]["o"], out.reshape(b, s, -1),
                         sub.mixer.o, cfg)
        if return_cache:
            cache = {"k": k, "v": v}
    elif sub.mixer_kind == "attn_mla":
        out = A.mla_forward(pp["mixer"], h, sub.mixer, cfg, causal=causal,
                            positions=positions, plan=plan)
        if return_cache:
            c_kv, k_rope = A._mla_kv_latent(pp["mixer"], h, sub.mixer, cfg,
                                            positions)
            cache = {"c_kv": c_kv, "k_rope": k_rope}
    elif sub.mixer_kind == "mamba":
        out, st = S.mamba_forward(pp["mixer"], h, sub.mixer, cfg, None)
        if return_cache:
            cache = st
    elif sub.mixer_kind == "rwkv6":
        out, st = S.rwkv6_time_mix(pp["mixer"], h, sub.mixer, cfg, None)
        x = plan.hidden(x + out)
        h2 = rms_norm(x, pp["norm2"]["scale"], cfg.norm_eps)
        out2, st2 = S.rwkv6_channel_mix(pp["mixer"], h2, sub.mixer, cfg, None)
        x = plan.hidden(x + out2)
        if return_cache:
            cache = {**st, **st2}
        return x, aux, cache
    x = plan.hidden(x + out)
    if sub.ffn_kind is not None:
        h = rms_norm(x, pp["norm2"]["scale"], cfg.norm_eps)
        if sub.ffn_kind == "moe":
            out, a = M.moe_forward(pp["moe"], h, sub.ffn, cfg,
                                   mesh=plan.mesh, dp_axes=plan.dp_axes,
                                   token_mask=token_mask,
                                   capacity_tokens=capacity_tokens)
            aux = aux + a
        else:
            out = F.ffn_forward(pp["ffn"], h, sub.ffn, cfg)
        x = plan.hidden(x + out)
    return x, aux, cache


def _act_quant_edge(x: jax.Array, scales: dict, cfg: ModelConfig) -> jax.Array:
    """Policy-owned ``activation`` site for the zoo LMs: fake-quant the
    residual stream forward at ``act_bits`` and the incoming activation-
    gradient backward at ``grad_bits`` (clipped STE), with the SHARED
    managed scales from the ``TrainState.scales`` tree — the same §3.2/§3.3
    edge the FMNIST MLP carries per-tensor, scaled to one scale-owner per
    site across the whole stack (the policy's managed ScaleState)."""
    from ..core.quant import quant_edge_shared
    return quant_edge_shared(x, scales["activation"], scales["grad_edge"],
                             cfg.quant.act_bits, cfg.quant.grad_bits)


def _remat_wrap(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    else:
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol)


def lm_forward(params: dict, lm: LMDef, plan: ShardPlan, *,
               tokens: jax.Array | None = None,
               embeds: jax.Array | None = None,
               return_cache: bool = False,
               token_mask: jax.Array | None = None,
               scales: dict | None = None,
               capacity_tokens: int | None = None):
    """Train/prefill forward.

    tokens: (B, S) int32 and/or embeds: (B, P, D) frontend outputs (vlm:
    embeds are prepended to token embeddings; audio: embeds replace them).
    token_mask: optional (B, S) bool of real positions — padding (serve
    whole-prompt prefill buckets) is excluded from MoE capacity routing.
    scales: optional NumericsPolicy managed scale-state tree
    (``TrainState.scales``). When given (and ``cfg.quant.enable``) the
    ``activation`` site goes live: the residual stream is fake-quantized at
    every sublayer boundary (plus the embedding output) with the shared
    managed scales, and the return gains a 4th element ``obs`` — the
    per-layer mean |activation| statistic the scale manager consumes
    (``policy.update_scales(scales, obs)`` in the train step).
    Returns (logits, aux, cache|None) or (logits, aux, cache|None, obs).
    """
    cfg = lm.cfg
    # the edge quantizes fwd AND bwd, so both managed sites must be present
    # (a custom policy may demote either to fixed/per-tensor-max scales)
    quant_acts = (scales is not None and cfg.quant.enable
                  and "activation" in scales and "grad_edge" in scales)
    if embeds is not None and tokens is not None:
        xt = embed_tokens(params, tokens, lm)
        x = jnp.concatenate([embeds.astype(xt.dtype), xt], axis=1)
    elif embeds is not None:
        x = embeds.astype(jnp.dtype(cfg.dtype))
    else:
        x = embed_tokens(params, tokens, lm)
    b, s, _ = x.shape
    x = plan.hidden(x)
    if quant_acts:
        x = _act_quant_edge(x, scales, cfg)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))

    def body(carry, pp):
        x, aux, amean = carry
        caches = {}
        for i, sub in enumerate(lm.period):
            x, a, c = _sub_forward(pp[f"sub_{i}"], x, sub, cfg, plan,
                                   positions, return_cache=return_cache,
                                   token_mask=token_mask,
                                   capacity_tokens=capacity_tokens)
            if quant_acts:
                x = _act_quant_edge(x, scales, cfg)
            aux = aux + a
            caches[f"sub_{i}"] = c
        if quant_acts:
            amean = amean + jnp.mean(jnp.abs(
                jax.lax.stop_gradient(x).astype(jnp.float32)))
        return (x, aux, amean), caches

    body = _remat_wrap(body, cfg)
    (x, aux, amean), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        params["layers"])
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = apply_site(params["head"], x, lm.head, cfg)
    if cfg.logits_softcap > 0:
        logits = jnp.tanh(logits / cfg.logits_softcap) * cfg.logits_softcap
    logits = plan.logits(logits)
    cache = caches if return_cache else None
    if scales is None:
        return logits, aux, cache
    obs = {"activation": (amean / lm.n_periods)[None]} if quant_acts else {}
    return logits, aux, cache, obs


def sub_ffn_decode(pp: dict, x: jax.Array, sub: SubDef, cfg: ModelConfig,
                   plan: ShardPlan,
                   token_mask: jax.Array | None = None,
                   capacity_tokens: int | None = None) -> jax.Array:
    """Post-mixer FFN/MoE half of a sublayer (shared by the static decode
    path and repro.serve's paged decode/chunk steps).

    ``token_mask``: optional (B, S) bool of real tokens — inactive serve
    slots / prefill-chunk padding are masked out of the MoE router so junk
    tokens never consume expert capacity (dense FFN ignores it: per-token
    math can't interfere across rows)."""
    if sub.ffn_kind is None:
        return x
    h = rms_norm(x, pp["norm2"]["scale"], cfg.norm_eps)
    if sub.ffn_kind == "moe":
        out, _ = M.moe_forward(pp["moe"], h, sub.ffn, cfg,
                               mesh=plan.mesh, dp_axes=plan.dp_axes,
                               token_mask=token_mask,
                               capacity_tokens=capacity_tokens)
    else:
        out = F.ffn_forward(pp["ffn"], h, sub.ffn, cfg)
    return x + out


def _sub_decode(pp: dict, x: jax.Array, cc: dict, sub: SubDef,
                cfg: ModelConfig, plan: ShardPlan, cur_len: jax.Array):
    h = rms_norm(x, pp["norm1"]["scale"], cfg.norm_eps)
    if sub.mixer_kind == "attn_gqa":
        out, cnew = A.gqa_decode(pp["mixer"], h, cc, sub.mixer, cfg, cur_len)
        cnew = {k: plan.cache_kv(v) for k, v in cnew.items()}
    elif sub.mixer_kind == "attn_mla":
        out, cnew = A.mla_decode(pp["mixer"], h, cc, sub.mixer, cfg, cur_len)
        cnew = {k: plan.cache_kv(v) for k, v in cnew.items()}
    elif sub.mixer_kind == "mamba":
        out, cnew = S.mamba_forward(pp["mixer"], h, sub.mixer, cfg, cc)
    elif sub.mixer_kind == "rwkv6":
        out, st = S.rwkv6_time_mix(pp["mixer"], h, sub.mixer, cfg, cc)
        x = x + out
        h2 = rms_norm(x, pp["norm2"]["scale"], cfg.norm_eps)
        out2, st2 = S.rwkv6_channel_mix(pp["mixer"], h2, sub.mixer, cfg, cc)
        return x + out2, {**st, **st2}
    x = x + out
    return sub_ffn_decode(pp, x, sub, cfg, plan), cnew


def lm_decode_step(params: dict, cache: dict, tokens: jax.Array,
                   cur_len: jax.Array, lm: LMDef, plan: ShardPlan):
    """One-token decode. tokens: (B,1). cache leaves stacked (n_periods, ...).
    ``cur_len``: scalar shared position, or a per-slot (B,) vector — each
    batch row then appends/attends at its own length (the continuous-
    batching primitive; see repro.serve). Returns (logits, new_cache)."""
    cfg = lm.cfg
    x = embed_tokens(params, tokens, lm)
    x = plan.constrain(x, jax.sharding.PartitionSpec(plan.dp_axes, None, None))

    def body(x, scan_in):
        pp, cc = scan_in
        new_cc = {}
        for i, sub in enumerate(lm.period):
            x, cnew = _sub_decode(pp[f"sub_{i}"], x, cc[f"sub_{i}"], sub,
                                  cfg, plan, cur_len)
            new_cc[f"sub_{i}"] = cnew
        return x, new_cc

    x, new_cache = jax.lax.scan(body, x, (params["layers"], cache))
    x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
    logits = apply_site(params["head"], x, lm.head, cfg)
    return logits, new_cache


def lm_init_cache(lm: LMDef, batch: int, max_len: int, plan: ShardPlan) -> dict:
    cfg = lm.cfg
    dtype = jnp.dtype(cfg.dtype)

    def one_sub(sub: SubDef) -> dict:
        if sub.mixer_kind == "attn_gqa":
            c = A.gqa_init_cache(sub.mixer, batch, max_len, dtype)
        elif sub.mixer_kind == "attn_mla":
            c = A.mla_init_cache(sub.mixer, batch, max_len, dtype)
        elif sub.mixer_kind == "mamba":
            c = S.mamba_init_state(sub.mixer, batch, dtype)
        else:
            c = S.rwkv6_init_state(sub.mixer, batch, cfg.d_model, dtype)
        return c

    def stack(c):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (lm.n_periods,) + a.shape), c)

    return {f"sub_{i}": stack(one_sub(sub))
            for i, sub in enumerate(lm.period)}


def lm_cache_pspec(lm: LMDef, cache: dict, plan: ShardPlan):
    """PartitionSpec tree for a decode cache: seq-sharded over data when
    plan.seq_sharded_cache (long-context SP), else batch over dp / heads
    over model where divisible."""
    from jax.sharding import PartitionSpec as P

    def spec_for(a: jax.Array) -> P:
        if plan.mesh is None:
            return P()
        # leading axis = period stack, axis 1 = batch, axis 2 = seq/feature
        rest = (None,) * (a.ndim - 3)
        if plan.seq_sharded_cache and a.ndim >= 3 and \
                a.shape[2] % plan.mesh.shape["data"] == 0 and a.shape[2] > 1024:
            return P(None, None, "data", *rest)
        if a.shape[1] % _dpsize(plan) == 0 and a.shape[1] >= _dpsize(plan):
            return P(None, plan.dp_axes, None, *rest)
        return P()

    return jax.tree.map(spec_for, cache)


def _dpsize(plan: ShardPlan) -> int:
    if plan.mesh is None:
        return 1
    n = 1
    for ax in plan.dp_axes:
        n *= plan.mesh.shape[ax]
    return n


# ---------------------------------------------------------------------------
# TT-site walking (prior loss, λ update, param counting)
# ---------------------------------------------------------------------------

def _walk_sites(lm: LMDef):
    """Yield (params_path_tuple, SiteDef) for every weight site."""
    if lm.embed is not None:
        yield ("embed",), lm.embed
    for i, sub in enumerate(lm.period):
        base = ("layers", f"sub_{i}")
        mk = sub.mixer_kind
        if mk == "attn_gqa":
            for n in ("q", "kv", "o"):
                yield base + ("mixer", n), getattr(sub.mixer, n)
        elif mk == "attn_mla":
            for n in ("q_down", "q_up", "kv_down", "k_up", "v_up", "o"):
                yield base + ("mixer", n), getattr(sub.mixer, n)
        elif mk == "mamba":
            for n in ("in_proj", "x_proj", "dt_proj", "out_proj"):
                yield base + ("mixer", n), getattr(sub.mixer, n)
        elif mk == "rwkv6":
            for n in ("r", "k", "v", "g", "o", "w_lora_a", "w_lora_b",
                      "ffn_k", "ffn_v", "ffn_r"):
                yield base + ("mixer", n), getattr(sub.mixer, n)
        if sub.ffn_kind == "ffn":
            for n in ("gate", "up", "down"):
                yield base + ("ffn", n), getattr(sub.ffn, n)
        elif sub.ffn_kind == "moe":
            for n in ("router",):
                yield base + ("moe", n), getattr(sub.ffn, n)
            for n in ("gate", "up", "down"):
                yield base + ("moe", n), getattr(sub.ffn, n)
            if sub.ffn.shared is not None:
                for n in ("gate", "up", "down"):
                    yield base + ("moe", "shared", n), getattr(sub.ffn.shared, n)
    yield ("head",), lm.head


def _get_path(params, path):
    node = params
    for p in path:
        node = node[p]
    return node


def lm_prior_loss(params: dict, lm: LMDef) -> jax.Array:
    total = jnp.zeros((), jnp.float32)
    for path, site in _walk_sites(lm):
        if site.use_tt:
            total = total + site_prior_loss(_get_path(params, path), site, lm.cfg)
    return total


def lm_lambda_update(params: dict, lm: LMDef) -> dict:
    if not lm.cfg.tt.enable or not lm.cfg.tt.rank_adapt:
        return params
    import copy
    new = jax.tree.map(lambda a: a, params)  # shallow-ish copy of structure

    def set_path(tree, path, value):
        node = tree
        for p in path[:-1]:
            node = node[p]
        node[path[-1]] = value

    for path, site in _walk_sites(lm):
        if site.use_tt:
            old = _get_path(new, path)
            set_path(new, path, site_lambda_update(old, site, lm.cfg))
    return new


def lm_param_counts(params: dict, lm: LMDef) -> dict:
    """Dense-equivalent vs actual vs live (post-pruning) parameter counts."""
    from ..core import rank_adapt as RA
    dense = 0
    actual = 0
    live = 0
    for path, site in _walk_sites(lm):
        stack = lm.n_periods if path[0] == "layers" else 1
        mult = stack
        if site.use_tt:
            p = _get_path(params, path)
            spec = site.spec
            dense += site.out_dim * site.in_dim * mult
            actual += spec.num_params * mult
            lambdas = [p[f"lambda_{n}"] for n in range(spec.d - 1)
                       if f"lambda_{n}" in p]
            if lambdas and lambdas[0].ndim > 0:
                # stacked: count live ranks per stack entry
                import numpy as np
                th = lm.cfg.tt.prune_threshold
                for s_i in range(mult if lambdas[0].ndim > 1 else 1):
                    eff = []
                    for lam in lambdas:
                        l = lam[s_i] if lam.ndim > 1 else lam
                        eff.append(int(jnp.sum(l > th * jnp.max(l))))
                    ranks = [1] + eff + [1]
                    live += sum(ranks[n] * spec.j_dims[n] * spec.i_dims[n]
                                * ranks[n + 1] for n in range(spec.d))
            else:
                live += spec.num_params * mult
        else:
            n = site.out_dim * site.in_dim * mult
            dense += n
            actual += n
            live += n
    return {"dense": dense, "tt": actual, "live": live,
            "compression": dense / max(live, 1)}

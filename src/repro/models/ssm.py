"""State-space blocks: Mamba-1 selective scan (Jamba's mixer) and RWKV6
"Finch" (data-dependent decay linear attention).

Both are O(1)-state decoders — these are the archs that run the long_500k
cell. Projections are weight *sites* (TT-factorizable); the recurrences
themselves carry per-channel vectors, not matrices, so the paper's technique
does not apply to them (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import SiteDef, apply_site, init_site, make_site, rms_norm, silu


# ---------------------------------------------------------------------------
# Mamba-1 (selective scan)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MambaDef:
    in_proj: SiteDef        # D -> 2 * d_inner  (x and z)
    x_proj: SiteDef         # d_inner -> dt_rank + 2*d_state
    dt_proj: SiteDef        # dt_rank -> d_inner
    out_proj: SiteDef       # d_inner -> D
    d_inner: int
    d_state: int
    d_conv: int
    dt_rank: int


def make_mamba(cfg: ModelConfig) -> MambaDef:
    di = cfg.ssm.expand * cfg.d_model
    dtr = cfg.ssm.dt_rank or max(1, math.ceil(cfg.d_model / 16))
    return MambaDef(
        in_proj=make_site(cfg, "ssm_proj", 2 * di, cfg.d_model),
        x_proj=make_site(cfg, "ssm_proj", dtr + 2 * cfg.ssm.d_state, di),
        dt_proj=make_site(cfg, "ssm_proj", di, dtr, use_bias=True),
        out_proj=make_site(cfg, "ssm_proj", cfg.d_model, di),
        d_inner=di, d_state=cfg.ssm.d_state, d_conv=cfg.ssm.d_conv, dt_rank=dtr)


def init_mamba(key: jax.Array, d: MambaDef, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 6)
    dtype = jnp.dtype(cfg.dtype)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, d.d_state + 1, dtype=jnp.float32)[None, :],
                 (d.d_inner, 1))
    return {
        "in_proj": init_site(ks[0], d.in_proj, cfg),
        "conv_w": (jax.random.normal(ks[1], (d.d_conv, d.d_inner), jnp.float32)
                   * (1.0 / math.sqrt(d.d_conv))).astype(dtype),
        "conv_b": jnp.zeros((d.d_inner,), dtype),
        "x_proj": init_site(ks[2], d.x_proj, cfg),
        "dt_proj": init_site(ks[3], d.dt_proj, cfg),
        "A_log": jnp.log(a),
        "D": jnp.ones((d.d_inner,), jnp.float32),
        "out_proj": init_site(ks[4], d.out_proj, cfg),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). Returns (y, new_state)
    where state holds the last K-1 inputs for decode."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(k))
    new_state = xp[:, -(k - 1):, :]
    return y + b[None, None, :], new_state


SCAN_CHUNK = 256


def _ssm_step(h, u_t, dt_t, bt, ct, a):
    """One selective-scan step: h (B,Di,N) f32, u_t/dt_t (B,Di), bt/ct (B,N).
    Returns (h_new, y (B,Di)). Shared by the prefill scan and the serve
    engine's single-step decode so the two are bit-identical."""
    da_t = jnp.exp(dt_t[..., None] * a[None])               # (B,Di,N)
    x_t = (dt_t * u_t)[..., None] * bt[:, None, :]
    h = da_t * h + x_t
    y = jnp.einsum("bdn,bn->bd", h, ct)
    return h, y


def _selective_scan(u, dt, a, b_t, c_t, d_skip, h0=None):
    """u,dt: (B,S,Di); a: (Di,N); b_t,c_t: (B,S,N). Returns (y, h_last).

    Two structural choices that matter at scale (EXPERIMENTS §Perf,
    jamba row):
    - exp(dt·A) and dt·B·u are computed INSIDE the step, never materialized
      as (B,S,Di,N) tensors (N× the activation size, ~4.3 GB/layer on
      jamba-1.5-large);
    - the time loop is chunked with a remat boundary per chunk, so the
      backward saves the state every SCAN_CHUNK steps instead of every
      step (O(S/chunk) instead of O(S) saved states).
    """
    bsz, s, di = u.shape
    n = a.shape[-1]
    h_init = jnp.zeros((bsz, di, n), jnp.float32) if h0 is None else h0

    def step(h, inp):
        u_t, dt_t, bt, ct = inp             # (B,Di),(B,Di),(B,N),(B,N)
        return _ssm_step(h, u_t, dt_t, bt, ct, a)

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk(h, inp):
        return jax.lax.scan(step, h, inp)

    chunk_len = min(SCAN_CHUNK, s)
    if s % chunk_len:
        chunk_len = s  # odd lengths: single chunk
    nchunks = s // chunk_len

    def to_time(x):                          # (B,S,...) -> (nc, T, B, ...)
        x = x.swapaxes(0, 1).astype(jnp.float32)
        return x.reshape((nchunks, chunk_len) + x.shape[1:])

    xs = (to_time(u), to_time(dt), to_time(b_t), to_time(c_t))
    h_last, ys = jax.lax.scan(chunk, h_init, xs)
    y = ys.reshape((s, bsz, di)).swapaxes(0, 1)
    return (y + u.astype(jnp.float32) * d_skip[None, None]).astype(u.dtype), \
        h_last


def mamba_forward(params: dict, x: jax.Array, d: MambaDef, cfg: ModelConfig,
                  state: dict | None = None):
    """x: (B,S,D) -> (y, new_state). state = {"conv": (B,K-1,Di), "h": (B,Di,N)}."""
    b, s, _ = x.shape
    xz = apply_site(params["in_proj"], x, d.in_proj, cfg)
    xi, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xi, new_conv = _causal_conv(xi, params["conv_w"].astype(xi.dtype),
                                params["conv_b"].astype(xi.dtype), conv_state)
    xi = silu(xi)
    proj = apply_site(params["x_proj"], xi, d.x_proj, cfg)
    dt = proj[..., :d.dt_rank]
    b_t = proj[..., d.dt_rank:d.dt_rank + d.d_state].astype(jnp.float32)
    c_t = proj[..., d.dt_rank + d.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(apply_site(params["dt_proj"], dt, d.dt_proj, cfg)
                         .astype(jnp.float32))
    a = -jnp.exp(params["A_log"])
    h0 = None if state is None else state["h"]
    y, h_last = _selective_scan(xi.astype(jnp.float32), dt, a, b_t, c_t,
                                params["D"], h0)
    y = y.astype(x.dtype) * silu(z)
    out = apply_site(params["out_proj"], y, d.out_proj, cfg)
    return out, {"conv": new_conv.astype(x.dtype), "h": h_last}


def mamba_init_state(d: MambaDef, batch: int, dtype) -> dict:
    return {"conv": jnp.zeros((batch, d.d_conv - 1, d.d_inner), dtype),
            "h": jnp.zeros((batch, d.d_inner, d.d_state), jnp.float32)}


def mamba_decode_step(params: dict, x: jax.Array, d: MambaDef,
                      cfg: ModelConfig, state: dict):
    """Single-token Mamba decode against externally-held state (the serve
    engine's state-cache entry point). x: (B,1,D); state as
    ``mamba_init_state``. Returns (y (B,1,D), new_state).

    Runs ``_ssm_step`` directly — no ``lax.scan``, no remat wrapper — with
    the exact op sequence of ``mamba_forward`` at S=1, so continuous-batched
    decode is bit-identical to the static scan-carried loop."""
    xz = apply_site(params["in_proj"], x, d.in_proj, cfg)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, new_conv = _causal_conv(xi, params["conv_w"].astype(xi.dtype),
                                params["conv_b"].astype(xi.dtype),
                                state["conv"])
    xi = silu(xi)
    proj = apply_site(params["x_proj"], xi, d.x_proj, cfg)
    dt = proj[..., :d.dt_rank]
    b_t = proj[..., d.dt_rank:d.dt_rank + d.d_state].astype(jnp.float32)
    c_t = proj[..., d.dt_rank + d.d_state:].astype(jnp.float32)
    dt = jax.nn.softplus(apply_site(params["dt_proj"], dt, d.dt_proj, cfg)
                         .astype(jnp.float32))
    a = -jnp.exp(params["A_log"])
    u = xi.astype(jnp.float32)
    h_new, y = _ssm_step(state["h"], u[:, 0], dt[:, 0], b_t[:, 0], c_t[:, 0],
                         a)
    y = (y[:, None] + u * params["D"][None, None]).astype(u.dtype)
    y = y.astype(x.dtype) * silu(z)
    out = apply_site(params["out_proj"], y, d.out_proj, cfg)
    return out, {"conv": new_conv.astype(x.dtype), "h": h_new}


# ---------------------------------------------------------------------------
# RWKV6 "Finch"
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RWKV6Def:
    r: SiteDef
    k: SiteDef
    v: SiteDef
    g: SiteDef
    o: SiteDef
    w_lora_a: SiteDef       # D -> lora_dim
    w_lora_b: SiteDef       # lora_dim -> D
    ffn_k: SiteDef          # channel-mix
    ffn_v: SiteDef
    ffn_r: SiteDef
    num_heads: int
    head_dim: int


W_LORA_DIM = 64


def make_rwkv6(cfg: ModelConfig) -> RWKV6Def:
    hd = cfg.ssm.head_dim
    nh = cfg.d_model // hd
    return RWKV6Def(
        r=make_site(cfg, "ssm_proj", cfg.d_model, cfg.d_model),
        k=make_site(cfg, "ssm_proj", cfg.d_model, cfg.d_model),
        v=make_site(cfg, "ssm_proj", cfg.d_model, cfg.d_model),
        g=make_site(cfg, "ssm_proj", cfg.d_model, cfg.d_model),
        o=make_site(cfg, "ssm_proj", cfg.d_model, cfg.d_model),
        w_lora_a=make_site(cfg, "ssm_proj", W_LORA_DIM, cfg.d_model),
        w_lora_b=make_site(cfg, "ssm_proj", cfg.d_model, W_LORA_DIM),
        ffn_k=make_site(cfg, "ffn", cfg.d_ff, cfg.d_model),
        ffn_v=make_site(cfg, "ffn", cfg.d_model, cfg.d_ff),
        ffn_r=make_site(cfg, "ffn", cfg.d_model, cfg.d_model),
        num_heads=nh, head_dim=hd)


def init_rwkv6(key: jax.Array, d: RWKV6Def, cfg: ModelConfig) -> dict:
    ks = jax.random.split(key, 12)
    dm = cfg.d_model
    p = {
        "r": init_site(ks[0], d.r, cfg), "k": init_site(ks[1], d.k, cfg),
        "v": init_site(ks[2], d.v, cfg), "g": init_site(ks[3], d.g, cfg),
        "o": init_site(ks[4], d.o, cfg),
        "w_lora_a": init_site(ks[5], d.w_lora_a, cfg),
        "w_lora_b": init_site(ks[6], d.w_lora_b, cfg),
        "w0": jnp.linspace(-6.0, -1.0, dm, dtype=jnp.float32),   # decay base
        "u": (jax.random.normal(ks[7], (d.num_heads, d.head_dim), jnp.float32)
              * 0.1),
        # token-shift mix coefficients (per-channel, per-use)
        "mu_x": jnp.full((5, dm), 0.5, jnp.float32),
        "ffn_k": init_site(ks[8], d.ffn_k, cfg),
        "ffn_v": init_site(ks[9], d.ffn_v, cfg),
        "ffn_r": init_site(ks[10], d.ffn_r, cfg),
        "mu_ffn": jnp.full((2, dm), 0.5, jnp.float32),
        "ln_x_scale": jnp.ones((dm,), jnp.float32),
    }
    return p


def _token_shift(x: jax.Array, last: jax.Array | None):
    """shift(x)[t] = x[t-1]; returns (shifted, new_last)."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    shifted = jnp.concatenate([last, x[:, :-1]], axis=1)
    return shifted, x[:, -1:]


def _wkv6_step(s, rt, kt, vt, wt, u):
    """One WKV6 recurrence step: s (B,H,Dh,Dh) f32 state, rt/kt/vt/wt
    (B,H,Dh) f32, u (H,Dh) bonus. Returns (s_new, out (B,H,Dh)). Shared by
    the prefill scan and the serve engine's single-step decode."""
    kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
    out = jnp.einsum("bhk,bhkv->bhv", rt, s + u[None, :, :, None] * kv)
    s = wt[..., None] * s + kv
    return s, out


def _wkv6_scan(r, k, v, w, u, h0):
    """RWKV6 recurrence. r,k,v: (B,S,H,Dh); w: (B,S,H,Dh) decay in (0,1);
    u: (H,Dh) bonus. State S: (B,H,Dh_k,Dh_v).
      out_t = (S_{t-1} + diag(u·k_t outer) ) applied to r_t
      S_t   = diag(w_t) S_{t-1} + k_t^T v_t

    Chunked with a remat boundary per chunk: the backward otherwise saves
    the (B,H,Dh,Dh) state for every timestep (~137 GB on rwkv6-1.6b
    train_4k; EXPERIMENTS §Perf).
    """
    def step(s, inp):
        rt, kt, vt, wt = inp                         # (B,H,Dh)
        return _wkv6_step(s, rt, kt, vt, wt, u)

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk(s, inp):
        return jax.lax.scan(step, s, inp)

    bsz, s_len = r.shape[0], r.shape[1]
    chunk_len = min(SCAN_CHUNK, s_len)
    if s_len % chunk_len:
        chunk_len = s_len
    nchunks = s_len // chunk_len

    def to_time(x):
        x = x.swapaxes(0, 1).astype(jnp.float32)
        return x.reshape((nchunks, chunk_len) + x.shape[1:])

    h_last, outs = jax.lax.scan(chunk, h0, (to_time(r), to_time(k),
                                            to_time(v), to_time(w)))
    outs = outs.reshape((s_len,) + outs.shape[2:])
    return outs.swapaxes(0, 1), h_last               # (B,S,H,Dh), state


def rwkv6_time_mix(params, x, d: RWKV6Def, cfg: ModelConfig,
                   state: dict | None):
    b, s, dm = x.shape
    nh, hd = d.num_heads, d.head_dim
    last = None if state is None else state["shift"]
    xs, new_last = _token_shift(x, last)
    mu = params["mu_x"].astype(x.dtype)              # (5, D)
    xr, xk, xv, xw, xg = (x + (xs - x) * mu[i][None, None] for i in range(5))
    r = apply_site(params["r"], xr, d.r, cfg).reshape(b, s, nh, hd)
    k = apply_site(params["k"], xk, d.k, cfg).reshape(b, s, nh, hd)
    v = apply_site(params["v"], xv, d.v, cfg).reshape(b, s, nh, hd)
    g = apply_site(params["g"], xg, d.g, cfg)
    # data-dependent decay (the Finch contribution)
    dw = apply_site(params["w_lora_b"],
                    jnp.tanh(apply_site(params["w_lora_a"], xw, d.w_lora_a, cfg)),
                    d.w_lora_b, cfg)
    w = jnp.exp(-jnp.exp(params["w0"][None, None].astype(jnp.float32)
                         + dw.astype(jnp.float32)))   # (B,S,D) in (0,1)
    w = w.reshape(b, s, nh, hd)
    h0 = (jnp.zeros((b, nh, hd, hd), jnp.float32) if state is None
          else state["wkv"])
    out, h_last = _wkv6_scan(r, k, v, w, params["u"], h0)
    out = out.reshape(b, s, dm).astype(x.dtype)
    out = rms_norm(out, params["ln_x_scale"], cfg.norm_eps)   # group-norm proxy
    out = out * silu(g)
    y = apply_site(params["o"], out, d.o, cfg)
    return y, {"shift": new_last, "wkv": h_last}


def rwkv6_channel_mix(params, x, d: RWKV6Def, cfg: ModelConfig,
                      state: dict | None):
    last = None if state is None else state["shift_ffn"]
    xs, new_last = _token_shift(x, last)
    mu = params["mu_ffn"].astype(x.dtype)
    xk = x + (xs - x) * mu[0][None, None]
    xr = x + (xs - x) * mu[1][None, None]
    k = apply_site(params["ffn_k"], xk, d.ffn_k, cfg)
    k = jnp.square(jax.nn.relu(k))
    kv = apply_site(params["ffn_v"], k, d.ffn_v, cfg)
    r = jax.nn.sigmoid(apply_site(params["ffn_r"], xr, d.ffn_r, cfg))
    return r * kv, {"shift_ffn": new_last}


def rwkv6_time_mix_step(params, x, d: RWKV6Def, cfg: ModelConfig,
                        state: dict):
    """Single-token RWKV6 time-mix against externally-held state (the serve
    engine's state-cache entry point). x: (B,1,D). Returns (y, new state
    {"shift", "wkv"}). Runs ``_wkv6_step`` directly — the exact op sequence
    of ``rwkv6_time_mix`` at S=1 (token shift degenerates to the stored
    last token), so engine decode is bit-identical to the static loop."""
    b, s, dm = x.shape
    nh, hd = d.num_heads, d.head_dim
    xs, new_last = state["shift"], x[:, -1:]         # S=1 token shift
    mu = params["mu_x"].astype(x.dtype)              # (5, D)
    xr, xk, xv, xw, xg = (x + (xs - x) * mu[i][None, None] for i in range(5))
    r = apply_site(params["r"], xr, d.r, cfg).reshape(b, s, nh, hd)
    k = apply_site(params["k"], xk, d.k, cfg).reshape(b, s, nh, hd)
    v = apply_site(params["v"], xv, d.v, cfg).reshape(b, s, nh, hd)
    g = apply_site(params["g"], xg, d.g, cfg)
    dw = apply_site(params["w_lora_b"],
                    jnp.tanh(apply_site(params["w_lora_a"], xw, d.w_lora_a,
                                        cfg)),
                    d.w_lora_b, cfg)
    w = jnp.exp(-jnp.exp(params["w0"][None, None].astype(jnp.float32)
                         + dw.astype(jnp.float32)))
    w = w.reshape(b, s, nh, hd)
    h_last, out = _wkv6_step(state["wkv"],
                             r[:, 0].astype(jnp.float32),
                             k[:, 0].astype(jnp.float32),
                             v[:, 0].astype(jnp.float32),
                             w[:, 0].astype(jnp.float32), params["u"])
    out = out[:, None].reshape(b, s, dm).astype(x.dtype)
    out = rms_norm(out, params["ln_x_scale"], cfg.norm_eps)
    out = out * silu(g)
    y = apply_site(params["o"], out, d.o, cfg)
    return y, {"shift": new_last, "wkv": h_last}


def rwkv6_channel_mix_step(params, x, d: RWKV6Def, cfg: ModelConfig,
                           state: dict):
    """Single-token RWKV6 channel-mix (state-cache entry point). x: (B,1,D).
    Returns (y, {"shift_ffn"}). The channel mix has no recurrence beyond
    the token shift — at S=1 the generic path IS the single-step path
    (the shift degenerates to the stored last token), so delegate."""
    return rwkv6_channel_mix(params, x, d, cfg, state)


def rwkv6_init_state(d: RWKV6Def, batch: int, d_model: int, dtype) -> dict:
    return {
        "shift": jnp.zeros((batch, 1, d_model), dtype),
        "wkv": jnp.zeros((batch, d.num_heads, d.head_dim, d.head_dim),
                         jnp.float32),
        "shift_ffn": jnp.zeros((batch, 1, d_model), dtype),
    }

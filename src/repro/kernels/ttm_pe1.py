"""PE1 Pallas kernel — two-index tensor contraction over the *last* dims of
both operands (paper Eq. 5):

    Z'(a, d) = sum_{b, c}  Z(a, b, c) * G(b, d, c)

TPU adaptation (DESIGN.md §2): fold (b, c) into one contraction dim K.
Z(a,b,c) is already contiguous as (a, K); G(b,d,c) is re-laid-out once to
(K, d) outside the kernel (cores are KB-sized — the FPGA design also pre-lays
factors in BRAM). The kernel is then a K-accumulating tiled MXU matmul with
fp32 accumulation in VMEM scratch and an optional fused requantize epilogue
(the FPGA PE writes quantized results back to DRAM; we mirror that).

The epilogue body is NOT hand-rolled here: it comes from the codec registry
(``numerics.codecs`` pow2 ``epilogue``), so the fused writeback and the
unfused encode→decode reference path share one round/clip/scale
implementation — tests/test_kernels.py asserts they are bit-identical.

Grid: (M/bm, N/bn, K/bk), K iterates fastest (TPU sequential grid) so the
accumulator lives across the K steps of one (m, n) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..numerics.codecs import get_codec
from ..numerics.spec import QuantSpec


def _pe1_kernel(step_ref, z_ref, g_ref, o_ref, acc_ref, *, n_k: int,
                spec: QuantSpec | None):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        z_ref[...], g_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == n_k - 1)
    def _store():
        acc = acc_ref[...]
        if spec is not None:
            # registry-owned requant epilogue (kernel-safe jnp body)
            acc = get_codec(spec, "reference").epilogue(acc, spec, step_ref[0])
        o_ref[...] = acc.astype(o_ref.dtype)


def pe1_matmul(z2d: jax.Array, g2d: jax.Array, *, bm: int = 128, bn: int = 128,
               bk: int = 512, spec: QuantSpec | None = None,
               step_log2: jax.Array | float = 0.0,
               interpret: bool = True) -> jax.Array:
    """(M, K) @ (K, N) with fp32 accumulation; inputs must be pre-padded to
    block multiples (ops.py handles padding/unpadding). ``spec`` selects the
    fused requantize epilogue (pow2, ``spec.bits``-bit grid at
    ``step_log2``)."""
    m, k = z2d.shape
    k2, n = g2d.shape
    assert k == k2 and m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (z2d.shape, g2d.shape, bm, bn, bk)
    n_k = k // bk
    kernel = functools.partial(_pe1_kernel, n_k=n_k, spec=spec)
    step = jnp.asarray(step_log2, jnp.float32).reshape(1)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(m // bm, n // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk, step: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk, step: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk, step: (i, j)),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), z2d.dtype),
        interpret=interpret,
    )(step, z2d, g2d)

"""Jitted public wrappers around the Pallas kernels.

Handles padding to TPU-aligned block multiples (the TPU analogue of the
paper's "last dimension must be a multiple of 16" constraint), operand
re-layout for PE1, and interpret-mode selection (interpret=True on CPU where
the kernel body executes in Python for validation; compiled on real TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..numerics.pallas_backend import interpret_mode as _interpret
from ..numerics.pallas_backend import native_backend
from ..obs.counters import record_kernel_call
from . import paged_attention as PA
from . import ttm_pe1, ttm_pe2, ttm_pe3


def _nbytes(*arrs) -> int:
    """Modeled bytes moved by a kernel call: operand + result footprints
    from static shape/dtype (works on tracers — recorded at trace time, one
    entry per compiled specialization; see obs.counters.record_kernel_call)."""
    return sum(int(np.prod(a.shape)) * jnp.dtype(a.dtype).itemsize
               for a in arrs)


def _pad_to(x: jax.Array, mults: tuple[int, ...]) -> jax.Array:
    pads = [(0, (-s) % m) for s, m in zip(x.shape, mults)]
    if any(p[1] for p in pads):
        return jnp.pad(x, pads)
    return x


def _blk(dim: int, pref: int, floor: int) -> int:
    """Pick a block size <= pref that is a multiple of `floor`."""
    if dim >= pref:
        return pref
    return max(floor, ((dim + floor - 1) // floor) * floor)


@functools.partial(jax.jit, static_argnames=("bits", "impl"))
def pe1(z: jax.Array, g: jax.Array, step_log2: float | None = None,
        bits: int | None = None, impl: str = "pallas") -> jax.Array:
    """PE1 (Eq. 5): Z(a,b,c) x G(b,d,c) -> (a,d), optional fused requantize
    (``bits`` selects the pow2 grid at ``step_log2``; the epilogue body is
    the codec registry's, shared with the unfused reference path).

    impl: "pallas" (the kernel; compiled on TPU, interpret elsewhere — PE1
    is a training kernel, so unlike ``paged_attention`` there is no hot
    off-TPU serve path to protect and the kernel stays the default) or
    "jnp" — the registry-composed reference (einsum + codec encode→decode),
    the oracle the differential tests pin the fused epilogue against.

    Re-layout: G(b,d,c) -> (b*c, d); Z(a,b,c) -> (a, b*c). Cores are KB-sized
    so the one-off G transpose is free relative to the contraction.
    """
    from ..numerics import QuantSpec
    spec = QuantSpec("pow2", bits) if bits is not None else None
    step = 0.0 if step_log2 is None else step_log2
    record_kernel_call(f"pe1.{impl}", bytes_moved=_nbytes(z, g)
                       + z.shape[0] * g.shape[1] * z.dtype.itemsize)
    if impl == "jnp":
        from ..numerics.codecs import get_codec
        from . import ref
        with jax.named_scope("repro.ops.pe1"):
            acc = ref.pe1_ref(z, g).astype(jnp.float32)
            if spec is not None:
                acc = get_codec(spec, "reference").epilogue(
                    acc, spec, jnp.asarray(step, jnp.float32))
            return acc.astype(z.dtype)
    if impl != "pallas":
        raise ValueError(f"unknown pe1 impl {impl!r}")
    a, b, c = z.shape
    b2, d, c2 = g.shape
    assert b == b2 and c == c2, (z.shape, g.shape)
    with jax.named_scope("repro.ops.pe1"):
        zf = z.reshape(a, b * c)
        gf = jnp.transpose(g, (0, 2, 1)).reshape(b * c, d)
        bm = _blk(a, 128, 8)
        bn = _blk(d, 128, 128)
        bk = _blk(b * c, 512, 128)
        zp = _pad_to(zf, (bm, bk))
        gp = _pad_to(gf, (bk, bn))
        out = ttm_pe1.pe1_matmul(zp, gp, bm=bm, bn=bn, bk=bk, spec=spec,
                                 step_log2=step, interpret=_interpret())
        return out[:a, :d]


@jax.jit
def pe2(z: jax.Array, g: jax.Array) -> jax.Array:
    """PE2 (Eq. 6): Z(a,b,c) x G(b,d) -> (a,d,c)."""
    a, b, c = z.shape
    b2, d = g.shape
    assert b == b2, (z.shape, g.shape)
    record_kernel_call("pe2", bytes_moved=_nbytes(z, g)
                       + a * d * c * z.dtype.itemsize)
    with jax.named_scope("repro.ops.pe2"):
        ba = _blk(a, 8, 8)
        bd = _blk(d, 128, 128)
        bc = _blk(c, 128, 128)
        zp = _pad_to(z, (ba, 1, bc))
        gp = _pad_to(g, (1, bd))
        out = ttm_pe2.pe2_batched(zp, gp, ba=ba, bd=bd, bc=bc,
                                  interpret=_interpret())
        return out[:a, :d, :c]


@jax.jit
def pe3(ybar: jax.Array, x: jax.Array) -> jax.Array:
    """PE3: Ybar(b,j) x X(b,i) -> What(j,i) (batch-contracted outer product)."""
    b, j = ybar.shape
    b2, i = x.shape
    assert b == b2, (ybar.shape, x.shape)
    record_kernel_call("pe3", bytes_moved=_nbytes(ybar, x)
                       + j * i * ybar.dtype.itemsize)
    with jax.named_scope("repro.ops.pe3"):
        bj = _blk(j, 128, 8)
        bi = _blk(i, 128, 128)
        bb = _blk(b, 256, 8)
        yp = _pad_to(ybar, (bb, bj))
        xp = _pad_to(x, (bb, bi))
        out = ttm_pe3.pe3_outer(yp, xp, bj=bj, bi=bi, bb=bb,
                                interpret=_interpret())
        return out[:j, :i]


@functools.partial(jax.jit, static_argnames=("bits",))
def quantize_fused(x: jax.Array, step_log2: jax.Array, bits: int) -> jax.Array:
    """Fused fake-quant over an arbitrary-shape tensor — the pow2 Pallas
    codec of ``repro.numerics`` (which pads/reshapes internally)."""
    from ..numerics import QuantSpec, fake_quant
    record_kernel_call("quantize_fused", bytes_moved=2 * _nbytes(x))
    with jax.named_scope("repro.ops.quantize_fused"):
        return fake_quant(x, QuantSpec("pow2", bits), step_log2,
                          backend="pallas")


def _paged_attention_dispatch(q, kdata, vdata, kscale, vscale, table, lens,
                              *, page_size, quantized, impl, page_chunk):
    """impl-resolved page walk on whatever head slice it is handed — the
    whole pool, or one device's head shard under ``shard_map``."""
    if impl == "pallas":
        with jax.named_scope("repro.ops.paged_attention"):
            return PA.paged_attention_kernel(
                q, kdata, vdata, kscale, vscale, table, lens,
                page_size=page_size, quantized=quantized,
                interpret=_interpret())
    if impl == "jnp":
        if page_chunk is None:
            page_chunk = max(1, 256 // page_size)
        with jax.named_scope("repro.ops.paged_attention"):
            return PA.paged_attention_jnp(
                q, kdata, vdata, kscale, vscale, table, lens,
                page_size=page_size, quantized=quantized,
                page_chunk=page_chunk)
    raise ValueError(f"unknown paged_attention impl {impl!r}")


def paged_attention(q: jax.Array, kdata: jax.Array, vdata: jax.Array,
                    kscale: jax.Array, vscale: jax.Array, table: jax.Array,
                    lens: jax.Array, *, page_size: int, quantized: bool,
                    impl: str = "auto", page_chunk: int | None = None,
                    plan=None) -> jax.Array:
    """Fused paged attention: per-page int8 dequant + online-softmax
    attention over each slot's page list (never materializes the fp32 slot
    view). q is (B, Hq, Dh) for single-token decode or (B, S, Hq, Dh) for a
    q-block (chunked prefill / k-token speculative verify); ``lens`` is the
    position of the first query row either way. See
    ``kernels/paged_attention.py`` for layouts.

    impl: "pallas" (the kernel; compiled on TPU, interpret elsewhere),
    "jnp" (the same dataflow as a page-scan in XLA), or "auto" — the kernel
    on TPU (or when JAX_PALLAS_INTERPRET=1 asks for kernel validation), the
    jnp page-scan on other backends where interpret-mode grid iteration
    would serialize the hot loop.

    page_chunk (jnp impl only): pages folded per online-softmax step.
    1 is bit-locked to the kernel's update order; None picks ~256 tokens
    per step to amortize dispatch overhead off-TPU.

    plan (``sharding.ShardPlan``): when its mesh shards the pool's KV-head
    axis over ``model`` (``plan.shards_kv_heads``), the walk runs inside a
    ``shard_map`` — each device walks its local head shard of the pages
    with its local q heads and no collective at all (GQA query heads group
    contiguously per KV head, so shard-local attention is exact; the per-
    slot scales/table/lens are replicated operands). Numerics are those of
    the unsharded walk on each head slice — identical update order per
    head, so decode stays token-identical to single-device.
    """
    if impl == "auto":
        impl = "pallas" if native_backend() else "jnp"
    # bytes actually touched by the page walk: the whole pool row array is
    # an operand, but only each slot's mapped pages move — model the table-
    # addressable footprint (B * pages_per_slot pages) plus q in and out
    pages_touched = table.shape[0] * table.shape[1]
    page_bytes = (int(np.prod(kdata.shape[1:])) + int(np.prod(vdata.shape[1:]))
                  ) * jnp.dtype(kdata.dtype).itemsize
    record_kernel_call(f"paged_attention.{impl}",
                       bytes_moved=pages_touched * page_bytes
                       + 2 * _nbytes(q))
    f = functools.partial(_paged_attention_dispatch, page_size=page_size,
                          quantized=quantized, impl=impl,
                          page_chunk=page_chunk)
    hkv = kdata.shape[2]
    if plan is not None and plan.shards_kv_heads(hkv) \
            and q.shape[-2] % hkv == 0:
        from jax.sharding import PartitionSpec as P

        from ..sharding import compat_shard_map
        # q's head axis is -2 in both ranks: (B, Hq, Dh) decode or
        # (B, S, Hq, Dh) q-block
        qspec = (P(None, "model", None) if q.ndim == 3
                 else P(None, None, "model", None))
        f = compat_shard_map(
            f, plan.mesh,
            in_specs=(qspec,                           # q
                      P(None, None, "model", None),    # k pages
                      P(None, None, "model", None),    # v pages
                      P(None), P(None),                # per-slot scales
                      P(None, None), P(None)),         # table, lens
            out_specs=qspec)
    return f(q, kdata, vdata, kscale, vscale, table, lens)


def ttm_matvec_kernels(cores, x, spec):
    """TTM forward chain routed through the PE kernels (kernel-path analogue
    of ``core.ttm.ttm_matvec``). Used in tests and kernel benchmarks."""
    from ..core.ttm import ttm_matvec_pe

    def k_pe1(z, g):
        return pe1(z, g)

    def k_pe2(z, g):
        return pe2(z, g)

    return ttm_matvec_pe(cores, x, spec, pe1=k_pe1, pe2=k_pe2)

"""Fused pow-2 quantize-dequantize Pallas kernel (paper §3.2-3.3 numerics).

One VMEM pass: scale -> round -> clip -> dequantize. On the FPGA this is the
implicit writeback datapath of every PE; on TPU we expose it as a standalone
elementwise kernel (used on the BinaryConnect buffer after the optimizer step
and as the quant epilogue when not fused into PE1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _quant_kernel(x_ref, step_ref, o_ref, *, bits: int):
    scale = jnp.exp2(step_ref[0].astype(jnp.float32)).astype(x_ref.dtype)
    lo = -(2.0 ** (bits - 1))
    hi = 2.0 ** (bits - 1) - 1.0
    x = x_ref[...]
    o_ref[...] = (jnp.clip(jnp.round(x / scale), lo, hi) * scale).astype(o_ref.dtype)


def quantize(x2d: jax.Array, step_log2: jax.Array, bits: int, *,
             bm: int = 256, bn: int = 256, interpret: bool = True) -> jax.Array:
    """(M, N) fused fake-quant; pre-padded to block multiples."""
    m, n = x2d.shape
    assert m % bm == 0 and n % bn == 0, (x2d.shape, bm, bn)
    step = jnp.asarray(step_log2, jnp.float32).reshape(1)
    kernel = functools.partial(_quant_kernel, bits=bits)
    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x2d.dtype),
        interpret=interpret,
    )(x2d, step)

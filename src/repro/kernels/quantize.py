"""Fused pow-2 quantize-dequantize (paper §3.2-3.3 numerics) — compat shim.

The kernel now lives in the Pallas codec backend of the unified quantization
API (``repro.numerics.pallas_backend``); this module keeps the historical
entry point. Unlike the old kernel, padding to (bm, bn) block multiples is
handled *internally* — callers pass any (M, N) operand.
"""
from __future__ import annotations

import functools

import jax

from ..numerics.pallas_backend import _elementwise_2d, _p2_fq_kernel


def quantize(x2d: jax.Array, step_log2: jax.Array, bits: int, *,
             bm: int = 256, bn: int = 256, interpret: bool = True) -> jax.Array:
    """(M, N) fused fake-quant; pads to block multiples internally and
    slices the result back to (M, N). ``interpret`` is ignored (the codec
    backend selects it from the JAX backend)."""
    del interpret
    kernel = functools.partial(_p2_fq_kernel, bits=bits)
    return _elementwise_2d(kernel, x2d, step_log2, x2d.dtype, bm=bm, bn=bn)

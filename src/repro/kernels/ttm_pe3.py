"""PE3 Pallas kernel — batched outer product accumulating the full-weight
gradient (paper Appendix A.2):

    What(j, i) = sum_b  Ybar(b, j) * X(b, i)

The FPGA PE3 streams rank-1 outer products straight to DRAM because it is
DRAM-bandwidth-bound (16 multipliers, write-through, no caching). On TPU a
batched outer product IS a matmul contracting the batch dim — running it on
the MXU turns a bandwidth-bound loop into a compute-dense one (DESIGN.md §2
records this deliberate departure). Grid: (J/bj, I/bi, B/bb) with fp32
accumulation over the batch tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _pe3_kernel(y_ref, x_ref, o_ref, acc_ref, *, n_b: int):
    bstep = pl.program_id(2)

    @pl.when(bstep == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # y: (bb, bj)  x: (bb, bi)  -> contract batch (axis 0 of both)
    acc_ref[...] += jax.lax.dot_general(
        y_ref[...], x_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(bstep == n_b - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def pe3_outer(ybar: jax.Array, x: jax.Array, *, bj: int = 128, bi: int = 128,
              bb: int = 256, interpret: bool = True) -> jax.Array:
    """(B, J) x (B, I) -> (J, I); pre-padded to block multiples."""
    b, j = ybar.shape
    b2, i = x.shape
    assert b == b2 and j % bj == 0 and i % bi == 0 and b % bb == 0, \
        (ybar.shape, x.shape, bj, bi, bb)
    n_b = b // bb
    kernel = functools.partial(_pe3_kernel, n_b=n_b)
    return pl.pallas_call(
        kernel,
        grid=(j // bj, i // bi, n_b),
        in_specs=[
            pl.BlockSpec((bb, bj), lambda jj, ii, bs: (bs, jj)),
            pl.BlockSpec((bb, bi), lambda jj, ii, bs: (bs, ii)),
        ],
        out_specs=pl.BlockSpec((bj, bi), lambda jj, ii, bs: (jj, ii)),
        out_shape=jax.ShapeDtypeStruct((j, i), ybar.dtype),
        scratch_shapes=[pltpu.VMEM((bj, bi), jnp.float32)],
        interpret=interpret,
    )(ybar, x)

"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Signatures match the kernel wrappers in ``ops.py`` exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pe1_ref(z: jax.Array, g: jax.Array) -> jax.Array:
    """PE1 (paper Eq. 5): Z'(a,d) = sum_{b,c} Z(a,b,c) * G(b,d,c)."""
    return jnp.einsum("abc,bdc->ad", z.astype(jnp.float32),
                      g.astype(jnp.float32)).astype(z.dtype)


def pe2_ref(z: jax.Array, g: jax.Array) -> jax.Array:
    """PE2 (paper Eq. 6): Z'(a,d,c) = sum_b Z(a,b,c) * G(b,d)."""
    return jnp.einsum("abc,bd->adc", z.astype(jnp.float32),
                      g.astype(jnp.float32)).astype(z.dtype)


def pe3_ref(ybar: jax.Array, x: jax.Array) -> jax.Array:
    """PE3: What(j,i) = sum_b Ybar(b,j) * X(b,i) (batched outer product)."""
    return jnp.einsum("bj,bi->ji", ybar.astype(jnp.float32),
                      x.astype(jnp.float32)).astype(ybar.dtype)


def quantize_ref(x: jax.Array, step_log2: jax.Array, bits: int) -> jax.Array:
    """Fused pow-2 quantize-dequantize: clip(round(x/2^k)) * 2^k."""
    scale = jnp.exp2(step_log2.astype(jnp.float32)).astype(x.dtype)
    lo = -(2.0 ** (bits - 1))
    hi = 2.0 ** (bits - 1) - 1.0
    return (jnp.clip(jnp.round(x / scale), lo, hi) * scale).astype(x.dtype)


def pe1_quant_ref(z: jax.Array, g: jax.Array, step_log2: jax.Array,
                  bits: int) -> jax.Array:
    """PE1 with the FPGA-style requantize-on-writeback epilogue fused."""
    return quantize_ref(pe1_ref(z, g), step_log2, bits)

"""Fused paged-attention q-block kernel with in-kernel int8 dequantization.

The serve engine's hottest path used to gather every slot's *entire*
dequantized cache view (``kv_cache.gather_slots``: (B, max_len, *feat) fp32
per layer per tensor) before attending.  This module fuses the three steps —
page gather, pow-2 dequantize, attention — into one pass that walks each
slot's page list and accumulates online-softmax attention per page, so the
full-precision slot view is never materialized (the paper's §3.2 point that
low-precision storage only pays off when dequantization lives inside the
compute path; Tian et al. 2501.06663 make the same argument for transformer
attention caches).

The walk carries a q-block: S query rows per slot at consecutive positions
``lens[b] .. lens[b] + S - 1`` with a per-row causal length mask, so ONE
kernel serves single-token decode (S=1, the original dataflow), chunked
prefill (S=chunk), and k-token speculative verification (S=k+1).

Two implementations of the same dataflow:

- ``paged_attention_kernel``: the Pallas kernel.  Grid ``(num_slots,
  pages_per_slot)`` with the page table and length vector as scalar-prefetch
  operands — the BlockSpec index map chases the slot's page pointers, so
  each grid step DMAs exactly one int8 K and V page into VMEM, dequantizes
  with the slot's pow-2 scale in-register, and folds the page into the
  (m, l, acc) online-softmax state (now q-tiled: (S, Hq, ...)) held in VMEM
  scratch.  Grid steps for pages entirely above the block's LAST row
  (``lens[slot] + S - 1``) are predicated out (``pl.when``): a fully-masked
  page is the exact identity update, so short slots in a ragged batch skip
  their tail pages' dequant + MXU work for free (the grid is sized by
  ``pages_per_slot``, i.e. the longest possible slot).  Runs compiled on
  TPU; in interpret mode everywhere else (the differential-test oracle mode
  — see tests/test_paged_attention.py).
- ``paged_attention_jnp``: the identical page-walk written as a
  ``jax.lax.scan`` over pages in plain jnp.  Same per-page dequant, same
  online-softmax update order, so it is bit-locked against the kernel (the
  tests assert exact equality).  It is the engine's fused path off-TPU,
  where interpret-mode grid iteration would serialize poorly.

Numerics contract: per slot, query row j computes softmax(q_j·K^T * scale,
masked to ``pos <= lens[slot] + j``) @ V with KV heads expanded to the
query head count — the same math as ``gather_slots`` + ``models/attention
.py::gqa_attend`` with ``qpos = lens[slot] + j``, evaluated in f32 with an
online (per-page) softmax instead of a full-T one.  Greedy decode is
token-identical to the gather path; logits agree to float-roundoff
(asserted differentially).

Head-sharding contract: every head is independent (GQA groups the query
heads contiguously per KV head), so when the pool's KV-head axis is sharded
over the ``model`` mesh axis (``ShardPlan.shards_kv_heads``) the dispatcher
in ``ops.paged_attention`` shard_maps this walk — each device runs the
SAME kernel on its local head slice with zero collectives, and the
numerics above hold per shard unchanged.  Nothing in this module is
mesh-aware; the table/lens operands are replicated and page ids are global
(the page axis is never sharded).

Layouts (one attention sublayer, one layer of the scanned stack):

- q:        (B, S, Hq, Dh) f32 — S-row q-block per slot; a rank-3
            (B, Hq, Dh) q is accepted as the S=1 decode case and the
            result is returned rank-3 to match
- k/v data: (P+1, page, Hkv, Dh) int8 codes (quantized pool) or fp values;
            row ``P`` is the trash page absorbing inactive-slot writes
- scale:    (B,) f32 per-slot ``scale_log2`` (pow-2 grid, kv_cache site)
- table:    (B, pages_per_slot) int32 physical page ids (trash when unmapped)
- lens:     (B,) int32 position of the FIRST query row (row j attends keys
            at pos <= lens + j; unmapped pages sit entirely above the last
            row, so the mask also excludes trash-page junk for active slots)

TPU alignment note: compiled runs want Dh a multiple of 128 and page a
multiple of 8 (f32 sublane); the interpret path takes any shape.  The
wrapper in ``kernels/ops.py`` picks the implementation and leaves the pool
layout untouched — padding the pool per step would re-materialize exactly
the traffic this kernel exists to avoid.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _norm_q(q: jax.Array):
    """Accept (B, Hq, Dh) [legacy S=1 decode] or (B, S, Hq, Dh); return the
    rank-4 view plus whether to squeeze the S axis back out of the result."""
    if q.ndim == 3:
        return q[:, None], True
    if q.ndim == 4:
        return q, False
    raise ValueError(f"q must be rank 3 or 4, got {q.shape}")


def _block_update(m, l, acc, qf, k, v, base_pos, limit, scale):
    """One online-softmax step, shared VERBATIM by the Pallas kernel body
    (b=1, one page) and the jnp page-scan (full batch, a chunk of pages) —
    identical einsum shapes modulo the batch/page-chunk dims, which the
    CPU/interpret lowering treats as outer loops, is what keeps the two
    implementations bitwise-locked.

    qf: (b, S, Hkv, g, Dh) f32 queries in the grouped-head layout; k/v:
    (b, cp, Hkv, Dh) f32 (already dequantized, ``cp`` key positions
    starting at ``base_pos``); limit: (b, S) per-row causal limits (row j
    attends pos <= limit[:, j]); m/l: (b, S, Hq, 1); acc: (b, S, Hq, Dh).
    KV heads are never expanded: scores and values use grouped einsums over
    the (Hkv, g) query layout."""
    b, sq, hkv, g, dh = qf.shape
    cp = k.shape[1]
    hq = hkv * g
    s = jnp.einsum("bshgd,bphd->bshgp", qf, k,
                   preferred_element_type=jnp.float32) * scale
    pos = base_pos + jax.lax.broadcasted_iota(
        jnp.int32, (1, 1, 1, 1, cp), 4)
    s = jnp.where(pos <= limit[:, :, None, None, None], s, NEG_INF)
    s = s.reshape(b, sq, hq, cp)
    m_new = jnp.maximum(m, jnp.max(s, axis=3, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=3, keepdims=True)
    acc_new = acc * corr + jnp.einsum(
        "bshgp,bphd->bshgd", p.reshape(b, sq, hkv, g, cp), v,
        preferred_element_type=jnp.float32).reshape(b, sq, hq, dh)
    return m_new, l_new, acc_new


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _pa_kernel(tab_ref, lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
               m_ref, l_ref, acc_ref, *, page_size: int, num_pages: int,
               quantized: bool, scale: float, groups: int, q_rows: int):
    b, p = pl.program_id(0), pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # per-slot early exit: pages whose first position sits above the LAST
    # q-block row (lens + S - 1) carry no attendable keys — every score
    # would mask to NEG_INF, making the online-softmax update the exact
    # identity (m_new = m, corr = 1, p = exp(NEG_INF - m) = 0), so
    # predicating the whole update out is bitwise-free and skips the dequant
    # + MXU work for short slots in a long-slot batch (the grid is sized by
    # the longest).
    @pl.when(p * page_size <= lens_ref[b] + (q_rows - 1))
    def _update():
        q = q_ref[0].astype(jnp.float32)                # (S, Hq, Dh)
        k = k_ref[...]                                  # (1, page, Hkv, Dh)
        v = v_ref[...]
        if quantized:
            # in-kernel pow-2 dequant: one multiply per element, straight
            # from the int8 page in VMEM — no fp32 page ever round-trips
            # through HBM
            k = k.astype(jnp.float32) * jnp.exp2(ks_ref[b])
            v = v.astype(jnp.float32) * jnp.exp2(vs_ref[b])
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        sq, hq, dh = q.shape
        hkv = k.shape[2]
        qf = q.reshape(1, sq, hkv, groups, dh)
        limit = lens_ref[b] + jax.lax.broadcasted_iota(
            jnp.int32, (1, sq), 1)
        m_new, l_new, acc_new = _block_update(
            m_ref[...][None], l_ref[...][None], acc_ref[...][None],
            qf, k, v, p * page_size, limit, scale)
        m_ref[...] = m_new[0]
        l_ref[...] = l_new[0]
        acc_ref[...] = acc_new[0]

    @pl.when(p == num_pages - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def paged_attention_kernel(q: jax.Array, kdata: jax.Array, vdata: jax.Array,
                           kscale: jax.Array, vscale: jax.Array,
                           table: jax.Array, lens: jax.Array, *,
                           page_size: int, quantized: bool,
                           interpret: bool = False) -> jax.Array:
    """Fused paged attention via Pallas. Shapes per module docstring;
    returns (B, S, Hq, Dh) in q.dtype ((B, Hq, Dh) for rank-3 q)."""
    q, squeeze = _norm_q(q)
    b, sq, hq, dh = q.shape
    pp = table.shape[1]
    hkv = kdata.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # page table + length vector
        grid=(b, pp),
        in_specs=[
            pl.BlockSpec((1, sq, hq, dh),
                         lambda bi, pi, tab, ln: (bi, 0, 0, 0)),
            # the page-pointer chase: block (pi of slot bi) is physical page
            # tab[bi, pi] — unmapped entries point at the trash page, whose
            # positions all sit above lens[bi] and mask to NEG_INF
            pl.BlockSpec((1, page_size, hkv, dh),
                         lambda bi, pi, tab, ln: (tab[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, page_size, hkv, dh),
                         lambda bi, pi, tab, ln: (tab[bi, pi], 0, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, sq, hq, dh),
                               lambda bi, pi, tab, ln: (bi, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((sq, hq, 1), jnp.float32),       # running max
            pltpu.VMEM((sq, hq, 1), jnp.float32),       # running denom
            pltpu.VMEM((sq, hq, dh), jnp.float32),      # running numerator
        ],
    )
    kern = functools.partial(
        _pa_kernel, page_size=page_size, num_pages=pp, quantized=quantized,
        scale=1.0 / math.sqrt(dh), groups=hq // hkv, q_rows=sq)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, sq, hq, dh), q.dtype),
        interpret=interpret,
    )(table, lens, q, kdata, vdata,
      jnp.asarray(kscale, jnp.float32), jnp.asarray(vscale, jnp.float32))
    return out[:, 0] if squeeze else out


# ---------------------------------------------------------------------------
# jnp page-scan — the same dataflow in XLA (engine fallback off-TPU)
# ---------------------------------------------------------------------------

def paged_attention_jnp(q: jax.Array, kdata: jax.Array, vdata: jax.Array,
                        kscale: jax.Array, vscale: jax.Array,
                        table: jax.Array, lens: jax.Array, *,
                        page_size: int, quantized: bool,
                        page_chunk: int = 1) -> jax.Array:
    """Page-walk online-softmax q-block attention as a ``lax.scan`` over the
    page axis, in plain jnp.  Per step it loads ``page_chunk`` int8 pages
    per slot, dequantizes, and folds them into the (m, l, acc) state.  With
    ``page_chunk=1`` this is the kernel's exact per-page update order (the
    bit-lock the differential tests assert); larger chunks amortize the
    scan's dispatch overhead on non-TPU backends while peak residency stays
    bounded by the chunk — the (B, max_len, *feat) fp32 slot view is never
    materialized either way.  KV heads are never expanded: scores and
    values use grouped einsums over the (Hkv, g) query layout."""
    q, squeeze = _norm_q(q)
    b, sq, hq, dh = q.shape
    pp = table.shape[1]
    hkv = kdata.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    c = max(1, min(page_chunk, pp))
    nsteps = -(-pp // c)
    # rebalance the chunk so tail padding stays minimal (36 pages at chunk
    # 16 would pad to 48 — 33% wasted positions; balanced: 3 chunks of 12,
    # zero pad). page_chunk=1 is unaffected (nsteps == pp), preserving the
    # bit-lock against the kernel.
    c = -(-pp // nsteps)
    if nsteps * c != pp:
        # pad the logical page axis with trash-page pointers; their
        # positions sit above every slot's length and mask to NEG_INF
        trash = kdata.shape[0] - 1
        table = jnp.pad(table, ((0, 0), (0, nsteps * c - pp)),
                        constant_values=trash)
    qf = q.astype(jnp.float32).reshape(b, sq, hkv, g, dh)
    ks = jnp.exp2(jnp.asarray(kscale, jnp.float32))
    vs = jnp.exp2(jnp.asarray(vscale, jnp.float32))
    # per-row causal limits: row j of the q-block attends pos <= lens + j
    limit = lens[:, None] + jnp.arange(sq)[None, :]         # (B, S)

    def body(carry, step):
        m, l, acc = carry
        pages = jax.lax.dynamic_slice_in_dim(table, step * c, c, axis=1)
        k = kdata[pages]                        # (B, c, page, Hkv, Dh)
        v = vdata[pages]
        if quantized:
            k = k.astype(jnp.float32) * ks[:, None, None, None, None]
            v = v.astype(jnp.float32) * vs[:, None, None, None, None]
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        k = k.reshape(b, c * page_size, hkv, dh)
        v = v.reshape(b, c * page_size, hkv, dh)
        return _block_update(m, l, acc, qf, k, v, step * (c * page_size),
                             limit, scale), None

    m0 = jnp.full((b, sq, hq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, hq, 1), jnp.float32)
    a0 = jnp.zeros((b, sq, hq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nsteps))
    out = (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)
    return out[:, 0] if squeeze else out

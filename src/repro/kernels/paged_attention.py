"""Fused paged-attention decode kernel with in-kernel int8 dequantization.

The serve engine's hottest path used to gather every slot's *entire*
dequantized cache view (``kv_cache.gather_slots``: (B, max_len, *feat) fp32
per layer per tensor) before attending.  This module fuses the three steps —
page gather, pow-2 dequantize, attention — into one pass that walks each
slot's page list and accumulates online-softmax attention per page, so the
full-precision slot view is never materialized (the paper's §3.2 point that
low-precision storage only pays off when dequantization lives inside the
compute path; Tian et al. 2501.06663 make the same argument for transformer
attention caches).

Two implementations of the same dataflow:

- ``paged_attention_kernel``: the Pallas kernel.  Grid ``(num_slots,
  pages_per_slot)`` with the page table and length vector as scalar-prefetch
  operands — the BlockSpec index map chases the slot's page pointers, so
  each grid step DMAs exactly one int8 K and V page into VMEM, dequantizes
  with the slot's pow-2 scale in-register, and folds the page into the
  (m, l, acc) online-softmax state held in VMEM scratch.  Grid steps for
  pages entirely above ``lens[slot]`` are predicated out (``pl.when``): a
  fully-masked page is the exact identity update, so short slots in a
  ragged batch skip their tail pages' dequant + MXU work for free (the
  grid is sized by ``pages_per_slot``, i.e. the longest possible slot).
  Runs compiled on TPU; in interpret mode everywhere else (the
  differential-test oracle mode — see tests/test_paged_attention.py).
- ``paged_attention_jnp``: the identical page-walk written as a
  ``jax.lax.scan`` over pages in plain jnp.  Same per-page dequant, same
  online-softmax update order, so it is bit-locked against the kernel (the
  tests assert exact equality).  It is the engine's fused path off-TPU,
  where interpret-mode grid iteration would serialize poorly.

Numerics contract: per slot the computation is softmax(q·K^T * scale,
masked to ``pos <= lens[slot]``) @ V with KV heads expanded to the query
head count — the same math as ``gather_slots`` + ``models/attention.py::
gqa_attend``, evaluated in f32 with an online (per-page) softmax instead of
a full-T one.  Greedy decode is token-identical to the gather path; logits
agree to float-roundoff (asserted differentially).

Head-sharding contract: every head is independent (GQA groups the query
heads contiguously per KV head), so when the pool's KV-head axis is sharded
over the ``model`` mesh axis (``ShardPlan.shards_kv_heads``) the dispatcher
in ``ops.paged_attention`` shard_maps this walk — each device runs the
SAME kernel on its local head slice with zero collectives, and the
numerics above hold per shard unchanged.  Nothing in this module is
mesh-aware; the table/lens operands are replicated and page ids are global
(the page axis is never sharded).

Layouts (one attention sublayer, one layer of the scanned stack):

- q:        (B, Hq, Dh)   f32 — one decode query per slot
- k/v data: (P+1, page, Hkv, Dh) int8 codes (quantized pool) or fp values;
            row ``P`` is the trash page absorbing inactive-slot writes
- scale:    (B,) f32 per-slot ``scale_log2`` (pow-2 grid, kv_cache site)
- table:    (B, pages_per_slot) int32 physical page ids (trash when unmapped)
- lens:     (B,) int32 position of the incoming token (keys at pos <= lens
            attend; unmapped pages sit entirely above lens, so the mask also
            excludes trash-page junk for active slots)

TPU alignment note: compiled runs want Dh a multiple of 128 and page a
multiple of 8 (f32 sublane); the interpret path takes any shape.  The
wrapper in ``kernels/ops.py`` picks the implementation and leaves the pool
layout untouched — padding the pool per step would re-materialize exactly
the traffic this kernel exists to avoid.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _expand_kv(x: jax.Array, groups: int) -> jax.Array:
    """(page, Hkv, Dh) -> (page, Hkv*groups, Dh), repeating each KV head
    ``groups`` times consecutively (matches ``gqa_attend``'s (hkv, g) query
    grouping; broadcast+reshape instead of jnp.repeat for TPU lowering)."""
    if groups == 1:
        return x
    pg, hkv, dh = x.shape
    return jnp.broadcast_to(x[:, :, None, :], (pg, hkv, groups, dh)).reshape(
        pg, hkv * groups, dh)


def _online_update(m, l, acc, s, v):
    """One online-softmax step: fold scores s (Hq, page) and values
    v (page, Hq, Dh) into the running (m (Hq,1), l (Hq,1), acc (Hq,Dh))."""
    m_new = jnp.maximum(m, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m - m_new)
    l_new = l * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_new = acc * corr + jnp.einsum("hp,phd->hd", p, v,
                                      preferred_element_type=jnp.float32)
    return m_new, l_new, acc_new


def _page_scores(q, k, page_idx, page_size, length, scale):
    """Masked scores of one page. q (Hq, Dh) f32, k (page, Hq, Dh) f32."""
    s = jnp.einsum("hd,phd->hp", q, k,
                   preferred_element_type=jnp.float32) * scale
    pos = page_idx * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)
    return jnp.where(pos <= length, s, NEG_INF)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _pa_kernel(tab_ref, lens_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
               m_ref, l_ref, acc_ref, *, page_size: int, num_pages: int,
               quantized: bool, scale: float, groups: int):
    b, p = pl.program_id(0), pl.program_id(1)

    @pl.when(p == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # per-slot early exit: pages whose first position sits above the slot's
    # incoming token carry no attendable keys — every score would mask to
    # NEG_INF, making the online-softmax update the exact identity
    # (m_new = m, corr = 1, p = exp(NEG_INF - m) = 0), so predicating the
    # whole update out is bitwise-free and skips the dequant + MXU work for
    # short slots in a long-slot batch (the grid is sized by the longest).
    @pl.when(p * page_size <= lens_ref[b])
    def _update():
        q = q_ref[0].astype(jnp.float32)                # (Hq, Dh)
        k = k_ref[0]                                    # (page, Hkv, Dh)
        v = v_ref[0]
        if quantized:
            # in-kernel pow-2 dequant: one multiply per element, straight
            # from the int8 page in VMEM — no fp32 page ever round-trips
            # through HBM
            k = k.astype(jnp.float32) * jnp.exp2(ks_ref[b])
            v = v.astype(jnp.float32) * jnp.exp2(vs_ref[b])
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        kx = _expand_kv(k, groups)
        vx = _expand_kv(v, groups)
        s = _page_scores(q, kx, p, page_size, lens_ref[b], scale)
        m_new, l_new, acc_new = _online_update(m_ref[...], l_ref[...],
                                               acc_ref[...], s, vx)
        m_ref[...] = m_new
        l_ref[...] = l_new
        acc_ref[...] = acc_new

    @pl.when(p == num_pages - 1)
    def _emit():
        o_ref[0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                    ).astype(o_ref.dtype)


def paged_attention_kernel(q: jax.Array, kdata: jax.Array, vdata: jax.Array,
                           kscale: jax.Array, vscale: jax.Array,
                           table: jax.Array, lens: jax.Array, *,
                           page_size: int, quantized: bool,
                           interpret: bool = False) -> jax.Array:
    """Fused paged attention via Pallas. Shapes per module docstring;
    returns (B, Hq, Dh) in q.dtype."""
    b, hq, dh = q.shape
    pp = table.shape[1]
    hkv = kdata.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # page table + length vector
        grid=(b, pp),
        in_specs=[
            pl.BlockSpec((1, hq, dh), lambda bi, pi, tab, ln: (bi, 0, 0)),
            # the page-pointer chase: block (pi of slot bi) is physical page
            # tab[bi, pi] — unmapped entries point at the trash page, whose
            # positions all sit above lens[bi] and mask to NEG_INF
            pl.BlockSpec((1, page_size, hkv, dh),
                         lambda bi, pi, tab, ln: (tab[bi, pi], 0, 0, 0)),
            pl.BlockSpec((1, page_size, hkv, dh),
                         lambda bi, pi, tab, ln: (tab[bi, pi], 0, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, hq, dh),
                               lambda bi, pi, tab, ln: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((hq, 1), jnp.float32),           # running max
            pltpu.VMEM((hq, 1), jnp.float32),           # running denom
            pltpu.VMEM((hq, dh), jnp.float32),          # running numerator
        ],
    )
    kern = functools.partial(
        _pa_kernel, page_size=page_size, num_pages=pp, quantized=quantized,
        scale=1.0 / math.sqrt(dh), groups=hq // hkv)
    return pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, dh), q.dtype),
        interpret=interpret,
    )(table, lens, q, kdata, vdata,
      jnp.asarray(kscale, jnp.float32), jnp.asarray(vscale, jnp.float32))


# ---------------------------------------------------------------------------
# jnp page-scan — the same dataflow in XLA (engine fallback off-TPU)
# ---------------------------------------------------------------------------

def paged_attention_jnp(q: jax.Array, kdata: jax.Array, vdata: jax.Array,
                        kscale: jax.Array, vscale: jax.Array,
                        table: jax.Array, lens: jax.Array, *,
                        page_size: int, quantized: bool,
                        page_chunk: int = 1) -> jax.Array:
    """Page-walk online-softmax attention as a ``lax.scan`` over the page
    axis, in plain jnp.  Per step it loads ``page_chunk`` int8 pages per
    slot, dequantizes, and folds them into the (m, l, acc) state.  With
    ``page_chunk=1`` this is the kernel's exact per-page update order (the
    bit-lock the differential tests assert); larger chunks amortize the
    scan's dispatch overhead on non-TPU backends while peak residency stays
    bounded by the chunk — the (B, max_len, *feat) fp32 slot view is never
    materialized either way.  KV heads are never expanded: scores and
    values use grouped einsums over the (Hkv, g) query layout."""
    b, hq, dh = q.shape
    pp = table.shape[1]
    hkv = kdata.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    c = max(1, min(page_chunk, pp))
    nsteps = -(-pp // c)
    # rebalance the chunk so tail padding stays minimal (36 pages at chunk
    # 16 would pad to 48 — 33% wasted positions; balanced: 3 chunks of 12,
    # zero pad). page_chunk=1 is unaffected (nsteps == pp), preserving the
    # bit-lock against the kernel.
    c = -(-pp // nsteps)
    if nsteps * c != pp:
        # pad the logical page axis with trash-page pointers; their
        # positions sit above every slot's length and mask to NEG_INF
        trash = kdata.shape[0] - 1
        table = jnp.pad(table, ((0, 0), (0, nsteps * c - pp)),
                        constant_values=trash)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, dh)
    ks = jnp.exp2(jnp.asarray(kscale, jnp.float32))
    vs = jnp.exp2(jnp.asarray(vscale, jnp.float32))

    def body(carry, step):
        m, l, acc = carry
        pages = jax.lax.dynamic_slice_in_dim(table, step * c, c, axis=1)
        k = kdata[pages]                        # (B, c, page, Hkv, Dh)
        v = vdata[pages]
        if quantized:
            k = k.astype(jnp.float32) * ks[:, None, None, None, None]
            v = v.astype(jnp.float32) * vs[:, None, None, None, None]
        else:
            k = k.astype(jnp.float32)
            v = v.astype(jnp.float32)
        k = k.reshape(b, c * page_size, hkv, dh)
        v = v.reshape(b, c * page_size, hkv, dh)
        s = jnp.einsum("bhgd,bphd->bhgp", qf, k,
                       preferred_element_type=jnp.float32) * scale
        pos = step * (c * page_size) + jnp.arange(c * page_size)
        s = jnp.where(pos[None, None, None, :] <= lens[:, None, None, None],
                      s, NEG_INF)
        s = s.reshape(b, hq, c * page_size)
        m_new = jnp.maximum(m, jnp.max(s, axis=2, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=2, keepdims=True)
        acc_new = acc * corr + jnp.einsum(
            "bhgp,bphd->bhgd", p.reshape(b, hkv, g, c * page_size), v,
            preferred_element_type=jnp.float32).reshape(b, hq, dh)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, 1), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, 1), jnp.float32)
    a0 = jnp.zeros((b, hq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(nsteps))
    return (acc / jnp.maximum(l, 1e-30)).astype(q.dtype)

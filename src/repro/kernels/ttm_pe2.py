"""PE2 Pallas kernel — single-index contraction over a *middle* dim
(paper Eq. 6):

    Z'(a, d, c) = sum_b  Z(a, b, c) * G(b, d)

TPU adaptation: `c` is the minor (lane) dimension of both Z and Z' — the
analogue of the paper's "last dim must be a multiple of 16" rule becomes
"c padded to 128 lanes". The contraction dim b is second-minor for Z.
Per grid step we load Z(a-tile, B, c-tile) and G(B, d-tile) into VMEM and
issue dot_general contracting b with batch dim c mapped across lanes.

b (= I_n * R_n in the chain) is small in TTM layers, so it is NOT tiled:
one grid step consumes all of b — this matches the FPGA PE2 which streams
the full b extent through the MAC array per (c, d) tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pe2_kernel(z_ref, g_ref, o_ref):
    # z: (ba, b, bc)   g: (b, bd)   ->  o: (ba, bd, bc)
    z = z_ref[...]
    g = g_ref[...]
    # contract b: dot_general(g^T (bd, b), z (ba, b, bc)) with z's b as
    # contracting — produce (ba, bd, bc) directly via per-a matmuls:
    # (b, bd)^T @ (b, bc) batched over a.
    out = jax.lax.dot_general(
        z, g,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (ba, bc, bd)
    o_ref[...] = jnp.transpose(out, (0, 2, 1)).astype(o_ref.dtype)


def pe2_batched(z3d: jax.Array, g2d: jax.Array, *, ba: int = 8, bd: int = 128,
                bc: int = 128, interpret: bool = True) -> jax.Array:
    """(A, B, C) x (B, D) -> (A, D, C); pre-padded to block multiples."""
    a, b, c = z3d.shape
    b2, d = g2d.shape
    assert b == b2 and a % ba == 0 and c % bc == 0 and d % bd == 0, \
        (z3d.shape, g2d.shape, ba, bd, bc)
    return pl.pallas_call(
        _pe2_kernel,
        grid=(a // ba, d // bd, c // bc),
        in_specs=[
            pl.BlockSpec((ba, b, bc), lambda i, j, kk: (i, 0, kk)),
            pl.BlockSpec((b, bd), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((ba, bd, bc), lambda i, j, kk: (i, j, kk)),
        out_shape=jax.ShapeDtypeStruct((a, d, c), z3d.dtype),
        interpret=interpret,
    )(z3d, g2d)

"""Counter registry + quant-health aggregates — the counter half of
``repro.obs``.

Two kinds of counter live here, matching where the information exists:

- **Host counters** (``CounterRegistry``): plain named integers incremented
  from Python — codec fallbacks, kernel trace events, recorder drops. The
  registry replaces ad-hoc module globals (``pallas_backend._FALLBACKS`` is
  now the ``numerics.codec_fallback`` counter; its ``fallback_count()`` /
  ``reset_fallback_count()`` API is preserved as a thin view). Counters
  here are *trace-time* for anything called under ``jax.jit`` — a kernel
  wrapper's Python body runs once per compiled specialization, so
  ``kernel.*.calls`` counts traced calls, not device executions (that is
  exactly the granularity the autotuner/bench consumers need: one row per
  (kernel, shape) with its modeled cost).

- **Device aggregates** (``pow2_clip_stats`` & friends): jit-safe scalar
  reductions computed next to a quantization site — clip/saturation counts
  and scale-drift sums. They are integer-exact, so the reference and Pallas
  codec backends agree BITWISE on the counts (asserted by tests/test_obs.py
  — both backends produce bit-identical codes, and the counts are pure
  functions of values + scale). Everything is off-by-default: a step
  function only traces these when its policy/engine asks for health
  (``NumericsPolicy.health``), so the disabled path's jaxpr is unchanged.

Interpretation guide (what the numbers mean) lives in README
"Observability"; the short version: ``clip_fraction`` is the fraction of
pre-quant values outside the representable range (persistent > ~1e-2 on the
KV site means decode amplitudes outgrew the prefill-frozen scale),
``sat_fraction`` the fraction of *codes* pinned at the grid edge (the
post-hoc view of the same failure), ``scale_drift`` the mean |Δlog2| of
re-chosen per-tensor scales (state-cache amplitude dynamics).
"""
from __future__ import annotations

import threading

import jax
import jax.numpy as jnp

from ..numerics.codecs import _bcast
from ..numerics.spec import QTensor, QuantSpec, qrange

# ---------------------------------------------------------------------------
# Host counter registry
# ---------------------------------------------------------------------------


class CounterRegistry:
    """Named monotonic host counters. Thread-safe, cheap, process-local.

    Names are dotted paths (``numerics.codec_fallback``,
    ``kernel.pe1.calls``); ``snapshot()`` returns a plain dict for
    JSON emission.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._c: dict[str, int] = {}

    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._c[name] = self._c.get(name, 0) + n

    def get(self, name: str) -> int:
        return self._c.get(name, 0)

    def reset(self, name: str | None = None) -> None:
        """Reset one counter, or every counter when ``name`` is None."""
        with self._lock:
            if name is None:
                self._c.clear()
            else:
                self._c.pop(name, None)

    def snapshot(self, prefix: str = "") -> dict[str, int]:
        with self._lock:
            return {k: v for k, v in sorted(self._c.items())
                    if k.startswith(prefix)}


#: Process-default registry — the one ``repro.numerics`` and
#: ``repro.kernels`` report into.
registry = CounterRegistry()


def record_kernel_call(name: str, *, bytes_moved: int = 0,
                       flops: int = 0) -> None:
    """Note one traced call of a wrapped kernel with its modeled cost.

    Called from the kernel wrappers' Python bodies (``kernels/ops.py``), so
    under jit this fires once per compiled specialization — the per-(kernel,
    shape) cost table benches and the future autotuner read via
    ``kernel_costs()``."""
    registry.inc(f"kernel.{name}.calls")
    if bytes_moved:
        registry.inc(f"kernel.{name}.bytes", bytes_moved)
    if flops:
        registry.inc(f"kernel.{name}.flops", flops)


def kernel_costs() -> dict[str, dict[str, int]]:
    """Per-kernel cost table: {kernel: {calls, bytes, flops}}."""
    out: dict[str, dict[str, int]] = {}
    for k, v in registry.snapshot("kernel.").items():
        name, field = k[len("kernel."):].rsplit(".", 1)
        out.setdefault(name, {})[field] = v
    return out


# ---------------------------------------------------------------------------
# Device aggregates (jit-safe, integer-exact)
# ---------------------------------------------------------------------------

def pow2_clip_stats(x: jax.Array, scale_log2, bits: int,
                    valid: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
    """(clipped, total) int32 counts of ``x`` against the pow-2 grid at
    ``scale_log2`` (leading-dim broadcast, the codec ``_bcast`` convention).

    ``clipped`` counts pre-quant values strictly outside the representable
    code range — the elements ``encode``/``fake_quant`` would saturate.
    ``valid`` (optional, broadcastable bool) restricts both counts to real
    rows (active slots; padding never counts). Integer-exact, so every
    backend agrees bitwise."""
    lo, hi = qrange(bits)
    step = jnp.exp2(_bcast(jnp.asarray(scale_log2), x.ndim)
                    .astype(jnp.float32))
    r = x.astype(jnp.float32) / step
    outside = (r < lo) | (r > hi)
    if valid is None:
        return (jnp.sum(outside.astype(jnp.int32)),
                jnp.asarray(x.size, jnp.int32))
    v = jnp.broadcast_to(valid, outside.shape)
    return (jnp.sum((outside & v).astype(jnp.int32)),
            jnp.sum(v.astype(jnp.int32)))


def saturation_counts(qt: QTensor) -> tuple[jax.Array, jax.Array]:
    """(saturated, total) int32 counts of codes pinned at the grid edge of
    an encoded ``QTensor`` — the post-hoc view of ``pow2_clip_stats``
    (saturated >= clipped: a value exactly at the edge rounds onto it
    without having been clipped). Packed int4x2 codes are unpacked first so
    the count is over logical codes, not stored bytes."""
    spec = qt.spec
    codes = qt.codes
    if spec.kind == "pow2" and spec.packed:
        from ..numerics.codecs import unpack_int4
        codes = unpack_int4(codes, qt.shape[-1] if qt.shape else 1)
    if spec.kind == "pow2":
        lo, hi = qrange(spec.bits)
    else:   # blockwise: symmetric ±qmax
        lo, hi = -spec.qmax, spec.qmax
    c = codes.astype(jnp.int32)
    sat = jnp.sum(((c <= int(lo)) | (c >= int(hi))).astype(jnp.int32))
    return sat, jnp.asarray(c.size, jnp.int32)


def scale_drift_stats(old_log2: jax.Array, new_log2: jax.Array,
                      valid: jax.Array | None = None
                      ) -> tuple[jax.Array, jax.Array]:
    """(|Δlog2| sum, count) of a re-chosen per-tensor scale array — the
    state-cache drift statistic (how fast recurrent-state amplitude moves
    across the pow-2 grid). f32 sum over ``valid`` entries."""
    d = jnp.abs(new_log2.astype(jnp.float32) - old_log2.astype(jnp.float32))
    if valid is None:
        return jnp.sum(d), jnp.asarray(d.size, jnp.float32)
    v = jnp.broadcast_to(valid, d.shape).astype(jnp.float32)
    return jnp.sum(d * v), jnp.sum(v)


def tree_sat_stats(tree, spec: QuantSpec,
                   scale_for=None) -> tuple[jax.Array, jax.Array]:
    """(saturated, total) over every float leaf of ``tree`` encoded under
    ``spec`` — the grad_edge/dp_wire health aggregate. ``scale_for(leaf)``
    supplies the pow2 scale per leaf (defaults to per-tensor-max, the
    clip-free scale the step factories use)."""
    from ..numerics.codecs import encode, per_tensor_max_scale_log2

    def is_f(g):
        return hasattr(g, "dtype") and g.dtype != jax.dtypes.float0 \
            and jnp.issubdtype(g.dtype, jnp.floating)

    sat = jnp.asarray(0, jnp.int32)
    tot = jnp.asarray(0, jnp.int32)
    for leaf in jax.tree_util.tree_leaves(tree):
        if not is_f(leaf):
            continue
        if spec.kind == "pow2":
            step = (per_tensor_max_scale_log2(leaf, spec)
                    if scale_for is None else scale_for(leaf))
            qt = encode(leaf, spec, step)
        else:
            qt = encode(leaf.reshape(-1), spec)
        s, t = saturation_counts(qt)
        sat, tot = sat + s, tot + t
    return sat, tot


def fraction(count: jax.Array, total: jax.Array) -> jax.Array:
    """count / total as f32, 0 when total == 0 (jit-safe)."""
    t = jnp.asarray(total, jnp.float32)
    return jnp.where(t > 0, jnp.asarray(count, jnp.float32)
                     / jnp.maximum(t, 1.0), 0.0)

"""Trace export: JSONL for machines, Chrome trace format for Perfetto.

JSONL is the archival form — one event per line, ``{"ts", "kind",
**fields}`` — streamed by the benches' ``--trace-out`` flags and uploaded
as a CI artifact. ``chrome_trace`` converts the same events into the
Chrome Trace Event format (https://ui.perfetto.dev loads it directly):

- events carrying ``dur`` (prefill, decode_step, train_step) become
  complete slices (ph "X") on a per-kind track;
- the request lifecycle (admit → preempt/retire) becomes async begin/end
  pairs (ph "b"/"e", cat "request", id=rid) so each request renders as one
  horizontal bar spanning its residencies;
- everything else becomes instant events (ph "i").

Timestamps are recorder-clock seconds converted to µs (the format's unit),
rebased to the first event so traces start at t=0.
"""
from __future__ import annotations

import json
from typing import Any, Iterable

from .trace import Event

# stable track ids (tid) so Perfetto groups slices sensibly
_TRACKS = {"decode_step": 1, "prefill": 2, "prefill_chunk": 2,
           "train_step": 1}
_PID = 1


def write_jsonl(events: Iterable[Event], path: str) -> int:
    """Write one JSON object per line; returns the number written."""
    n = 0
    with open(path, "w") as f:
        for e in events:
            f.write(json.dumps(e.to_json()) + "\n")
            n += 1
    return n


def read_jsonl(path: str) -> list[Event]:
    out = []
    with open(path) as f:
        for line in f:
            d = json.loads(line)
            out.append(Event(d.pop("ts"), d.pop("kind"), d))
    return out


def chrome_trace(events: Iterable[Event]) -> dict[str, Any]:
    """Chrome Trace Event JSON for the given events (see module doc)."""
    evs = list(events)
    t0 = evs[0].ts if evs else 0.0

    def us(t: float) -> float:
        return (t - t0) * 1e6

    out: list[dict[str, Any]] = []
    for e in evs:
        args = {k: v for k, v in e.fields.items()}
        dur = e.fields.get("dur")
        if dur is not None:
            out.append({"name": e.kind, "ph": "X", "pid": _PID,
                        "tid": _TRACKS.get(e.kind, 3),
                        "ts": us(e.ts) - dur * 1e6, "dur": dur * 1e6,
                        "args": args})
        elif e.kind == "admit":
            out.append({"name": f"req {e.fields.get('rid')}", "ph": "b",
                        "cat": "request", "id": e.fields.get("rid"),
                        "pid": _PID, "tid": 0, "ts": us(e.ts),
                        "args": args})
        elif e.kind in ("retire", "preempt"):
            out.append({"name": f"req {e.fields.get('rid')}", "ph": "e",
                        "cat": "request", "id": e.fields.get("rid"),
                        "pid": _PID, "tid": 0, "ts": us(e.ts),
                        "args": args})
        else:
            out.append({"name": e.kind, "ph": "i", "pid": _PID,
                        "tid": _TRACKS.get(e.kind, 3), "ts": us(e.ts),
                        "s": "t", "args": args})
    return {"traceEvents": out, "displayTimeUnit": "ms"}


def write_chrome_trace(events: Iterable[Event], path: str) -> None:
    with open(path, "w") as f:
        json.dump(chrome_trace(events), f)

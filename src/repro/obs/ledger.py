"""Live memory ledger: byte-accurate accounting every allocation site
reports into, with per-phase peak watermarks and a reconcile check against
``jax.live_arrays()``.

The paper's headline claim is memory (Table 1: ultra memory reduction vs
full-size fp32 training), and the repo's figures for it were analytic
(``benchmarks/table1_memory.py``) or one-shot bench outputs.  The ledger
makes the byte budget *observable live*: the serve engine, the train driver
and the benches register every resident allocation site —

==================  =====================================================
site                what it accounts
==================  =====================================================
params              model parameters (TT cores / embeddings) as resident
tt_factor           packed int4x2 TT-factor deploy bytes (train bench)
activation          activation edges under the policy's activation spec
optimizer_moment    int8-blockwise Adam moments (``QTensor.nbytes``)
grad_residual       error-feedback residual of the int8 gradient wire
dp_wire             encoded bytes of one gradient all-reduce
scale_state         managed scale-state tree (f32 log2 exponents)
kv_pool             the paged int8 KV pool (codes + per-slot scales)
state_pool          the recurrent-state pool (mamba/rwkv6 mixers)
prefix_*            logical vs physical mapped KV pages (uncounted
                    overlay of ``kv_pool`` — see below)
compile_cache       bucketed prefill executables (entry count only;
                    XLA does not expose portable executable sizes)
==================  =====================================================

Two accounting rules keep the totals honest:

- **No double counting.** Overlay sites describe bytes already counted by
  another site (prefix logical/physical pages live *inside* the KV pool)
  and register with ``counted=False``: they appear in the summary and in
  watermark snapshots but never in ``total()``.  This is how
  ``pages_saved`` becomes a *verified bytes figure*: ``prefix_bytes_saved``
  is ``(logical - physical) * page_nbytes`` recomputed from the page table
  at every step, not a monotone counter.
- **One-sided reconcile.** The ledger tracks the sites the repo *owns*; the
  process also holds batches, temporaries and donated-buffer shadows.  So
  the invariant is subset-shaped: ``total() <= sum(a.nbytes for a in
  jax.live_arrays()) * (1 + tol)``.  A ledger total exceeding live bytes
  means a site is stale or double counted.

Phases and watermarks: ``set_phase`` names the current phase (``init`` /
``prefill`` / ``decode`` / ``train_step``) and every ``set`` updates that
phase's peak watermark (counted total + a full per-site byte snapshot at
the peak).  Each site additionally tracks its own all-time ``peak_bytes``,
which is what the benches report for transient figures like bytes saved by
prefix sharing.

Everything here is host-side Python over concrete arrays — ledger updates
never run inside jitted bodies, so the disabled path keeps decode jaxprs
byte-identical (same contract as ``TraceRecorder``).
"""
from __future__ import annotations

import jax

PHASES = ("init", "prefill", "decode", "train_step")


class MemoryLedger:
    """Byte ledger over named allocation sites with per-phase watermarks."""

    def __init__(self):
        # site -> {"bytes", "fp32_bytes", "counted", "peak_bytes", "meta"}
        self._sites: dict[str, dict] = {}
        self.phase: str = "init"
        # phase -> {"total_bytes": int, "sites": {name: bytes}}
        self._watermarks: dict[str, dict] = {}
        self.per_device: dict[str, int] | None = None

    # ---- recording ------------------------------------------------------
    def set(self, site: str, nbytes: int, fp32: int | None = None,
            counted: bool = True, **meta) -> None:
        """Report ``site``'s current resident bytes (idempotent overwrite).

        ``fp32`` is the site's fp32-dense shadow — what the same state would
        cost uncompressed (defaults to ``nbytes`` in the reduction figure).
        ``counted=False`` marks an overlay site whose bytes are already
        counted elsewhere (kept out of ``total()``/reconcile)."""
        nbytes = int(nbytes)
        prev = self._sites.get(site)
        peak = max(nbytes, prev["peak_bytes"]) if prev else nbytes
        self._sites[site] = {
            "bytes": nbytes,
            "fp32_bytes": None if fp32 is None else int(fp32),
            "counted": bool(counted),
            "peak_bytes": peak,
            "meta": dict(meta),
        }
        self._touch_watermark()

    def drop(self, site: str) -> None:
        self._sites.pop(site, None)
        self._touch_watermark()

    def set_phase(self, phase: str) -> None:
        """Enter a phase; its watermark starts from the current totals so a
        phase with no subsequent ``set`` still records one."""
        self.phase = str(phase)
        self._touch_watermark()

    def record_devices(self, *trees) -> None:
        """Fold per-device resident bytes of ``trees`` (pytrees of jax
        arrays) into the ledger's per-device breakdown."""
        self.per_device = device_breakdown(*trees)

    def _touch_watermark(self) -> None:
        total = self.total()
        wm = self._watermarks.get(self.phase)
        if wm is None or total > wm["total_bytes"]:
            self._watermarks[self.phase] = {
                "total_bytes": total,
                "sites": {n: s["bytes"] for n, s in self._sites.items()},
            }

    # ---- totals ---------------------------------------------------------
    def get(self, site: str) -> int:
        s = self._sites.get(site)
        return 0 if s is None else s["bytes"]

    def total(self, sites=None) -> int:
        """Counted resident bytes (optionally restricted to ``sites``)."""
        return sum(s["bytes"] for n, s in self._sites.items()
                   if s["counted"] and (sites is None or n in sites))

    def fp32_total(self, sites=None) -> int:
        """fp32-dense shadow of the counted sites (shadow defaults to the
        site's own bytes where none was declared)."""
        return sum(s["fp32_bytes"] if s["fp32_bytes"] is not None
                   else s["bytes"]
                   for n, s in self._sites.items()
                   if s["counted"] and (sites is None or n in sites))

    def reduction_vs_fp32(self, sites=None) -> float:
        """Live "reduction vs fp32-dense baseline" figure (Table 1 shape):
        shadow bytes / resident bytes over the counted sites."""
        t = self.total(sites)
        return float(self.fp32_total(sites)) / t if t else 0.0

    def watermark(self, phase: str) -> dict | None:
        return self._watermarks.get(phase)

    # ---- reconcile ------------------------------------------------------
    def reconcile(self, tolerance: float = 0.02,
                  live_bytes: int | None = None) -> dict:
        """Check the counted total against the process's live arrays.

        One-sided by design (see module docstring): the ledger must not
        claim more resident bytes than actually live, modulo ``tolerance``
        (covers declared-but-transient sites like activation edges)."""
        if live_bytes is None:
            live_bytes = sum(int(a.nbytes) for a in jax.live_arrays())
        total = self.total()
        ok = total <= live_bytes * (1.0 + tolerance)
        return {
            "ledger_bytes": int(total),
            "live_bytes": int(live_bytes),
            "tolerance": float(tolerance),
            "coverage_frac": (total / live_bytes) if live_bytes else 0.0,
            "ok": bool(ok),
        }

    # ---- summary --------------------------------------------------------
    def summary(self) -> dict:
        """JSON-friendly snapshot: sites, totals, the live reduction figure,
        per-phase watermarks, and the per-device breakdown when recorded."""
        sites = {}
        for name, s in self._sites.items():
            row = {"bytes": s["bytes"], "peak_bytes": s["peak_bytes"],
                   "counted": s["counted"]}
            if s["fp32_bytes"] is not None:
                row["fp32_bytes"] = s["fp32_bytes"]
            row.update(s["meta"])
            sites[name] = row
        out = {
            "phase": self.phase,
            "sites": sites,
            "total_bytes": self.total(),
            "fp32_total_bytes": self.fp32_total(),
            "reduction_vs_fp32_x": self.reduction_vs_fp32(),
            "watermarks": {p: dict(w) for p, w in self._watermarks.items()},
        }
        if self.per_device is not None:
            out["per_device"] = dict(self.per_device)
        return out


def device_breakdown(*trees) -> dict[str, int]:
    """Resident bytes per device across pytrees of jax arrays, summed from
    ``addressable_shards`` (a replicated array contributes its full size on
    every device — that is its real footprint)."""
    out: dict[str, int] = {}
    for tree in trees:
        for leaf in jax.tree_util.tree_leaves(tree):
            shards = getattr(leaf, "addressable_shards", None)
            if shards is None:
                continue
            for sh in shards:
                key = str(sh.device)
                out[key] = out.get(key, 0) + int(sh.data.nbytes)
    return out

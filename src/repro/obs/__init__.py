"""repro.obs — unified telemetry: counters, event traces, spans, export.

One API for every layer of the stack:

- ``counters``: host ``CounterRegistry`` (codec fallbacks, per-kernel
  bytes-moved cost table) + jit-safe quant-health aggregates
  (clip/saturation fractions, scale drift) that bit-agree across codec
  backends.
- ``trace``: host-side ring-buffered ``TraceRecorder`` — engine/scheduler/
  train-driver structured events, zero device overhead.
- ``ledger``: byte-accurate live ``MemoryLedger`` — every allocation site
  (params, moments, residuals, KV/state pools, prefix pages) reports in;
  per-phase peak watermarks, ``jax.live_arrays()`` reconcile, live
  reduction-vs-fp32 figure.
- ``spans``: per-request span trees derived from the flat event log.
- ``export``: JSONL + Chrome-trace (Perfetto) writers.

See README "Observability" for the schema and interpretation guide.
"""
from .counters import (CounterRegistry, fraction, kernel_costs,
                       pow2_clip_stats, record_kernel_call, registry,
                       saturation_counts, scale_drift_stats, tree_sat_stats)
from .export import (chrome_trace, read_jsonl, write_chrome_trace,
                     write_jsonl)
from .ledger import PHASES, MemoryLedger, device_breakdown
from .spans import Span, check_nesting, request_spans
from .trace import Event, TraceRecorder

__all__ = [
    "CounterRegistry", "registry", "record_kernel_call", "kernel_costs",
    "pow2_clip_stats", "saturation_counts", "scale_drift_stats",
    "tree_sat_stats", "fraction",
    "Event", "TraceRecorder",
    "MemoryLedger", "device_breakdown", "PHASES",
    "Span", "request_spans", "check_nesting",
    "write_jsonl", "read_jsonl", "chrome_trace", "write_chrome_trace",
]

"""Ring-buffered structured event recorder — the event half of ``repro.obs``.

The recorder is pure host-side Python: emitters call ``trace.emit(kind,
**fields)`` from *untraced* code (the engine step loop, the scheduler, the
train driver), so an attached recorder never changes a jaxpr and a detached
one costs a single ``is None`` check at the call site. tests/test_obs.py
asserts the stronger claim directly: the decode-step jaxpr with a recorder
attached is byte-identical to one without.

Events are tiny and flat — ``Event(ts, kind, fields)`` with JSON-scalar
fields only — and live in a ``deque(maxlen=capacity)`` ring, so a long
serve run keeps the newest ``capacity`` events and counts what it dropped
(``dropped``). Export (JSONL, Chrome trace) lives in ``export.py``; span
reconstruction (per-request admit→retire trees) in ``spans.py``.

Event kinds emitted by the stack (the trace schema; fields beyond ``ts`` /
``kind`` are per-kind):

====================  =====================================================
kind                  fields
====================  =====================================================
``submit``            rid, prompt_len, max_new
``admit``             rid, slot, pages (pages allocated at admit)
``prefill_chunk``     rid, slot, start, len (one bucketed chunk)
``prefill``           rid, slot, len, dur (whole-prompt wall time)
``first_token``       rid, slot
``decode_step``       step, n_active, free_pages, dur
``preempt``           rid, slot, gen_len (generated tokens folded back)
``retire``            rid, slot, new_tokens, reason ("eos"|"max_new")
``page_alloc``        slot, page, pos (lazy growth in ``ensure_page``)
``page_free``         slot, n (pages released at retire/preempt)
``cache_hit``         rid, slot, hit_tokens, prompt_len (prefix cache)
``cow_fork``          rid, slot, src_page, dst_page, tokens (mid-page hit)
``prefix_evict``      pages, tokens (one LRU leaf freed under pressure)
``state_snapshot``    slot, nbytes
``state_restore``     slot, nbytes
``train_step``        step, loss, dur (train driver loop)
====================  =====================================================
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator


@dataclass(frozen=True)
class Event:
    ts: float                       # recorder-clock seconds
    kind: str
    fields: dict[str, Any] = field(default_factory=dict)

    def to_json(self) -> dict[str, Any]:
        return {"ts": self.ts, "kind": self.kind, **self.fields}


class TraceRecorder:
    """Host-side ring buffer of structured events.

    ``clock`` is injectable (tests drive a deterministic counter, matching
    the ``ServeMetrics`` convention); ``capacity`` bounds memory — overflow
    silently evicts the OLDEST events and bumps ``dropped``. ``enabled``
    gates ``emit`` so a recorder can be muted without detaching it.
    """

    def __init__(self, capacity: int = 65536,
                 clock: Callable[[], float] = time.monotonic):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.clock = clock
        self.enabled = True
        self.dropped = 0
        self._ring: deque[Event] = deque(maxlen=capacity)

    def emit(self, kind: str, **fields: Any) -> None:
        if not self.enabled:
            return
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(Event(self.clock(), kind, fields))

    def events(self, kind: str | None = None) -> list[Event]:
        """Snapshot of buffered events, oldest first (optionally one kind)."""
        if kind is None:
            return list(self._ring)
        return [e for e in self._ring if e.kind == kind]

    def __len__(self) -> int:
        return len(self._ring)

    def __iter__(self) -> Iterator[Event]:
        return iter(list(self._ring))

    def clear(self) -> None:
        self._ring.clear()
        self.dropped = 0

"""Per-request span reconstruction from a flat event stream.

The recorder stores flat events; spans are derived on demand — a request
span runs submit→retire and contains one "scheduled" child per residency
(admit→preempt or admit→retire; a preempted request is re-admitted later,
so it can have several), and each residency contains its prefill-chunk
spans. Deriving instead of recording spans keeps the emit path trivial and
makes the nesting a pure function of the event log — the lifecycle test
(admit→preempt→resume→retire) asserts on exactly this structure.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Iterable

from .trace import Event


@dataclass
class Span:
    name: str
    start: float
    end: float | None = None            # None: still open at end of log
    fields: dict[str, Any] = field(default_factory=dict)
    children: list["Span"] = field(default_factory=list)

    @property
    def dur(self) -> float | None:
        return None if self.end is None else self.end - self.start


def request_spans(events: Iterable[Event]) -> dict[Any, Span]:
    """{rid: request span} with scheduled-residency children.

    Events must be in emit order (the recorder guarantees it). Requests
    still in flight at the end of the log yield open spans (end=None).
    """
    spans: dict[Any, Span] = {}
    open_res: dict[Any, Span] = {}      # rid -> current residency span

    def req(rid, ts) -> Span:
        if rid not in spans:
            spans[rid] = Span("request", ts, fields={"rid": rid})
        return spans[rid]

    for e in events:
        rid = e.fields.get("rid")
        if e.kind == "submit":
            spans[rid] = Span("request", e.ts, fields=dict(e.fields))
        elif e.kind == "admit":
            res = Span("scheduled", e.ts, fields=dict(e.fields))
            req(rid, e.ts).children.append(res)
            open_res[rid] = res
        elif e.kind in ("prefill_chunk", "prefill"):
            res = open_res.get(rid)
            if res is not None:
                dur = e.fields.get("dur", 0.0) or 0.0
                res.children.append(Span(e.kind, e.ts - dur, e.ts,
                                         fields=dict(e.fields)))
        elif e.kind == "preempt":
            res = open_res.pop(rid, None)
            if res is not None:
                res.end = e.ts
                res.fields["outcome"] = "preempted"
        elif e.kind == "retire":
            res = open_res.pop(rid, None)
            if res is not None:
                res.end = e.ts
                res.fields["outcome"] = "retired"
            r = req(rid, e.ts)
            r.end = e.ts
            r.fields.setdefault("reason", e.fields.get("reason"))
    return spans


def check_nesting(span: Span) -> bool:
    """True iff every child interval sits inside its parent (closed spans
    only) and children are in start order — the structural invariant the
    lifecycle test asserts."""
    prev = span.start
    for c in span.children:
        if c.start < span.start - 1e-9 or c.start < prev - 1e-9:
            return False
        if span.end is not None and c.end is not None \
                and c.end > span.end + 1e-9:
            return False
        prev = c.start
        if not check_nesting(c):
            return False
    return True

"""repro.numerics — the unified quantization API.

One pow-2-scaled symmetric fixed-point scheme (paper §3.2-3.3) plus its
blockwise-absmax extension carries every low-precision site in the system:
TT-factor weights, activations, gradient edges, optimizer moments, the
data-parallel gradient wire, and the serving KV-cache.

- ``QuantSpec``      frozen descriptor of one scheme (kind/bits/block/...)
- ``QTensor``        codes + scale metadata container (``nbytes()``)
- ``encode/decode/fake_quant``  codec operations; ``get_codec`` selects a
  backend ("reference" jnp or "pallas" fused kernels — bit-identical)
- ``NumericsPolicy`` named sites -> specs, JSON-round-trippable, owner of
  the managed scale-state tree (§3.3 scale manager)
"""
from .codecs import (decode, encode, fake_quant, fake_quant_stats,  # noqa: F401
                     get_codec, pack_int4, per_tensor_max_scale_log2,
                     pow2_fake_quant, pow2_qdq, register_codec, roundtrip,
                     unpack_int4, BACKENDS)
from .policy import (NumericsPolicy, SITES, ScaleState,  # noqa: F401
                     init_scale, policy_from_quant_config, step_log2,
                     update_scale)
from .spec import QTensor, QuantSpec, qrange, spec_nbytes  # noqa: F401

"""Quantization descriptors: ``QuantSpec`` (how to quantize) and ``QTensor``
(a quantized tensor: codes + scale metadata).

One frozen ``QuantSpec`` describes every low-precision scheme the repo uses
(paper §3.2-3.3 and its serving/optimizer extensions):

- ``kind="pow2"``: symmetric fixed point on a power-of-2 grid,
  ``x ≈ q * 2^scale_log2`` with ``q ∈ [-2^{b-1}, 2^{b-1}-1]``. The scale is
  supplied by the caller (fixed, scale-managed, or chosen per tensor from
  max|x| — see ``scale_policy``).
- ``kind="blockwise"``: Dettmers-style per-block absmax quantization along
  the last axis, ``q ∈ [-(2^{b-1}-1), 2^{b-1}-1]``, one f32 scale per block
  of ``block`` elements. The scale is derived from the data inside
  ``encode`` (always per-block max — ``scale_policy`` is informational).

``scale_policy`` records who owns the scale at a site:

- ``"fixed"``: a constant chosen at init (TT factors, paper §3.2).
- ``"managed"``: the §3.3 scale manager adjusts an integer log2 exponent to
  keep mean|x/2^k| inside a target band (activations, gradient edges).
- ``"per_tensor_max"``: derived from max|x| when the tensor is first seen
  (KV-cache prefill, blockwise optimizer/wire codecs).

Specs are plain frozen dataclasses: hashable (usable as static jit args),
JSON-round-trippable via ``to_json_dict``/``from_json_dict``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass

import jax
import jax.numpy as jnp

KINDS = ("pow2", "blockwise")
SCALE_POLICIES = ("fixed", "managed", "per_tensor_max")
# "int4x2" is *packed* int4: two 4-bit codes per int8 byte, packed along the
# trailing axis (odd trailing dims pad one zero nibble inside the codec) —
# the TT-factor deploy format (3U-EdgeAI-style int4 export).
STORAGE_DTYPES = ("int8", "int16", "int32", "float32", "int4x2")


def packed_trailing(last: int) -> int:
    """Packed trailing dim of an int4x2 code array: two codes per byte."""
    return -(-last // 2)


def qrange(bits: int) -> tuple[float, float]:
    """Representable code range of a ``bits``-bit pow2 grid (paper §3.2):
    the full asymmetric two's-complement range."""
    return -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1.0


@dataclass(frozen=True)
class QuantSpec:
    """Frozen description of one quantization scheme."""
    kind: str = "pow2"              # "pow2" | "blockwise"
    bits: int = 8
    block: int = 0                  # blockwise: elements per scale (0 for pow2)
    storage_dtype: str = "int8"     # dtype codes are materialized in
    scale_policy: str = "fixed"     # "fixed" | "managed" | "per_tensor_max"

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown kind {self.kind!r}; one of {KINDS}")
        if self.scale_policy not in SCALE_POLICIES:
            raise ValueError(f"unknown scale_policy {self.scale_policy!r}")
        if self.kind == "blockwise" and self.block <= 0:
            raise ValueError("blockwise spec needs block > 0")
        if self.storage_dtype not in STORAGE_DTYPES:
            raise ValueError(f"unknown storage_dtype {self.storage_dtype!r}; "
                             f"one of {STORAGE_DTYPES}")
        if self.packed and (self.kind != "pow2" or self.bits > 4):
            raise ValueError("int4x2 packed storage holds one nibble per "
                             "code: pow2 kind with bits <= 4 only")

    @property
    def packed(self) -> bool:
        """Two codes per stored byte (``storage_dtype="int4x2"``)."""
        return self.storage_dtype == "int4x2"

    @property
    def qmin(self) -> float:
        lo, hi = qrange(self.bits)
        # blockwise codecs are symmetric (±qmax) so that scale = absmax/qmax
        # is exact at both ends; pow2 uses the full two's-complement range.
        return -hi if self.kind == "blockwise" else lo

    @property
    def qmax(self) -> float:
        return qrange(self.bits)[1]

    @property
    def jnp_storage(self):
        # packed int4 codes are physically int8 bytes (two nibbles each)
        if self.packed:
            return jnp.dtype("int8")
        return jnp.dtype(self.storage_dtype)

    def to_json_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json_dict(cls, d: dict) -> "QuantSpec":
        return cls(**d)


# ---------------------------------------------------------------------------
# QTensor
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_with_keys_class
class QTensor:
    """A quantized tensor: integer ``codes`` + ``scale`` metadata.

    - pow2: ``codes`` has the logical shape, ``scale`` is the (broadcastable)
      ``scale_log2`` array/scalar; value = codes * 2^scale. With packed
      ``int4x2`` storage ``codes`` is ``shape[:-1] + (ceil(last/2),)`` int8
      bytes, two nibbles each (odd trailing dims carry one zero pad nibble).
    - blockwise: ``codes`` is ``shape[:-1] + (nb*block,)`` (last axis padded
      to a block multiple), ``scale`` is ``shape[:-1] + (nb,)`` f32;
      value = codes * scale per block, sliced back to ``shape``.

    ``spec`` and the logical ``shape`` ride as static pytree aux data, so a
    QTensor can sit inside jitted state trees (optimizer moments) and
    checkpoints like any other pytree node.
    """

    __slots__ = ("codes", "scale", "spec", "shape")

    def __init__(self, codes, scale, spec: QuantSpec,
                 shape: tuple[int, ...] | None = None):
        self.codes = codes
        self.scale = scale
        self.spec = spec
        self.shape = tuple(shape) if shape is not None \
            else tuple(getattr(codes, "shape", ()))

    def nbytes(self) -> int:
        """Resident bytes of the quantized representation."""
        return int(getattr(self.codes, "nbytes", 0)) \
            + int(getattr(self.scale, "nbytes", 0))

    def dequantize(self, dtype=jnp.float32):
        """Decode through the reference codec (convenience)."""
        from .codecs import get_codec
        return get_codec(self.spec, "reference").decode(self, dtype)

    def __repr__(self):
        return (f"QTensor(kind={self.spec.kind!r}, bits={self.spec.bits}, "
                f"shape={self.shape}, nbytes={self.nbytes()})")

    # pytree protocol -----------------------------------------------------
    def tree_flatten_with_keys(self):
        # keys are DictKey("q")/DictKey("scale") — NOT GetAttrKey — so the
        # flattened paths ("...§q", "...§scale") match the pre-QTensor
        # {"q": ..., "scale": ...} dict layout and old int8 optimizer-state
        # checkpoints keep loading (ckpt/checkpoint.py keys by tree path)
        return (((jax.tree_util.DictKey("q"), self.codes),
                 (jax.tree_util.DictKey("scale"), self.scale)),
                (self.spec, self.shape))

    @classmethod
    def tree_unflatten(cls, aux, children):
        spec, shape = aux
        return cls(children[0], children[1], spec, shape)


def spec_nbytes(spec: QuantSpec, shape: tuple[int, ...]) -> int:
    """Analytic resident bytes of quantizing ``shape`` under ``spec``
    (without materializing): codes + scale metadata."""
    import math
    n = math.prod(shape) if shape else 1
    itemsize = spec.jnp_storage.itemsize
    if spec.kind == "pow2":
        if spec.packed:
            last = shape[-1] if shape else 1
            lead = n // max(last, 1)
            return lead * packed_trailing(last) * itemsize + 4
        return n * itemsize + 4
    last = shape[-1] if shape else 1
    b = min(spec.block, max(1, last))
    nb = -(-last // b)
    lead = n // max(last, 1)
    return lead * nb * b * itemsize + lead * nb * 4

"""NumericsPolicy: named quantization sites -> QuantSpec, plus the §3.3
scale manager that owns every *managed* pow-2 scale.

The paper's claim is that ONE hardware-friendly low-precision scheme carries
the whole training pipeline. The policy is that claim as an object: a frozen
map from the pipeline's quantization sites to specs, JSON-round-trippable so
a training run's numerics are a single serializable artifact.

Site names (``SITES``):

- ``tt_factor``        TT-core weights (4-bit pow2, fixed scales — §3.2)
- ``activation``       forward activations (8-bit pow2, managed — §3.3)
- ``grad_edge``        backward activation-gradients (16-bit pow2, managed)
- ``optimizer_moment`` Adam m/v state (blockwise int8, block 256)
- ``dp_wire``          data-parallel gradient all-reduce (blockwise int8,
                       block 1024, error feedback in optim/grad_compress)
- ``kv_cache``         serving KV entries (8-bit pow2, per-tensor-max scale
                       chosen at prefill — serve/kv_cache.py)
- ``ssm_state``        serving recurrent-state entries for SSM/RWKV mixers
                       (8-bit pow2, per-tensor-max scale re-chosen at every
                       overwrite — serve/state_cache.py)

Scale-state: the policy hands out one ``ScaleState`` per managed site
(``init_scales``) and the resulting tree is threaded through ``TrainState``
(launch/steps.py) and the serve engine's pool (``scale_log2`` leaves), so
every dynamic scale in the system has a single owner.
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .spec import QuantSpec

SITES = ("tt_factor", "activation", "grad_edge", "optimizer_moment",
         "dp_wire", "kv_cache", "ssm_state")


# ---------------------------------------------------------------------------
# Scale manager (§3.3) — owned here; core/quant.py re-exports for compat
# ---------------------------------------------------------------------------

class ScaleState(NamedTuple):
    """Per-site dynamic pow-2 scale: k (log2 scale) and the tracked mean
    |x / 2^k| the manager drives into the target band."""
    log2: jax.Array      # int32 scalar
    mean_abs: jax.Array  # f32 scalar, EMA of mean |x| / 2^k


def init_scale(log2: int = 0) -> ScaleState:
    return ScaleState(jnp.asarray(log2, jnp.int32),
                      jnp.asarray(0.2, jnp.float32))


def update_scale(state: ScaleState, x: jax.Array, *, lo: float = 0.1,
                 hi: float = 0.3, ema: float = 0.9) -> ScaleState:
    """Track mean|x/2^k| and adjust k to hold it in [lo, hi] (paper §3.3).

    jit-friendly; runs on stop_gradient(x).
    """
    x = jax.lax.stop_gradient(x).astype(jnp.float32)
    m = jnp.mean(jnp.abs(x)) / jnp.exp2(state.log2.astype(jnp.float32))
    m = ema * state.mean_abs + (1.0 - ema) * m
    up = (m > hi).astype(jnp.int32)      # too large -> coarser scale (k+1)
    dn = (m < lo).astype(jnp.int32)      # too small -> finer scale (k-1)
    new_log2 = state.log2 + up - dn
    # after a bump the tracked statistic halves/doubles accordingly
    m = m * jnp.exp2(-(up - dn).astype(jnp.float32))
    return ScaleState(new_log2, m)


def step_log2(state: ScaleState, bits: int) -> jax.Array:
    """Grid step exponent of a managed scale: the representable range
    [-2^{b-1}, 2^{b-1}-1] * 2^{k-(b-1)} then covers ~2^k (so "mean |x|/2^k
    in [0.1, 0.3]" uses a healthy fraction of the range)."""
    return state.log2.astype(jnp.float32) - (bits - 1)


# ---------------------------------------------------------------------------
# Policy
# ---------------------------------------------------------------------------

def _default_sites(weight_bits: int = 4, act_bits: int = 8,
                   grad_bits: int = 16) -> tuple[tuple[str, QuantSpec], ...]:
    return (
        ("tt_factor", QuantSpec("pow2", weight_bits, 0, "int8", "fixed")),
        ("activation", QuantSpec("pow2", act_bits, 0, "int8", "managed")),
        ("grad_edge", QuantSpec("pow2", grad_bits, 0, "int16", "managed")),
        ("optimizer_moment",
         QuantSpec("blockwise", 8, 256, "int8", "per_tensor_max")),
        ("dp_wire", QuantSpec("blockwise", 8, 1024, "int8", "per_tensor_max")),
        ("kv_cache", QuantSpec("pow2", 8, 0, "int8", "per_tensor_max")),
        ("ssm_state", QuantSpec("pow2", 8, 0, "int8", "per_tensor_max")),
    )


@dataclass(frozen=True)
class NumericsPolicy:
    """Frozen site -> QuantSpec map + scale-manager knobs. Hashable, so it
    can ride as a static argument of jitted step functions."""
    enable: bool = False
    sites: tuple[tuple[str, QuantSpec], ...] = _default_sites()
    # scale manager (§3.3): keep mean |x/2^k| within [lo, hi]
    target_lo: float = 0.1
    target_hi: float = 0.3
    ema: float = 0.9
    # quant-health telemetry (repro.obs): when True, step functions and the
    # serve pools trace the per-site clip/saturation/drift aggregates as
    # extra outputs. Off by default — the disabled path's jaxpr is
    # unchanged (the health code is Python-gated at trace time).
    health: bool = False

    def spec_for(self, site: str) -> QuantSpec:
        for name, spec in self.sites:
            if name == site:
                return spec
        raise KeyError(f"unknown numerics site {site!r}; "
                       f"known: {[n for n, _ in self.sites]}")

    def nbytes(self, site: str, shape: tuple[int, ...]) -> int:
        """Analytic resident bytes of a ``shape`` tensor at ``site`` under
        this policy (codes + scale metadata; packed storage counted at two
        codes per byte). The per-site accounting the train-wire memory
        harness asserts against (tests/test_train_wire.py)."""
        from .spec import spec_nbytes
        return spec_nbytes(self.spec_for(site), tuple(shape))

    def with_spec(self, site: str, spec: QuantSpec) -> "NumericsPolicy":
        if site not in [n for n, _ in self.sites]:
            raise KeyError(site)
        new = tuple((n, spec if n == site else s) for n, s in self.sites)
        return dataclasses.replace(self, sites=new)

    # scale-state tree ----------------------------------------------------
    def managed_sites(self) -> tuple[str, ...]:
        return tuple(n for n, s in self.sites if s.scale_policy == "managed")

    def init_scales(self) -> dict[str, ScaleState]:
        """One ScaleState per managed site — the scale-state tree threaded
        through TrainState (and, for kv_cache, materialized per (layer,
        slot) by serve/kv_cache.init_pool)."""
        return {n: init_scale(0) for n in self.managed_sites()}

    def update_scales(self, scales: dict, observed: dict) -> dict:
        """Scale-manager step for every observed site. ``observed`` maps
        site name -> tensor whose magnitude statistic to track."""
        out = dict(scales)
        for name, x in observed.items():
            if name in out:
                out[name] = update_scale(out[name], x, lo=self.target_lo,
                                         hi=self.target_hi, ema=self.ema)
        return out

    # JSON ----------------------------------------------------------------
    def to_json_dict(self) -> dict:
        return {
            "enable": self.enable,
            "sites": {n: s.to_json_dict() for n, s in self.sites},
            "target_lo": self.target_lo,
            "target_hi": self.target_hi,
            "ema": self.ema,
            "health": self.health,
        }

    @classmethod
    def from_json_dict(cls, d: dict) -> "NumericsPolicy":
        sites = tuple((n, QuantSpec.from_json_dict(s))
                      for n, s in d["sites"].items())
        return cls(enable=d["enable"], sites=sites,
                   target_lo=d.get("target_lo", 0.1),
                   target_hi=d.get("target_hi", 0.3),
                   ema=d.get("ema", 0.9),
                   health=d.get("health", False))

    def to_json(self) -> str:
        # no sort_keys: the sites map is ordered and the order is identity
        return json.dumps(self.to_json_dict(), indent=2)

    @classmethod
    def from_json(cls, s: str) -> "NumericsPolicy":
        return cls.from_json_dict(json.loads(s))


def policy_from_quant_config(qc) -> NumericsPolicy:
    """The back-compat constructor: ``configs.base.QuantConfig`` (the
    paper-era knob set) lowered onto the unified policy. ``QuantConfig``
    remains the config-surface type; this is its semantics."""
    return NumericsPolicy(
        enable=qc.enable,
        sites=_default_sites(qc.weight_bits, qc.act_bits, qc.grad_bits),
        target_lo=qc.target_lo, target_hi=qc.target_hi, ema=qc.ema,
        health=getattr(qc, "health", False))

"""Pallas codec backend: fused quantize kernels behind the same
``encode / decode / fake_quant`` API as the reference backend.

Absorbs the old ``kernels/quantize.py`` fused fake-quant (one VMEM pass:
scale -> round -> clip -> dequantize — on the FPGA this is the implicit
writeback datapath of every PE) and adds code-producing encode / decode
kernels plus a blockwise-absmax kernel pair.

All entry points pad to block multiples *internally* and slice the result
back, so callers never pre-pad (the old ``quantize()`` asserted exact
(bm, bn) multiples — that footgun is gone). Kernels run compiled on TPU and
in interpret mode elsewhere, where the kernel body executes as jnp — which
is also why the backend is bit-identical to the reference codec (asserted
by tests/test_numerics.py).

Scale handling: the fused kernels take one scalar ``scale_log2`` through
SMEM (per-tensor pow-2 scale, the §3.2 scheme) OR a *multi-scale* array
following the leading-dim broadcast convention of ``codecs._bcast`` — one
scale per leading index, e.g. the KV pool's per-(layer, slot) scale arrays.
Multi-scale calls collapse to a (rows, cols) layout with one scale per row
and run a vectorized row-scale kernel (the per-page dequant datapath of the
fused paged-attention kernel, exposed as a standalone codec).  Only scale
shapes that do not broadcast against the leading dims fall back to the
reference codec; ``fallback_count()`` lets tests assert a path stayed
native (tests/test_numerics.py pins every KV-pool shape to zero fallbacks).
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..obs.counters import registry as _counters
from .codecs import (Pow2Reference, BlockwiseReference, _bcast, _p2fq_bwd,
                     _p2fq_fwd, register_codec)
from .spec import QTensor, QuantSpec, packed_trailing, qrange

# Calls that fell back to the reference codec because the scale array did
# not fit a kernel layout live in the obs counter registry under this name
# (incremented at trace time; tests reset + assert zero around pool-shaped
# calls). fallback_count()/reset_fallback_count() are kept as the
# long-standing API — they are now views over the registry counter.
FALLBACK_COUNTER = "numerics.codec_fallback"


def fallback_count() -> int:
    return _counters.get(FALLBACK_COUNTER)


def reset_fallback_count() -> None:
    _counters.reset(FALLBACK_COUNTER)


def _note_fallback() -> None:
    _counters.inc(FALLBACK_COUNTER)


def interpret_mode() -> bool:
    """Pallas interpret-mode switch shared by every kernel call site
    (kernels/ops.py and this backend): JAX_PALLAS_INTERPRET=1 forces
    interpret (the CI kernel-validation mode); otherwise interpret
    everywhere but TPU."""
    if os.environ.get("JAX_PALLAS_INTERPRET", "") == "1":
        return True
    return jax.default_backend() != "tpu"


def native_backend() -> bool:
    """True where Pallas kernels are the preferred lowering: a TPU backend
    (compiled), or JAX_PALLAS_INTERPRET=1 explicitly asking for kernel
    validation. One predicate so the codec, the pool, and the kernel
    wrapper can never route differently for the same configuration."""
    return (jax.default_backend() == "tpu"
            or os.environ.get("JAX_PALLAS_INTERPRET", "") == "1")


_interpret = interpret_mode


def _blk(dim: int, pref: int, floor: int) -> int:
    if dim >= pref:
        return pref
    return max(floor, ((dim + floor - 1) // floor) * floor)


def _pad2d(x: jax.Array, bm: int, bn: int) -> jax.Array:
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        return jnp.pad(x, ((0, pm), (0, pn)))
    return x


def _as2d(flat: jax.Array, cols: int = 256) -> tuple[jax.Array, int]:
    """(n,) -> (rows, cols) zero-padded; returns (x2d, n)."""
    n = flat.shape[0]
    rows = -(-n // cols)
    pad = rows * cols - n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(rows, cols), n


# ---------------------------------------------------------------------------
# pow2 kernels
# ---------------------------------------------------------------------------

def _p2_fq_kernel(x_ref, step_ref, o_ref, *, bits: int):
    scale = jnp.exp2(step_ref[0].astype(jnp.float32)).astype(x_ref.dtype)
    lo, hi = qrange(bits)
    x = x_ref[...]
    o_ref[...] = (jnp.clip(jnp.round(x / scale), lo, hi) * scale
                  ).astype(o_ref.dtype)


def _p2_enc_kernel(x_ref, step_ref, o_ref, *, bits: int):
    scale = jnp.exp2(step_ref[0].astype(jnp.float32))
    lo, hi = qrange(bits)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.clip(jnp.round(x / scale), lo, hi).astype(o_ref.dtype)


def _p2_dec_kernel(q_ref, step_ref, o_ref):
    scale = jnp.exp2(step_ref[0].astype(jnp.float32))
    o_ref[...] = (q_ref[...].astype(jnp.float32) * scale).astype(o_ref.dtype)


def _elementwise_2d(kernel, x2d: jax.Array, step_log2, out_dtype, *,
                    bm: int = 256, bn: int = 256) -> jax.Array:
    """Grid-tiled elementwise pass with the scalar step in SMEM; pads the
    operand to (bm, bn) multiples internally and slices the result back."""
    m, n = x2d.shape
    xp = _pad2d(x2d, bm, bn)
    mp, np_ = xp.shape
    step = jnp.asarray(step_log2, jnp.float32).reshape(1)
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=_interpret(),
    )(xp, step)
    return out[:m, :n]


def _flat_call(kernel, x: jax.Array, step_log2, out_dtype) -> jax.Array:
    """Arbitrary-shape elementwise call: flatten -> 2D tile -> restore."""
    shape = x.shape
    x2d, n = _as2d(x.reshape(-1))
    bm = _blk(x2d.shape[0], 256, 8)
    out = _elementwise_2d(kernel, x2d, step_log2, out_dtype, bm=bm)
    return out.reshape(-1)[:n].reshape(shape)


# ---- multi-scale (one pow-2 scale per leading index) ----------------------

def _rowwise(x: jax.Array, scale) -> tuple[jax.Array, jax.Array] | None:
    """View (x, scale) as (rows, cols) with one scale per row.

    Accepts any scale following the ``codecs._bcast`` convention: after
    stripping trailing length-1 dims, ``scale.shape`` must broadcast against
    the same number of *leading* dims of ``x`` (each dim equal or 1).
    Returns (x2d, scale_row) or None when the convention doesn't hold
    (caller falls back to the reference codec)."""
    scale = jnp.asarray(scale)
    sh = list(scale.shape)
    while sh and sh[-1] == 1:
        sh.pop()
    if not sh or len(sh) > x.ndim:
        return None
    lead = x.shape[:len(sh)]
    if any(s not in (1, d) for s, d in zip(sh, lead)):
        return None
    rows = 1
    for d in lead:
        rows *= d
    srow = jnp.broadcast_to(scale.reshape(sh), lead).reshape(rows)
    return x.reshape(rows, -1), srow


def _p2_enc_rows_kernel(x_ref, s_ref, o_ref, *, bits: int):
    step = jnp.exp2(s_ref[...].astype(jnp.float32))     # (bm, 1) per-row
    lo, hi = qrange(bits)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = jnp.clip(jnp.round(x / step), lo, hi).astype(o_ref.dtype)


def _p2_dec_rows_kernel(q_ref, s_ref, o_ref):
    step = jnp.exp2(s_ref[...].astype(jnp.float32))
    o_ref[...] = (q_ref[...].astype(jnp.float32) * step).astype(o_ref.dtype)


def _rowscale_call(kernel, x2d: jax.Array, srow: jax.Array,
                   out_dtype) -> jax.Array:
    """Grid-tiled pass with one f32 scale per row delivered as a (bm, 1)
    VMEM block (same layout as the blockwise decode kernel)."""
    r, c = x2d.shape
    bm = _blk(r, 256, 8)
    bn = _blk(c, 256, 128)
    xp = _pad2d(x2d, bm, bn)
    sp = _pad2d(srow.astype(jnp.float32).reshape(r, 1), bm, 1)
    mp, np_ = xp.shape
    out = pl.pallas_call(
        kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
                  pl.BlockSpec((bm, 1), lambda i, j: (i, 0))],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        interpret=_interpret(),
    )(xp, sp)
    return out[:r, :c]


# ---- int4x2 packed (two codes per byte, packed along the trailing dim) ----
# The kernel bodies call the codec's own pack_int4/unpack_int4 (kernel-safe
# jnp; blocks are always even-width so the no-pad path runs) — ONE nibble
# layout owned by codecs.py, same single-implementation rule as the PE1
# epilogue.

def _p2_enc_packed_kernel(x_ref, step_ref, o_ref, *, bits: int):
    from .codecs import pack_int4
    scale = jnp.exp2(step_ref[0].astype(jnp.float32))
    lo, hi = qrange(bits)
    q = jnp.clip(jnp.round(x_ref[...].astype(jnp.float32) / scale), lo, hi)
    o_ref[...] = pack_int4(q)


def _p2_enc_packed_rows_kernel(x_ref, s_ref, o_ref, *, bits: int):
    from .codecs import pack_int4
    step = jnp.exp2(s_ref[...].astype(jnp.float32))      # (bm, 1) per-row
    lo, hi = qrange(bits)
    q = jnp.clip(jnp.round(x_ref[...].astype(jnp.float32) / step), lo, hi)
    o_ref[...] = pack_int4(q)


def _p2_dec_packed_kernel(q_ref, step_ref, o_ref):
    from .codecs import unpack_int4
    scale = jnp.exp2(step_ref[0].astype(jnp.float32))
    q = unpack_int4(q_ref[...], 2 * q_ref.shape[-1])
    o_ref[...] = (q.astype(jnp.float32) * scale).astype(o_ref.dtype)


def _p2_dec_packed_rows_kernel(q_ref, s_ref, o_ref):
    from .codecs import unpack_int4
    step = jnp.exp2(s_ref[...].astype(jnp.float32))
    q = unpack_int4(q_ref[...], 2 * q_ref.shape[-1])
    o_ref[...] = (q.astype(jnp.float32) * step).astype(o_ref.dtype)


def _rowwise_lastdim(x: jax.Array, scale) -> tuple | None:
    """View ``x`` as (rows, last) with one scale per row, KEEPING the
    logical trailing dim intact (the packed codec pairs nibbles along it —
    `_rowwise`'s full collapse would let pairs straddle row boundaries when
    the trailing dim is odd). None when the scale extends into the trailing
    dim (per-element scales: reference fallback)."""
    scale = jnp.asarray(scale)
    sh = list(scale.shape)
    while sh and sh[-1] == 1:
        sh.pop()
    if len(sh) > x.ndim - 1:
        return None
    lead = x.shape[:-1]
    if any(s not in (1, d) for s, d in zip(sh, lead)):
        return None
    rows = 1
    for d in lead:
        rows *= d
    srow = jnp.broadcast_to(
        scale.reshape(tuple(sh) + (1,) * (len(lead) - len(sh))),
        lead).reshape(rows)
    return x.reshape(rows, x.shape[-1]), srow


def _packed_call(kernel, x2d: jax.Array, srow_or_step, out_shape_cols: str,
                 rowwise: bool, out_dtype) -> jax.Array:
    """Grid-tiled packed pass. ``out_shape_cols``: "half" for encode
    ((bm, 2*bc) in -> (bm, bc) out), "double" for decode ((bm, bc) in ->
    (bm, 2*bc) out). Pads internally, slices back."""
    r, c = x2d.shape
    half = out_shape_cols == "half"
    pk = packed_trailing(c) if half else c   # packed (byte) cols
    bm = _blk(r, 256, 8)
    bc = _blk(pk, 256, 128)
    cp = -(-pk // bc) * bc                   # padded packed cols
    rp = -(-r // bm) * bm
    in_cols = 2 * cp if half else cp
    xp = jnp.zeros((rp, in_cols), x2d.dtype).at[:r, :c].set(x2d)
    in_block = (bm, 2 * bc) if half else (bm, bc)
    out_block = (bm, bc) if half else (bm, 2 * bc)
    if rowwise:
        sp = _pad2d(srow_or_step.astype(jnp.float32).reshape(r, 1), bm, 1)
        scale_spec = pl.BlockSpec((bm, 1), lambda i, j: (i, 0))
        scale_arg = sp
    else:
        scale_spec = pl.BlockSpec(memory_space=pltpu.SMEM)
        scale_arg = jnp.asarray(srow_or_step, jnp.float32).reshape(1)
    out_cols = cp if half else 2 * cp
    out = pl.pallas_call(
        kernel,
        grid=(rp // bm, cp // bc),
        in_specs=[pl.BlockSpec(in_block, lambda i, j: (i, j)), scale_spec],
        out_specs=pl.BlockSpec(out_block, lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rp, out_cols), out_dtype),
        interpret=_interpret(),
    )(xp, scale_arg)
    return out[:r, :pk] if half else out[:r]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _p2_fake_quant_pallas(x, scale_log2, bits):
    return _flat_call(functools.partial(_p2_fq_kernel, bits=bits), x,
                      scale_log2, x.dtype)


# same clipped-STE backward as the reference codec; the forward residual
# (the inside-range mask) is cheap enough to compute outside the kernel
_p2_fake_quant_pallas.defvjp(
    lambda x, s, bits: (_p2_fake_quant_pallas(x, s, bits),
                        _p2fq_fwd(x, s, bits)[1]),
    _p2fq_bwd)


def _p2_fq_rows_kernel(x_ref, s_ref, o_ref, *, bits: int):
    # per-row fused qdq in x.dtype — the multi-scale twin of _p2_fq_kernel,
    # matching the reference pow2_qdq grid (scale cast to x.dtype) exactly
    step = jnp.exp2(s_ref[...].astype(jnp.float32)).astype(x_ref.dtype)
    lo, hi = qrange(bits)
    x = x_ref[...]
    o_ref[...] = (jnp.clip(jnp.round(x / step), lo, hi) * step
                  ).astype(o_ref.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _p2_fake_quant_rows(x, scale_log2, bits):
    x2d, srow = _rowwise(x, scale_log2)
    out = _rowscale_call(functools.partial(_p2_fq_rows_kernel, bits=bits),
                         x2d, srow, x.dtype)
    return out.reshape(x.shape)


# clipped STE with the reference's leading-dim broadcast semantics: the
# inside-range mask comes from _p2fq_fwd on the _bcast-shaped scale
_p2_fake_quant_rows.defvjp(
    lambda x, s, bits: (_p2_fake_quant_rows(x, s, bits),
                        _p2fq_fwd(x, _bcast(s, x.ndim), bits)[1]),
    _p2fq_bwd)


class Pow2Pallas(Pow2Reference):
    backend = "pallas"

    @staticmethod
    def _scalar(scale) -> bool:
        return jnp.ndim(scale) == 0 or getattr(scale, "size", 2) == 1

    def encode(self, x, spec: QuantSpec, scale):
        if spec.packed:
            return self._encode_packed(jnp.asarray(x), spec, scale)
        if self._scalar(scale):
            codes = _flat_call(
                functools.partial(_p2_enc_kernel, bits=spec.bits),
                x, scale, spec.jnp_storage)
            return QTensor(codes, jnp.asarray(scale), spec, x.shape)
        rw = _rowwise(jnp.asarray(x), scale)
        if rw is None:
            _note_fallback()
            return super().encode(x, spec, scale)
        x2d, srow = rw
        codes = _rowscale_call(
            functools.partial(_p2_enc_rows_kernel, bits=spec.bits),
            x2d, srow, spec.jnp_storage)
        return QTensor(codes.reshape(x.shape), jnp.asarray(scale), spec,
                       x.shape)

    def _encode_packed(self, x, spec: QuantSpec, scale):
        if x.ndim == 0:                       # scalars: no trailing dim to pack
            _note_fallback()
            return super().encode(x, spec, scale)
        if self._scalar(scale):
            x2d = x.reshape(-1, x.shape[-1])
            codes = _packed_call(
                functools.partial(_p2_enc_packed_kernel, bits=spec.bits),
                x2d, scale, "half", False, jnp.int8)
        else:
            rw = _rowwise_lastdim(x, scale)
            if rw is None:
                _note_fallback()
                return super().encode(x, spec, scale)
            x2d, srow = rw
            codes = _packed_call(
                functools.partial(_p2_enc_packed_rows_kernel, bits=spec.bits),
                x2d, srow, "half", True, jnp.int8)
        return QTensor(codes.reshape(x.shape[:-1] + (codes.shape[-1],)),
                       jnp.asarray(scale), spec, x.shape)

    def _decode_packed(self, qt: QTensor, dtype):
        last = qt.shape[-1] if qt.shape else 1
        if self._scalar(qt.scale):
            q2d = qt.codes.reshape(-1, qt.codes.shape[-1])
            out = _packed_call(_p2_dec_packed_kernel, q2d, qt.scale,
                               "double", False, dtype)
        else:
            rw = _rowwise_lastdim(qt.codes, qt.scale)
            if rw is None:
                _note_fallback()
                return super().decode(qt, dtype)
            q2d, srow = rw
            out = _packed_call(_p2_dec_packed_rows_kernel, q2d, srow,
                               "double", True, dtype)
        return out[:, :last].reshape(qt.shape).astype(dtype)

    def decode(self, qt: QTensor, dtype=jnp.float32):
        if qt.spec.packed:
            return self._decode_packed(qt, dtype)
        if self._scalar(qt.scale):
            return _flat_call(_p2_dec_kernel, qt.codes, qt.scale, dtype)
        rw = _rowwise(qt.codes, qt.scale)
        if rw is None:
            _note_fallback()
            return super().decode(qt, dtype)
        q2d, srow = rw
        out = _rowscale_call(_p2_dec_rows_kernel, q2d, srow, dtype)
        return out.reshape(qt.codes.shape)

    def fake_quant(self, x, spec: QuantSpec, scale):
        if self._scalar(scale):
            return _p2_fake_quant_pallas(x, scale, spec.bits)
        x = jnp.asarray(x)
        if _rowwise(x, scale) is None:
            # scale doesn't follow the leading-dim broadcast convention
            # (e.g. per-element scales): reference fallback, counted
            _note_fallback()
            return super().fake_quant(x, spec, scale)
        return _p2_fake_quant_rows(x, jnp.asarray(scale), spec.bits)


# ---------------------------------------------------------------------------
# blockwise kernels
# ---------------------------------------------------------------------------

def _bw_enc_kernel(x_ref, q_ref, s_ref, *, qmax: float):
    x = x_ref[...].astype(jnp.float32)                 # (bm, b)
    sc = jnp.max(jnp.abs(x), axis=1, keepdims=True) / qmax
    q = jnp.round(x / jnp.maximum(sc, 1e-20))
    q_ref[...] = jnp.clip(q, -qmax, qmax).astype(q_ref.dtype)
    s_ref[...] = sc


def _bw_dec_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32) * s_ref[...]
                  ).astype(o_ref.dtype)


class BlockwisePallas(BlockwiseReference):
    backend = "pallas"

    def encode(self, x, spec: QuantSpec, scale=None):
        v = x.astype(jnp.float32)
        if v.ndim == 0:
            v = v[None]
        shape = v.shape
        from .codecs import blockwise_geometry
        b, nb, pad = blockwise_geometry(spec, shape[-1])
        if pad:
            v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, pad)])
        rows = 1
        for d in v.shape[:-1]:
            rows *= d
        x2d = v.reshape(rows, nb * b)
        bm = _blk(rows, 256, 8)
        xp = _pad2d(x2d, bm, b)
        mp = xp.shape[0]
        codes, sc = pl.pallas_call(
            functools.partial(_bw_enc_kernel, qmax=spec.qmax),
            grid=(mp // bm, nb),
            in_specs=[pl.BlockSpec((bm, b), lambda i, j: (i, j))],
            out_specs=[pl.BlockSpec((bm, b), lambda i, j: (i, j)),
                       pl.BlockSpec((bm, 1), lambda i, j: (i, j))],
            out_shape=[jax.ShapeDtypeStruct((mp, nb * b), spec.jnp_storage),
                       jax.ShapeDtypeStruct((mp, nb), jnp.float32)],
            interpret=_interpret(),
        )(xp)
        codes = codes[:rows].reshape(v.shape[:-1] + (nb * b,))
        sc = sc[:rows].reshape(v.shape[:-1] + (nb,))
        return QTensor(codes, sc, spec, shape)

    def decode(self, qt: QTensor, dtype=jnp.float32):
        nb = qt.scale.shape[-1]
        b = qt.codes.shape[-1] // nb
        rows = 1
        for d in qt.codes.shape[:-1]:
            rows *= d
        q2d = qt.codes.reshape(rows, nb * b)
        s2d = qt.scale.reshape(rows, nb)
        bm = _blk(rows, 256, 8)
        qp = _pad2d(q2d, bm, b)
        sp = _pad2d(s2d, bm, 1)
        mp = qp.shape[0]
        out = pl.pallas_call(
            _bw_dec_kernel,
            grid=(mp // bm, nb),
            in_specs=[pl.BlockSpec((bm, b), lambda i, j: (i, j)),
                      pl.BlockSpec((bm, 1), lambda i, j: (i, j))],
            out_specs=pl.BlockSpec((bm, b), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((mp, nb * b), jnp.float32),
            interpret=_interpret(),
        )(qp, sp)
        flat = out[:rows].reshape(qt.codes.shape[:-1] + (nb * b,))
        sliced = flat[..., :qt.shape[-1]] if qt.shape else flat[..., :1]
        return sliced.reshape(qt.shape).astype(dtype)


register_codec("pow2", "pallas", Pow2Pallas())
register_codec("blockwise", "pallas", BlockwisePallas())

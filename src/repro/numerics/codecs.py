"""Codec registry: ``encode / decode / fake_quant`` for every QuantSpec,
with selectable backends.

A *codec* implements one ``QuantSpec.kind`` on one backend:

- ``"reference"``: pure jnp — the numerics oracle, runs everywhere.
- ``"pallas"``: fused Pallas kernels (``numerics/pallas_backend.py``),
  bit-identical to the reference (asserted by tests/test_numerics.py);
  pads to TPU block multiples internally so callers never pre-pad.

The three operations:

- ``encode(x, spec, scale)`` -> QTensor of integer codes (+ scale metadata).
  pow2 takes the caller's ``scale_log2`` (scalar or broadcastable against
  x's leading dims); blockwise derives per-block scales from the data and
  ignores ``scale``.
- ``decode(qt, dtype)`` -> dequantized array in ``dtype``.
- ``fake_quant(x, spec, scale)`` -> quantize-dequantize in one step. For
  pow2 this is the paper's Q(.) with the clipped straight-through estimator
  in the backward pass (§3.2); for blockwise it is a plain-STE roundtrip
  (used outside autodiff anyway: optimizer state, gradient wire).

Exact numerics contracts (kept bit-identical to the pre-refactor sites):

- pow2 fake_quant computes in ``x.dtype`` with ``scale = exp2(k)`` cast to
  ``x.dtype`` (core/quant.py semantics — the grid the QAT tests pin down).
- pow2 encode/decode compute in f32 (serve/kv_cache.py semantics — codes
  are storage, not autodiff values).
- blockwise uses symmetric ±(2^{b-1}-1) codes with ``scale = absmax/qmax``
  floored at 1e-20 (optim/adam.py, optim/grad_compress.py semantics).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .spec import QTensor, QuantSpec, qrange


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack int4 codes (values in [-8, 7]) two-per-byte along the trailing
    axis. Odd trailing dims get one zero pad nibble (the high nibble of the
    last byte). Returns int8 of shape ``q.shape[:-1] + (ceil(last/2),)``."""
    last = q.shape[-1]
    v = q.astype(jnp.int32)
    if last % 2:
        v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, 1)])
    lo = v[..., 0::2] & 0xF
    hi = v[..., 1::2] & 0xF
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jax.Array, last: int) -> jax.Array:
    """Inverse of ``pack_int4``: int8 bytes -> int32 codes in [-8, 7] of
    trailing dim ``last`` (the pad nibble, if any, is sliced away)."""
    v = packed.astype(jnp.int32) & 0xFF
    lo = ((v & 0xF) ^ 8) - 8                 # sign-extend each nibble
    hi = ((v >> 4) ^ 8) - 8
    q = jnp.stack([lo, hi], axis=-1).reshape(
        packed.shape[:-1] + (packed.shape[-1] * 2,))
    return q[..., :last]


def _bcast(scale: jax.Array, ndim: int) -> jax.Array:
    """Right-pad ``scale``'s shape with 1s so it broadcasts against the
    *leading* dims of an ndim-D tensor (the kv-cache layout: one scale per
    (layer, slot), data (L, S, *feat))."""
    scale = jnp.asarray(scale)
    return scale.reshape(scale.shape + (1,) * (ndim - scale.ndim))


# ---------------------------------------------------------------------------
# pow2: fake-quant with clipped STE (the canonical §3.2 Q(.))
# ---------------------------------------------------------------------------

def pow2_qdq(x: jax.Array, scale_log2: jax.Array, bits: int) -> jax.Array:
    """Raw quantize-dequantize on the pow-2 grid in ``x.dtype`` — the Q(.)
    of paper Eq. (3), no gradient rule attached."""
    scale = jnp.exp2(scale_log2).astype(x.dtype)
    lo, hi = qrange(bits)
    return jnp.clip(jnp.round(x / scale), lo, hi) * scale


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def pow2_fake_quant(x: jax.Array, scale_log2: jax.Array, bits: int) -> jax.Array:
    """Quantize-dequantize on the pow-2 grid; clipped STE backward: the
    gradient passes where the pre-quant value was representable, zero
    outside (the paper's "clipped ReLU" STE)."""
    return pow2_qdq(x, scale_log2, bits)


def _p2fq_fwd(x, scale_log2, bits):
    scale = jnp.exp2(scale_log2).astype(x.dtype)
    lo, hi = qrange(bits)
    inside = (x / scale >= lo) & (x / scale <= hi)
    q = jnp.clip(jnp.round(x / scale), lo, hi)
    return q * scale, inside


def _p2fq_bwd(bits, inside, g):
    return (jnp.where(inside, g, 0.0).astype(g.dtype), None)


pow2_fake_quant.defvjp(_p2fq_fwd, _p2fq_bwd)


class Pow2Reference:
    """Reference jnp pow-2 codec."""
    kind = "pow2"
    backend = "reference"

    def encode(self, x: jax.Array, spec: QuantSpec,
               scale: jax.Array) -> QTensor:
        lo, hi = qrange(spec.bits)
        step = jnp.exp2(_bcast(scale, x.ndim))
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / step), lo, hi)
        if spec.packed:
            # 0-d: pack as one (1,)-code row; decode's `shape or (1,)`
            # mirrors this (scalars carry one nibble + one pad nibble)
            return QTensor(pack_int4(q[None] if q.ndim == 0 else q),
                           jnp.asarray(scale), spec, x.shape)
        return QTensor(q.astype(spec.jnp_storage), jnp.asarray(scale), spec,
                       x.shape)

    def decode(self, qt: QTensor, dtype=jnp.float32) -> jax.Array:
        codes = qt.codes
        if qt.spec.packed:
            codes = unpack_int4(codes, qt.shape[-1] if qt.shape else 1)
        step = jnp.exp2(_bcast(qt.scale, codes.ndim))
        out = codes.astype(jnp.float32) * step
        return out.reshape(qt.shape).astype(dtype) if qt.spec.packed \
            else out.astype(dtype)

    def epilogue(self, acc: jax.Array, spec: QuantSpec,
                 scale_log2: jax.Array) -> jax.Array:
        """Requantize-on-writeback: the FPGA PE's fused epilogue, owned by
        the codec registry so `kernels/ttm_pe1.py` and the unfused
        encode→decode reference path share ONE round/clip/scale body
        (bit-identity asserted by tests/test_kernels.py). Kernel-safe:
        plain jnp on an f32 accumulator, no custom_vjp."""
        scale = jnp.exp2(jnp.asarray(scale_log2).astype(jnp.float32))
        lo, hi = qrange(spec.bits)
        return jnp.clip(jnp.round(acc / scale), lo, hi) * scale

    def fake_quant(self, x: jax.Array, spec: QuantSpec,
                   scale: jax.Array) -> jax.Array:
        # _bcast keeps the codec API's one scale convention across all
        # three ops: non-scalar scales broadcast against x's LEADING dims
        # (encode/decode semantics), not numpy trailing alignment — so a
        # per-layer (L, 1) scale means the same thing everywhere. Scalars
        # are unchanged (core/quant.py's QAT grid stays bit-identical).
        return pow2_fake_quant(x, _bcast(jnp.asarray(scale), x.ndim),
                               spec.bits)


# ---------------------------------------------------------------------------
# blockwise: per-block absmax along the last axis
# ---------------------------------------------------------------------------

def blockwise_geometry(spec: QuantSpec, last: int) -> tuple[int, int, int]:
    """(block, num_blocks, pad) along a last axis of size ``last``. The block
    clamps to the axis so the codes keep the leading shape of the input —
    shape preservation is what lets q8 optimizer state carry the SAME
    sharding as its parameter (see optim/adam.py)."""
    b = min(spec.block, max(1, last))
    nb = -(-last // b)
    return b, nb, nb * b - last


class BlockwiseReference:
    """Reference jnp blockwise-absmax codec (Dettmers-style)."""
    kind = "blockwise"
    backend = "reference"

    def encode(self, x: jax.Array, spec: QuantSpec,
               scale=None) -> QTensor:
        v = x.astype(jnp.float32)
        if v.ndim == 0:
            v = v[None]
        shape = v.shape
        b, nb, pad = blockwise_geometry(spec, shape[-1])
        if pad:
            v = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, pad)])
        blocks = v.reshape(v.shape[:-1] + (nb, b))
        qmax = spec.qmax
        sc = jnp.max(jnp.abs(blocks), axis=-1) / qmax
        q = jnp.round(blocks / jnp.maximum(sc, 1e-20)[..., None])
        codes = jnp.clip(q, -qmax, qmax).astype(spec.jnp_storage)
        return QTensor(codes.reshape(v.shape[:-1] + (nb * b,)), sc, spec,
                       shape)

    def decode(self, qt: QTensor, dtype=jnp.float32) -> jax.Array:
        nb = qt.scale.shape[-1]
        b = qt.codes.shape[-1] // nb
        blocks = qt.codes.astype(jnp.float32).reshape(
            qt.codes.shape[:-1] + (nb, b)) * qt.scale[..., None]
        flat = blocks.reshape(qt.codes.shape[:-1] + (nb * b,))
        out = flat[..., :qt.shape[-1]] if qt.shape else flat[..., :1]
        return out.reshape(qt.shape).astype(dtype)

    def fake_quant(self, x: jax.Array, spec: QuantSpec, scale=None) -> jax.Array:
        # plain STE: identity gradient (blockwise sites sit outside autodiff)
        y = self.decode(self.encode(x, spec), x.dtype)
        return x + jax.lax.stop_gradient(y - x)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_CODECS: dict[tuple[str, str], object] = {
    ("pow2", "reference"): Pow2Reference(),
    ("blockwise", "reference"): BlockwiseReference(),
}

BACKENDS = ("reference", "pallas")


def register_codec(kind: str, backend: str, codec) -> None:
    _CODECS[(kind, backend)] = codec


def get_codec(spec: QuantSpec | str, backend: str = "reference"):
    """Codec for ``spec`` on ``backend``. The Pallas backend registers
    lazily on first request (keeps import light off-TPU)."""
    kind = spec if isinstance(spec, str) else spec.kind
    key = (kind, backend)
    if key not in _CODECS and backend == "pallas":
        from . import pallas_backend  # noqa: F401  (registers on import)
    if key not in _CODECS:
        raise KeyError(f"no codec for kind={kind!r} backend={backend!r}; "
                       f"registered: {sorted(_CODECS)}")
    return _CODECS[key]


# Module-level conveniences (the API most call sites use) -------------------

def encode(x: jax.Array, spec: QuantSpec, scale=None,
           backend: str = "reference") -> QTensor:
    return get_codec(spec, backend).encode(x, spec, scale)


def decode(qt: QTensor, dtype=jnp.float32,
           backend: str = "reference") -> jax.Array:
    return get_codec(qt.spec, backend).decode(qt, dtype)


def fake_quant(x: jax.Array, spec: QuantSpec, scale=None,
               backend: str = "reference") -> jax.Array:
    return get_codec(spec, backend).fake_quant(x, spec, scale)


def fake_quant_stats(x: jax.Array, spec: QuantSpec, scale=None,
                     backend: str = "reference"
                     ) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """``fake_quant`` with a quant-health aux output: ``(y, (clipped,
    total))`` int32 counts of values outside the representable range.

    The counts are integer-exact functions of (x, scale), so the reference
    and Pallas backends agree BITWISE (tests/test_obs.py). For blockwise
    specs the scale is data-derived (absmax covers the range), so the aux
    reports saturated codes instead — the same "pinned at the grid edge"
    health signal."""
    from ..obs.counters import pow2_clip_stats, saturation_counts
    y = fake_quant(x, spec, scale, backend)
    if spec.kind == "pow2":
        return y, pow2_clip_stats(x, scale, spec.bits)
    return y, saturation_counts(get_codec(spec, backend).encode(x, spec,
                                                                scale))


def roundtrip(x: jax.Array, spec: QuantSpec, scale=None,
              backend: str = "reference") -> jax.Array:
    """decode(encode(x)) without STE — pure value quantization (used on
    optimizer state and the gradient wire, where no gradient flows)."""
    codec = get_codec(spec, backend)
    return codec.decode(codec.encode(x, spec, scale), x.dtype)


def per_tensor_max_scale_log2(x: jax.Array, spec: QuantSpec,
                              valid=None, reduce_axes=None) -> jax.Array:
    """``scale_policy="per_tensor_max"``: smallest pow-2 step whose ±qmax
    range covers max|x| (serve/kv_cache.py's prefill scale choice).

    ``valid``: optional bool mask broadcastable against x (rows to include).
    ``reduce_axes``: axes folded into the max (default: all).
    """
    a = jnp.abs(x.astype(jnp.float32))
    if valid is not None:
        a = a * valid
    maxabs = jnp.max(a) if reduce_axes is None else jnp.max(a, axis=reduce_axes)
    return jnp.ceil(jnp.log2(jnp.maximum(maxabs, 1e-8) / spec.qmax))

"""Continuous-batching inference engine.

One fixed-shape jitted decode step serves the whole request stream: requests
occupy *slots* of a ``num_slots``-lane batch, each with its own length in a
per-slot ``cur_len`` vector; EOS / max-length retirement frees a slot (and
its cache pages) which the scheduler refills on the next iteration, so the
decode batch never drains to admit new work.  K/V live in the slot-paged,
optionally int8-quantized pool of ``serve/kv_cache.py`` and are dequantized
on read inside the per-layer scan.

Sublayer routing: attention sublayers read/write the slot-paged KV pool
(``serve/kv_cache.py``, gather or fused paged-attention); SSM/RWKV
sublayers read/write the slot-indexed recurrent-state cache
(``serve/state_cache.py``) through the single-step decode entry points of
``models/ssm.py`` — so pure-SSM (rwkv6), hybrid (jamba) and all-attention
configs run under one continuous-batching regime.

Numerics contract: in fp (non-quantized) mode the engine's prefill is the
model's own ``lm_forward`` and its decode runs the exact attend helpers of
``models/attention.py`` (and the exact recurrence steps of
``models/ssm.py``) over the same cached values/state, so continuous-batched
greedy decode is token-identical to the static single-request reference
(asserted by tests/test_serve.py and tests/test_serve_state.py). MoE:
inactive decode slots, chunked-prefill tail padding, and whole-prompt
prefill bucket padding are all masked out of the router (zero combine
weight -> they can never win a capacity slot against a real token; see
``models/moe.py::_route`` and ``lm_forward(token_mask=...)``).

Archs with recurrent state ignore ``prefill_bucket`` and pad no prefill
chunks: a pad token would contaminate the scan-carried state (attention can
trash-page a pad write; a recurrence cannot unwind one), so their prefill
shapes are exact-length.

Supported archs: every decoder family in the zoo — dense / MoE, GQA or
MLA, pure-SSM (rwkv6), hybrid (jamba). Frontend (vision/audio) archs are
an open roadmap item.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..numerics import NumericsPolicy
from ..models import attention as A
from ..models import ssm as S
from ..models.common import apply_site, rms_norm
from ..models.lm import LMDef, embed_tokens, lm_forward, sub_ffn_decode
from ..sharding import ShardPlan
from . import kv_cache as KC
from . import state_cache as SC
from .bucketing import CompileCache, bucket_len
from .kv_cache import PoolConfig
from .metrics import ServeMetrics
from .prefix import RadixPrefixCache
from .sampling import (SamplingParams, processed_probs, sample_from_probs,
                       sample_tokens, spec_accept)
from .scheduler import Request, Scheduler


class Completion(NamedTuple):
    rid: int
    prompt: list[int]
    tokens: list[int]           # generated tokens (first token included)


@dataclass(frozen=True)
class EngineConfig:
    pool: PoolConfig
    prefill_chunk: int = 0      # 0: whole-prompt prefill only
    prefill_bucket: int = 0     # pad prompts to a multiple of this to bound
                                # compile count (0: exact length). Pad
                                # tokens are masked out of MoE routing;
                                # archs with recurrent state ignore the
                                # bucket (pads would contaminate the
                                # scan-carried state)
    seed: int = 0
    policy: "NumericsPolicy | None" = None
                                # numerics policy: when set, its ``kv_cache``
                                # site overrides the pool's quantized/bits
                                # knobs (one owner for the system's numerics)
    fused_attention: bool = False
                                # decode attends via the fused paged-
                                # attention kernel (per-page in-kernel int8
                                # dequant + online softmax) instead of
                                # gather_slots + attend. GQA sublayers only;
                                # MLA sublayers keep the gather reference
                                # (fused MLA is an open roadmap item)
    fused_impl: str = "auto"    # "auto" | "pallas" | "jnp" — see
                                # kernels/ops.py::paged_attention
    prefix_cache: bool = False
                                # radix-tree COW prefix sharing over the
                                # paged pool (serve/prefix.py). Attention-
                                # only archs; archs with recurrent state
                                # silently take the always-miss path (their
                                # O(1) state is not per-token addressable)
    max_prefill_shapes: int = 0
                                # bound on live jitted prefill shapes
                                # (whole-prompt + chunk widths); LRU-evicted
                                # beyond it (serve/bucketing.py). 0:
                                # unbounded (the pre-policy behavior)
    moe_capacity_by_prompt: bool = False
                                # MoE chunked-prefill capacity parity:
                                # derive expert capacity from the FULL
                                # prompt length instead of the visible
                                # chunk, so chunked prefill routes like
                                # whole-prompt at capacity-bound loads
    spec_k: int = 0             # speculative decoding: draft tokens
                                # proposed per step (0: off). Needs a draft
                                # model (Engine(..., draft=(lm, params)));
                                # the target verifies all k+1 positions in
                                # ONE q-block kernel call and rejection
                                # sampling accepts a prefix — greedy
                                # spec-decode is token-identical to
                                # non-speculative greedy. Attention-only
                                # draft AND target (recurrent state cannot
                                # roll back a rejected token)


# ---------------------------------------------------------------------------
# Per-sublayer serve bodies (shared by decode + chunked prefill)
# ---------------------------------------------------------------------------

def _project(pm: dict, h: jax.Array, sub, cfg, positions: jax.Array):
    """Queries + new cache entries for one sublayer. h: (B,S,D)."""
    if sub.mixer_kind == "attn_gqa":
        q, k_new, v_new = A.gqa_decode_qkv(pm, h, sub.mixer, cfg, positions)
        return {"q": q}, {"k": k_new, "v": v_new}
    q_abs, q_rope = A.mla_decode_q(pm, h, sub.mixer, cfg, positions)
    c_new, kr_new = A._mla_kv_latent(pm, h, sub.mixer, cfg, positions)
    return ({"q_abs": q_abs, "q_rope": q_rope},
            {"c_kv": c_new, "k_rope": kr_new})


def _attend(pm: dict, qd: dict, kv: dict, sub, cfg,
            positions: jax.Array) -> jax.Array:
    """Attention over gathered (dequantized) cache views + output proj."""
    if sub.mixer_kind == "attn_gqa":
        out = A.gqa_attend(qd["q"], kv["k"], kv["v"], sub.mixer, positions)
    else:
        out = A.mla_attend(pm, qd["q_abs"], qd["q_rope"], kv["c_kv"],
                           kv["k_rope"], sub.mixer, cfg, positions)
    return apply_site(pm["o"], out, sub.mixer.o, cfg)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------

class Engine:
    """Continuous-batching serving engine over a paged, quantized KV pool."""

    def __init__(self, lm: LMDef, params, ecfg: EngineConfig,
                 plan: ShardPlan | None = None, clock=time.monotonic,
                 trace=None, draft=None):
        cfg = lm.cfg
        if cfg.is_encoder:
            raise NotImplementedError("encoder-only archs have no decode path")
        if cfg.frontend != "none":
            raise NotImplementedError(
                "frontend (vision/audio) serving is an open roadmap item")
        for sub in lm.period:
            KC.kv_feature_shapes(sub)   # raises for unknown mixer kinds
        # per-sublayer routing: attention -> paged KV pool, SSM/RWKV ->
        # slot-indexed recurrent-state cache
        self._attn_keys = tuple(
            f"sub_{i}" for i, sub in enumerate(lm.period)
            if sub.mixer_kind in ("attn_gqa", "attn_mla"))
        self._state_keys = tuple(
            f"sub_{i}" for i, sub in enumerate(lm.period)
            if sub.mixer_kind in ("mamba", "rwkv6"))
        self.lm = lm
        self.params = params
        self.ecfg = ecfg
        pcfg = ecfg.pool
        squant, sbits = pcfg.quantized, pcfg.bits
        if ecfg.policy is not None:
            kv = ecfg.policy.spec_for("kv_cache")
            pcfg = dataclasses.replace(pcfg, quantized=ecfg.policy.enable,
                                       bits=kv.bits)
            if self._state_keys:    # only validated where a state pool
                try:                # will actually exist
                    ss = ecfg.policy.spec_for("ssm_state")
                except KeyError:    # pre-ssm_state policy JSON: follow kv
                    ss = kv
                if (ss.kind, ss.storage_dtype) != ("pow2", "int8"):
                    raise NotImplementedError(
                        f"state cache stores pow2 int8 codes only, "
                        f"ssm_state site asks for "
                        f"{ss.kind}/{ss.storage_dtype}")
                squant, sbits = ecfg.policy.enable, ss.bits
        self.pcfg = pcfg
        self.scfg = SC.StateCacheConfig(quantized=squant, bits=sbits)
        self.plan = plan or ShardPlan(mesh=None)
        self.pool = KC.init_pool(lm, self.pcfg)
        self.spool = SC.init_state_pool(lm, self.pcfg.num_slots, self.scfg)
        # multi-device serving: place params and both pools by the plan —
        # KV pages head-sharded over ``model`` (plan.kv_page_spec), state
        # features over d_inner/heads (plan.state_spec), per-slot scales
        # replicated. The jitted step bodies re-assert these shardings on
        # their pool outputs (_ckv/_cst) so the donated buffers keep their
        # layout across steps; with no mesh both helpers are identity and
        # every jaxpr is unchanged (tests/test_obs.py byte-identity).
        self._pool_ns = self._spool_ns = None
        if self.plan.mesh is not None:
            self._pool_ns = self.plan.kv_pool_sharding(self.pool)
            self._spool_ns = self.plan.state_pool_sharding(self.spool)
            self.params = jax.device_put(
                self.params, self.plan.params_sharding_tree(self.params))
            self.pool = jax.device_put(self.pool, self._pool_ns)
            self.spool = jax.device_put(self.spool, self._spool_ns)
        # optional obs.TraceRecorder: host-side only — events are emitted
        # from the untraced step loop, never inside a jitted body, so an
        # attached recorder leaves every jaxpr unchanged (tests/test_obs.py
        # asserts the decode jaxpr is byte-identical with/without it)
        self.trace = trace
        # quant-health aggregates (repro.obs): Python-gated at trace time so
        # the disabled decode jaxpr is identical to a health-free build
        health = ecfg.policy is not None and ecfg.policy.health
        self._health_kv = health and pcfg.quantized and bool(self._attn_keys)
        self._health_state = health and squant and bool(self._state_keys)
        self._health = self._health_kv or self._health_state
        # prefix sharing needs per-token paged memory: attention-only archs
        # opt in; any recurrent sublayer routes every request down the
        # ordinary full-prefill miss path (the cache is simply absent)
        self._prefix = (RadixPrefixCache(self.pcfg.page_size,
                                         self.pcfg.total_pages, trace=trace)
                        if (ecfg.prefix_cache and self._attn_keys
                            and not self._state_keys) else None)
        # pure-SSM archs have no token-paged memory: admission is slot-only
        self.sched = Scheduler(self.pcfg, ecfg.prefill_chunk,
                               paged=bool(self._attn_keys), trace=trace,
                               prefix=self._prefix)
        self.metrics = ServeMetrics(clock=clock)
        self.metrics.num_slots = self.pcfg.num_slots
        self.metrics.cache_bytes = KC.pool_bytes(self.pool)
        self.metrics.cache_bytes_fp32 = KC.pool_bytes_fp32(self.pool)
        self.metrics.state_bytes = SC.pool_bytes(self.spool)
        self.metrics.state_bytes_fp32 = SC.pool_bytes_fp32(self.spool)
        # live memory ledger (repro.obs): every resident site reports in.
        # Pools are preallocated, so their byte totals are fixed at init;
        # what moves per phase is the prefix overlay (logical vs physical
        # mapped pages — the verified bytes behind ``pages_saved``) and the
        # compile-cache population. Host-side only, like the trace.
        from ..obs import MemoryLedger
        self.ledger = MemoryLedger()
        self._page_nbytes = (KC.page_nbytes(self.pool, self.pcfg)
                             if self._attn_keys else 0)
        self._params_nbytes = sum(
            int(l.nbytes) for l in jax.tree_util.tree_leaves(self.params))
        self._params_nbytes_fp32 = 4 * sum(
            int(l.size) for l in jax.tree_util.tree_leaves(self.params))
        self._key = jax.random.PRNGKey(ecfg.seed)
        self._nsample = 0
        self._completions: dict[int, Completion] = {}
        self._orig_prompt: dict[int, list[int]] = {}

        def make_prefill(key):
            """Whole-prompt prefill (the model's own forward): numerically
            the static-serving reference. One wrapper per (padded length,
            MoE capacity override) so the compile cache can evict whole
            executables; ``prefill_bucket`` bounds how many keys occur.
            Bucket padding is masked out of the MoE router via
            ``token_mask``."""
            _, cap = key

            def prefill(params, tokens, length):
                mask = (jnp.arange(tokens.shape[1]) < length)[None]
                logits, _, cache = lm_forward(params, lm, self.plan,
                                              tokens=tokens,
                                              return_cache=True,
                                              token_mask=mask,
                                              capacity_tokens=cap)
                return logits[0, length - 1][None], cache

            return jax.jit(prefill)

        def make_chunk(key):
            """Chunked-prefill step, one wrapper per (chunk width, MoE
            capacity override) — same eviction story as make_prefill."""
            _, cap = key
            return jax.jit(partial(self._chunk_impl, capacity_tokens=cap),
                           donate_argnums=(1, 2))

        # bounded LRUs of live jitted prefill shapes (serve/bucketing.py);
        # the decode step is a single fixed shape and never evicts
        self._prefill_fns = CompileCache(make_prefill,
                                         max_live=ecfg.max_prefill_shapes)
        self._chunk_fns = CompileCache(make_chunk,
                                       max_live=ecfg.max_prefill_shapes)
        self._decode_jit = jax.jit(self._decode_impl, donate_argnums=(1, 2))
        self._write_prefill_jit = jax.jit(self._write_prefill_impl,
                                          donate_argnums=(0,),
                                          static_argnames=("pcfg",))
        self._write_state_jit = jax.jit(self._write_state_impl,
                                        donate_argnums=(0,),
                                        static_argnames=("scfg",))
        self._reset_state_jit = jax.jit(self._reset_state_impl,
                                        donate_argnums=(0,))
        self._fork_jit = jax.jit(self._fork_impl, donate_argnums=(0,))
        self._adopt_jit = jax.jit(self._adopt_impl, donate_argnums=(0,))
        self._sample_jit = jax.jit(sample_tokens)
        # ---- speculative decoding (ecfg.spec_k > 0) --------------------
        if ecfg.spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {ecfg.spec_k}")
        self._spec = ecfg.spec_k > 0
        if self._spec and draft is None:
            raise ValueError("spec_k > 0 needs a draft model: "
                             "Engine(..., draft=(draft_lm, draft_params))")
        if self._spec:
            dlm, dparams = draft
            if self._state_keys:
                raise NotImplementedError(
                    "speculative decoding needs an attention-only TARGET: "
                    "recurrent state advanced through a rejected draft "
                    "token cannot be rolled back")
            for sub in dlm.period:
                if sub.mixer_kind not in ("attn_gqa", "attn_mla"):
                    raise NotImplementedError(
                        "speculative decoding needs an attention-only "
                        f"DRAFT (got mixer {sub.mixer_kind!r})")
            if dlm.cfg.vocab_size != cfg.vocab_size:
                raise ValueError(
                    f"draft vocab {dlm.cfg.vocab_size} != target vocab "
                    f"{cfg.vocab_size}")
            if self.plan.mesh is not None:
                raise NotImplementedError(
                    "draft-model sharding is an open roadmap item — run "
                    "speculative decoding mesh-less")
            self._draft = dlm
            self._draft_params = dparams
            self._draft_attn_keys = tuple(
                f"sub_{i}" for i, _ in enumerate(dlm.period))
            # the draft pool mirrors the target's geometry/numerics but
            # shares nothing: a STATIC identity page table (slot i owns
            # pages i*pp .. (i+1)*pp-1) removes every allocator interplay —
            # draft-side rollback is just the length vector not advancing,
            # and junk K/V above a slot's length is masked by the same
            # causal length mask as on the target side
            self._draft_pcfg = dataclasses.replace(self.pcfg, num_pages=0)
            self._draft_pool = KC.init_pool(dlm, self._draft_pcfg)
            pp = self._draft_pcfg.pages_per_slot
            self._draft_table = jnp.asarray(
                np.arange(self.pcfg.num_slots * pp,
                          dtype=np.int32).reshape(self.pcfg.num_slots, pp))
            self._draft_pool_bytes = KC.pool_bytes(self._draft_pool)
            self._draft_pool_bytes_fp32 = KC.pool_bytes_fp32(self._draft_pool)
            self._draft_params_nbytes = sum(
                int(l.nbytes) for l in jax.tree_util.tree_leaves(dparams))
            self._draft_params_nbytes_fp32 = 4 * sum(
                int(l.size) for l in jax.tree_util.tree_leaves(dparams))

            def make_draft_prefill(length):
                def dprefill(params, tokens, valid_len):
                    mask = (jnp.arange(tokens.shape[1]) < valid_len)[None]
                    _, _, cache = lm_forward(params, dlm, self.plan,
                                             tokens=tokens,
                                             return_cache=True,
                                             token_mask=mask)
                    return cache
                return jax.jit(dprefill)

            self._draft_prefill_fns = CompileCache(
                make_draft_prefill, max_live=ecfg.max_prefill_shapes)
            self._draft_propose_jit = jax.jit(self._draft_propose_impl,
                                              donate_argnums=(1,))
            self._verify_jit = jax.jit(self._verify_impl,
                                       donate_argnums=(1,))
            self._accept_jit = jax.jit(spec_accept)
        self._ledger_update("init")

    # ---- jitted step bodies -------------------------------------------
    def _ckv(self, pool):
        """Re-assert the KV pool's plan sharding on a jitted body's output
        so donation round-trips the layout (head-sharded pages stay head-
        sharded). Mesh-less engines: identity — jaxprs are unchanged."""
        if self._pool_ns is None:
            return pool
        return jax.tree.map(jax.lax.with_sharding_constraint, pool,
                            self._pool_ns)

    def _cst(self, spool):
        if self._spool_ns is None:
            return spool
        return jax.tree.map(jax.lax.with_sharding_constraint, spool,
                            self._spool_ns)

    def _write_prefill_impl(self, pool, cache, table_row, slot, length,
                            pcfg):
        return self._ckv(KC.write_prefill(pool, cache, table_row, slot,
                                          length, pcfg))

    def _write_state_impl(self, spool, cache, slot, scfg):
        return self._cst(SC.write_prefill(spool, cache, slot, scfg))

    def _reset_state_impl(self, spool, slot):
        return self._cst(SC.reset_slot(spool, slot))

    def _fork_impl(self, pool, src, dst):
        # COW fork on (possibly head-sharded) pages: the copy indexes the
        # unsharded page axis only, so each shard forks its own head slice
        # of the page — codes verbatim, no cross-device traffic
        return self._ckv(KC.fork_page(pool, src, dst))

    def _adopt_impl(self, pool, slot, snap):
        return self._ckv(KC.adopt_scales(pool, slot, snap))

    def _fused_for(self, sub) -> bool:
        """Fused-kernel eligibility of one sublayer (the fallback matrix:
        GQA/MQA/MHA fused; MLA latent attention stays on the gather
        reference — its absorbed-weight einsums need a dedicated kernel)."""
        return self.ecfg.fused_attention and sub.mixer_kind == "attn_gqa"

    def _sub_decode(self, pp, x, dsub, ssub, table, lens, active, sub,
                    health=None):
        cfg = self.lm.cfg
        h = rms_norm(x, pp["norm1"]["scale"], cfg.norm_eps)
        positions = A.len_positions(lens, x.shape[0])
        qd, newd = _project(pp["mixer"], h, sub, cfg, positions)
        if health is not None and self._health_kv:
            # clip counts of this append vs the prefill-frozen slot scales
            for name, new in newd.items():
                health["kv"].append(
                    KC.append_health(new, ssub[name], active, self.pcfg))
        new_dsub = {name: KC.append_token(dsub[name], ssub[name], new, table,
                                          lens, active, self.pcfg)
                    for name, new in newd.items()}
        if self._fused_for(sub):
            # fused path: attend straight off the int8 pages — per-page
            # dequant + online softmax inside the kernel, no gathered view
            d = sub.mixer
            b = x.shape[0]
            attn = KC.fused_attend(new_dsub["k"], new_dsub["v"], ssub["k"],
                                   ssub["v"], qd["q"][:, 0], table, lens,
                                   self.pcfg, impl=self.ecfg.fused_impl,
                                   plan=self.plan)
            attn = attn[:, :d.real_heads].reshape(b, 1,
                                                  d.real_heads * d.head_dim)
            out = apply_site(pp["mixer"]["o"], attn, d.o, cfg)
        else:
            kv = {name: KC.gather_slots(new_dsub[name], ssub[name], table,
                                        self.pcfg, h.dtype)
                  for name in new_dsub}
            out = _attend(pp["mixer"], qd, kv, sub, cfg, positions)
        x = x + out
        # inactive slots are masked out of the MoE router: their junk
        # tokens must not consume expert capacity (ROADMAP item)
        return sub_ffn_decode(pp, x, sub, cfg, self.plan,
                              token_mask=active[:, None]), new_dsub

    def _sub_decode_state(self, pp, x, sd, ss, active, sub, health=None):
        """One recurrent sublayer of the batched decode step: dequantize
        every slot's state, advance one token through the mixer's
        single-step entry point, requantize active lanes (inactive lanes
        keep their stored codes + scale)."""
        cfg = self.lm.cfg
        shapes = SC.state_feature_shapes(sub, cfg)
        state = {name: SC.read_layer(sd[name], ss[name],
                                     SC.natural_dtype(kind, cfg), self.scfg)
                 for name, (_, kind) in shapes.items()}
        h = rms_norm(x, pp["norm1"]["scale"], cfg.norm_eps)
        if sub.mixer_kind == "mamba":
            out, new_state = S.mamba_decode_step(pp["mixer"], h, sub.mixer,
                                                 cfg, state)
            x = x + out
            x = sub_ffn_decode(pp, x, sub, cfg, self.plan,
                               token_mask=active[:, None])
        else:   # rwkv6: time-mix + channel-mix are the whole sublayer
            out, st1 = S.rwkv6_time_mix_step(pp["mixer"], h, sub.mixer, cfg,
                                             state)
            x = x + out
            h2 = rms_norm(x, pp["norm2"]["scale"], cfg.norm_eps)
            out2, st2 = S.rwkv6_channel_mix_step(pp["mixer"], h2, sub.mixer,
                                                 cfg, state)
            x = x + out2
            new_state = {**st1, **st2}
        nd, ns = {}, {}
        for name in shapes:
            if health is not None and self._health_state:
                # drift of the re-chosen per-slot scale vs the stored one
                health["state"].append(SC.write_health(
                    ss[name], new_state[name], active, self.scfg))
            nd[name], ns[name] = SC.write_layer(sd[name], ss[name],
                                                new_state[name], active,
                                                self.scfg)
        return x, (nd, ns)

    def _decode_impl(self, params, pool, spool, table, lens, active, tokens):
        """One batched decode step. tokens: (B,1); lens/active: (B,).
        Returns (logits (B,V), new KV pool, new state pool) — plus, when
        quant-health is on (policy.health), a dict of per-site aggregates
        summed over layers. The health path is Python-gated so a disabled
        engine's jaxpr is byte-identical to a health-free build."""
        lm = self.lm
        x = embed_tokens(params, tokens, lm)

        def body(x, scan_in):
            pp, dl, sl, sd, ss = scan_in
            new, snew_d, snew_s = {}, {}, {}
            hc = {"kv": [], "state": []} if self._health else None
            for i, sub in enumerate(lm.period):
                key = f"sub_{i}"
                if sub.mixer_kind in ("mamba", "rwkv6"):
                    x, (nd, ns) = self._sub_decode_state(
                        pp[key], x, sd[key], ss[key], active, sub, health=hc)
                    snew_d[key], snew_s[key] = nd, ns
                    new[key] = dl[key]
                else:
                    x, nd = self._sub_decode(pp[key], x, dl[key], sl[key],
                                             table, lens, active, sub,
                                             health=hc)
                    new[key] = nd
                    snew_d[key], snew_s[key] = sd[key], ss[key]
            if self._health:
                z32 = jnp.asarray(0, jnp.int32)
                zf = jnp.asarray(0.0, jnp.float32)
                h = (sum((s[0] for s in hc["kv"]), z32),
                     sum((s[1] for s in hc["kv"]), z32),
                     sum((s[0] for s in hc["state"]), z32),
                     sum((s[1] for s in hc["state"]), z32),
                     sum((s[2] for s in hc["state"]), zf),
                     sum((s[3] for s in hc["state"]), zf))
                return x, (new, snew_d, snew_s, h)
            return x, (new, snew_d, snew_s)

        x, ys = jax.lax.scan(
            body, x, (params["layers"], pool["data"], pool["scale_log2"],
                      spool["data"], spool["scale_log2"]))
        if self._health:
            new_data, new_sdata, new_sscale, h = ys
        else:
            new_data, new_sdata, new_sscale = ys
        x = rms_norm(x, params["final_norm"]["scale"], lm.cfg.norm_eps)
        logits = apply_site(params["head"], x, lm.head, lm.cfg)
        out = (logits[:, 0],
               self._ckv({"data": new_data,
                          "scale_log2": pool["scale_log2"]}),
               self._cst({"data": new_sdata, "scale_log2": new_sscale}))
        if self._health:
            # per-layer ys stacked on axis 0: fold to per-step totals
            keys = ("kv_clipped", "kv_total", "state_clipped", "state_total",
                    "state_drift_sum", "state_drift_n")
            out = out + ({k: jnp.sum(v) for k, v in zip(keys, h)},)
        return out

    def _chunk_impl(self, params, pool, spool, tokens, table, slot, start,
                    valid_len, capacity_tokens=None):
        """Chunked-prefill step for one slot. Attention sublayers write the
        chunk's K/V into the pool and attend over the slot's full history;
        recurrent sublayers scan the chunk from the slot's carried state and
        write the end-of-chunk state back (stateful archs pad no chunks, so
        ``valid_len == S`` for them). tokens: (1,S).

        ``capacity_tokens`` (static, from the compile-cache key): MoE expert
        capacity derives from this token count instead of the visible chunk
        — the capacity-parity mode that makes chunked routing match
        whole-prompt at capacity-bound loads."""
        lm = self.lm
        cfg = lm.cfg
        s = tokens.shape[1]
        table_row = table[slot]
        positions = (start + jnp.arange(s))[None]          # (1,S)
        chunk_mask = (jnp.arange(s) < valid_len)[None]     # (1,S) real tokens
        x = embed_tokens(params, tokens, lm)

        def attn_sub(x, spp, dsub, ssub, sub):
            h = rms_norm(x, spp["norm1"]["scale"], cfg.norm_eps)
            qd, newd = _project(spp["mixer"], h, sub, cfg, positions)
            nd, ns, kv = {}, {}, {}
            for name, new in newd.items():
                dlay, slay = KC.write_chunk(
                    dsub[name], ssub[name], new[0], table_row, start,
                    valid_len, slot, self.pcfg)
                nd[name], ns[name] = dlay, slay
                kv[name] = KC.gather_slots(dlay, slay[slot][None],
                                           table_row[None], self.pcfg,
                                           h.dtype)
            x = x + _attend(spp["mixer"], qd, kv, sub, cfg, positions)
            # chunk tail padding is masked out of the MoE router
            x = sub_ffn_decode(spp, x, sub, cfg, self.plan,
                               token_mask=chunk_mask,
                               capacity_tokens=capacity_tokens)
            return x, nd, ns

        def state_sub(x, spp, sdsub, sssub, sub):
            shapes = SC.state_feature_shapes(sub, cfg)
            st = {name: SC.read_layer(sdsub[name][slot][None],
                                      sssub[name][slot][None],
                                      SC.natural_dtype(kind, cfg), self.scfg)
                  for name, (_, kind) in shapes.items()}
            h = rms_norm(x, spp["norm1"]["scale"], cfg.norm_eps)
            if sub.mixer_kind == "mamba":
                out, new_st = S.mamba_forward(spp["mixer"], h, sub.mixer,
                                              cfg, st)
                x = x + out
                x = sub_ffn_decode(spp, x, sub, cfg, self.plan,
                                   token_mask=chunk_mask,
                                   capacity_tokens=capacity_tokens)
            else:   # rwkv6
                out, st1 = S.rwkv6_time_mix(spp["mixer"], h, sub.mixer, cfg,
                                            st)
                x = x + out
                h2 = rms_norm(x, spp["norm2"]["scale"], cfg.norm_eps)
                out2, st2 = S.rwkv6_channel_mix(spp["mixer"], h2, sub.mixer,
                                                cfg, st)
                x = x + out2
                new_st = {**st1, **st2}
            nd, ns = {}, {}
            for name in shapes:
                nd[name], ns[name] = SC.write_slot(
                    sdsub[name], sssub[name], new_st[name][0], slot,
                    self.scfg)
            return x, nd, ns

        def body(x, scan_in):
            pp, dl, sl, sd, ss = scan_in
            new_d, new_s, snew_d, snew_s = {}, {}, {}, {}
            for i, sub in enumerate(lm.period):
                key = f"sub_{i}"
                if sub.mixer_kind in ("mamba", "rwkv6"):
                    x, nd, ns = state_sub(x, pp[key], sd[key], ss[key], sub)
                    snew_d[key], snew_s[key] = nd, ns
                    new_d[key], new_s[key] = dl[key], sl[key]
                else:
                    x, nd, ns = attn_sub(x, pp[key], dl[key], sl[key], sub)
                    new_d[key], new_s[key] = nd, ns
                    snew_d[key], snew_s[key] = sd[key], ss[key]
            return x, (new_d, new_s, snew_d, snew_s)

        x, (new_data, new_scale, new_sdata, new_sscale) = jax.lax.scan(
            body, x, (params["layers"], pool["data"], pool["scale_log2"],
                      spool["data"], spool["scale_log2"]))
        x = rms_norm(x, params["final_norm"]["scale"], cfg.norm_eps)
        logits = apply_site(params["head"], x, lm.head, cfg)
        last = logits[0, valid_len - 1][None]              # (1,V)
        return (last,
                self._ckv({"data": new_data, "scale_log2": new_scale}),
                self._cst({"data": new_sdata, "scale_log2": new_sscale}))

    # ---- speculative decoding bodies -----------------------------------
    def _sub_verify(self, pp, x, dsub, ssub, table, lens, active, positions,
                    tmask, sub):
        """One attention sublayer of the verify step: append the whole
        (k+1)-row block's K/V in one batched scatter, then attend every row
        in ONE q-block kernel call — ``_sub_decode`` generalized from S=1.
        Row j sits at position lens+j and attends causally through itself
        (the same append-then-attend self-inclusive semantics as decode)."""
        cfg = self.lm.cfg
        h = rms_norm(x, pp["norm1"]["scale"], cfg.norm_eps)
        qd, newd = _project(pp["mixer"], h, sub, cfg, positions)
        new_dsub = {name: KC.append_tokens(dsub[name], ssub[name], new,
                                           table, lens, active, self.pcfg)
                    for name, new in newd.items()}
        if self._fused_for(sub):
            d = sub.mixer
            b, s = x.shape[:2]
            attn = KC.fused_attend(new_dsub["k"], new_dsub["v"], ssub["k"],
                                   ssub["v"], qd["q"], table, lens,
                                   self.pcfg, impl=self.ecfg.fused_impl,
                                   plan=self.plan)
            attn = attn[:, :, :d.real_heads].reshape(
                b, s, d.real_heads * d.head_dim)
            out = apply_site(pp["mixer"]["o"], attn, d.o, cfg)
        else:
            kv = {name: KC.gather_slots(new_dsub[name], ssub[name], table,
                                        self.pcfg, h.dtype)
                  for name in new_dsub}
            out = _attend(pp["mixer"], qd, kv, sub, cfg, positions)
        x = x + out
        return sub_ffn_decode(pp, x, sub, cfg, self.plan,
                              token_mask=tmask), new_dsub

    def _verify_impl(self, params, pool, table, lens, active, tokens):
        """Target forward over the (B, S=k+1) verify block: the incoming
        token plus the k draft proposals, all scored in one step. The
        q-block twin of ``_decode_impl`` — attention-only archs (enforced
        at init), no health/state branches. Returns ((B, S, V) logits, new
        KV pool); rejected positions' K/V stay as junk above the slot's
        advanced length (see ``kv_cache.append_tokens``)."""
        lm = self.lm
        b, s = tokens.shape
        x = embed_tokens(params, tokens, lm)
        positions = lens[:, None] + jnp.arange(s)[None]
        tmask = jnp.broadcast_to(active[:, None], (b, s))

        def body(x, scan_in):
            pp, dl, sl = scan_in
            new = {}
            for i, sub in enumerate(lm.period):
                key = f"sub_{i}"
                x, nd = self._sub_verify(pp[key], x, dl[key], sl[key],
                                         table, lens, active, positions,
                                         tmask, sub)
                new[key] = nd
            return x, new

        x, new_data = jax.lax.scan(
            body, x, (params["layers"], pool["data"], pool["scale_log2"]))
        x = rms_norm(x, params["final_norm"]["scale"], lm.cfg.norm_eps)
        logits = apply_site(params["head"], x, lm.head, lm.cfg)
        return logits, self._ckv({"data": new_data,
                                  "scale_log2": pool["scale_log2"]})

    def _draft_step(self, dparams, dpool, table, lens, active, tokens):
        """One S=1 decode step of the draft model over its private pool
        (static identity table) — ``_decode_impl`` minus the state/health
        branches (the draft is attention-only by construction). Appends go
        through ``append_tokens`` for its past-horizon trash redirect: a
        draft block overhanging ``max_len`` must not scribble on pages."""
        dlm = self._draft
        cfg = dlm.cfg
        x = embed_tokens(dparams, tokens, dlm)
        positions = A.len_positions(lens, x.shape[0])

        def body(x, scan_in):
            pp, dl, sl = scan_in
            new = {}
            for i, sub in enumerate(dlm.period):
                key = f"sub_{i}"
                h = rms_norm(x, pp[key]["norm1"]["scale"], cfg.norm_eps)
                qd, newd = _project(pp[key]["mixer"], h, sub, cfg,
                                    positions)
                nd = {name: KC.append_tokens(dl[key][name], sl[key][name],
                                             new_, table, lens, active,
                                             self._draft_pcfg)
                      for name, new_ in newd.items()}
                kv = {name: KC.gather_slots(nd[name], sl[key][name], table,
                                            self._draft_pcfg, h.dtype)
                      for name in nd}
                x = x + _attend(pp[key]["mixer"], qd, kv, sub, cfg,
                                positions)
                x = sub_ffn_decode(pp[key], x, sub, cfg, self.plan,
                                   token_mask=active[:, None])
                new[key] = nd
            return x, new

        x, new_data = jax.lax.scan(
            body, x, (dparams["layers"], dpool["data"],
                      dpool["scale_log2"]))
        x = rms_norm(x, dparams["final_norm"]["scale"], cfg.norm_eps)
        logits = apply_site(dparams["head"], x, dlm.head, cfg)
        return logits[:, 0], {"data": new_data,
                              "scale_log2": dpool["scale_log2"]}

    def _draft_propose_impl(self, dparams, dpool, table, lens, active,
                            tokens, key, temp, topk, topp):
        """k draft decode steps (unrolled: k is small and static). Each
        proposal is sampled from the PROCESSED draft distribution Q
        (temperature/top-k/top-p applied) and Q itself is kept — the
        rejection test needs the exact proposal distribution, and greedy
        slots need their one-hots. Returns ((B, k) tokens, (B, k, V)
        probs, new draft pool)."""
        toks, probs = [], []
        cur = tokens
        for i in range(self.ecfg.spec_k):
            logits, dpool = self._draft_step(dparams, dpool, table,
                                             lens + i, active, cur)
            qp = processed_probs(logits, temp, topk, topp)
            t = sample_from_probs(qp, jax.random.fold_in(key, i))
            toks.append(t)
            probs.append(qp)
            cur = t[:, None]
        # trailing cache-fill step: each step above appends its INCOMING
        # token, so after k steps the last proposal d_k has no K/V in the
        # draft pool — and when the target accepts all k, the next round
        # resumes at lens+k+1 and would attend over a zero hole at lens+k.
        # Feed d_k once more (logits discarded) to complete the span; for
        # rejected slots the write is junk above the final length, exactly
        # like the target's own rejected rows.
        _, dpool = self._draft_step(dparams, dpool, table,
                                    lens + self.ecfg.spec_k, active, cur)
        return jnp.stack(toks, axis=1), jnp.stack(probs, axis=1), dpool

    def _draft_prefill(self, slot: int, st) -> None:
        """Whole-prompt prefill of the draft model for one slot. The draft
        always recomputes the full prompt (no chunking, no prefix sharing —
        it is a fraction of the target's cost by construction); bucket
        padding bounds its compiled shapes like the target's prefill."""
        toks = st.req.prompt
        padded = toks + [0] * (bucket_len(len(toks),
                                          self.ecfg.prefill_bucket)
                               - len(toks))
        tok_arr = jnp.asarray(padded, jnp.int32)[None]
        cache = self._draft_prefill_fns.get(len(padded))(
            self._draft_params, tok_arr, jnp.int32(len(toks)))
        self._draft_pool = self._write_prefill_jit(
            self._draft_pool,
            {k: cache[k] for k in self._draft_attn_keys},
            self._draft_table[slot], jnp.int32(slot),
            jnp.int32(len(toks)), pcfg=self._draft_pcfg)

    def _spec_step(self, active_slots: list[int]) -> None:
        """One speculative iteration over the current batch: k draft
        proposals per slot, ONE q-block verify call on the target,
        rejection sampling per slot (accepted prefix + bonus/replacement
        token), then page-level rollback (``trim_unused``). Every emitted
        token is a valid target sample, so greedy slots emit exactly the
        non-speculative greedy sequence (one-hot distributions make each
        accept/replace decision deterministic)."""
        sched = self.sched
        k = self.ecfg.spec_k
        table = jnp.asarray(sched.page_table)
        lens = jnp.asarray(sched.lens_vector())
        active = jnp.asarray(sched.active_mask())
        tokens = jnp.asarray(sched.tokens_vector())
        sp = [sched.slots[s].req.sampling if sched.slots[s]
              else SamplingParams() for s in range(self.pcfg.num_slots)]
        temp = jnp.asarray([p.temperature for p in sp], jnp.float32)
        topk = jnp.asarray([p.top_k for p in sp], jnp.int32)
        topp = jnp.asarray([p.top_p for p in sp], jnp.float32)
        dkey = jax.random.fold_in(self._key, self._nsample)
        self._nsample += 1
        akey = jax.random.fold_in(self._key, self._nsample)
        self._nsample += 1
        t0 = self.trace.clock() if self.trace is not None else 0.0
        dtoks, dprobs, self._draft_pool = self._draft_propose_jit(
            self._draft_params, self._draft_pool, self._draft_table, lens,
            active, tokens, dkey, temp, topk, topp)
        blk = jnp.concatenate([tokens, dtoks], axis=1)       # (B, k+1)
        vlogits, self.pool = self._verify_jit(self.params, self.pool,
                                              table, lens, active, blk)
        acc_len, next_tok = self._accept_jit(vlogits, dprobs, dtoks, akey,
                                             temp, topk, topp)
        acc = np.asarray(acc_len)
        nxt = np.asarray(next_tok)
        dt = np.asarray(dtoks)
        dur = (self.trace.clock() - t0) if self.trace is not None else None
        accepted = emitted = 0
        for slot in active_slots:
            st = sched.slots[slot]
            a = int(acc[slot])
            accepted += a
            # eos / max_new truncate the emission mid-prefix: tokens past
            # the stop never leave the engine (their K/V junk sits above
            # the slot's final length and the slot retires anyway)
            for tok in [int(t) for t in dt[slot, :a]] + [int(nxt[slot])]:
                st.generated.append(tok)
                st.last_token = tok
                emitted += 1
                if st.done():
                    break
            sched.trim_unused(slot)
            if st.done():
                self._finish(slot)
        free_pages = sched.alloc.free_pages if sched.paged else None
        self.metrics.decode_step(emitted, free_pages=free_pages, dur=dur)
        self.metrics.spec_step(len(active_slots), k * len(active_slots),
                               accepted, emitted)
        self._ledger_update("decode")
        if self.trace is not None:
            self.trace.emit("spec_step", step=self.metrics.decode_steps,
                            n_active=len(active_slots),
                            proposed=k * len(active_slots),
                            accepted=accepted, emitted=emitted,
                            free_pages=free_pages, dur=dur)

    # ---- memory ledger -------------------------------------------------
    def _ledger_update(self, phase: str | None = None) -> None:
        """Refresh every serve-side ledger site (host ints only — never
        called from a jitted body).  Counted sites are the real resident
        allocations; the prefix pages are an *uncounted* overlay of
        ``kv_pool`` (their bytes live inside the pool) whose logical-vs-
        physical split turns page sharing into verified bytes."""
        led = self.ledger
        if phase is not None:
            led.set_phase(phase)
        led.set("params", self._params_nbytes,
                fp32=self._params_nbytes_fp32)
        led.set("kv_pool", self.metrics.cache_bytes,
                fp32=self.metrics.cache_bytes_fp32)
        led.set("state_pool", self.metrics.state_bytes,
                fp32=self.metrics.state_bytes_fp32)
        if self._spec:
            led.set("draft_params", self._draft_params_nbytes,
                    fp32=self._draft_params_nbytes_fp32)
            led.set("draft_kv_pool", self._draft_pool_bytes,
                    fp32=self._draft_pool_bytes_fp32)
        if self.sched.paged:
            logical, physical = self.sched.mapped_page_stats()
            pb = self._page_nbytes
            led.set("prefix_pages_logical", logical * pb, counted=False,
                    pages=logical)
            led.set("prefix_pages_physical", physical * pb, counted=False,
                    pages=physical)
            led.set("prefix_bytes_saved", (logical - physical) * pb,
                    counted=False)
        if self._prefix is not None:
            stats = self._prefix.bytes_stats(self._page_nbytes)
            led.set("prefix_tree", stats["bytes"], counted=False,
                    pages=stats["pages"], pages_pinned=stats["pages_pinned"],
                    nodes=stats["nodes"])
        cc = self._prefill_fns.site()
        ch = self._chunk_fns.site()
        led.set("compile_cache", 0, counted=False,
                entries=cc["entries"] + ch["entries"],
                max_live=cc["max_live"],
                evictions=cc["evictions"] + ch["evictions"])

    # ---- request lifecycle --------------------------------------------
    def submit(self, prompt: list[int], max_new_tokens: int = 32,
               sampling: SamplingParams | None = None,
               eos_id: int = -1) -> int:
        req = Request(prompt=list(prompt), max_new_tokens=max_new_tokens,
                      sampling=sampling or SamplingParams(), eos_id=eos_id)
        rid = self.sched.submit(req)
        self._orig_prompt[rid] = list(prompt)
        self.metrics.request_submitted(rid)
        if self.trace is not None:
            self.trace.emit("submit", rid=rid, prompt_len=len(prompt),
                            max_new=max_new_tokens)
        return rid

    def _sample(self, logits: jax.Array, slots: list[int]) -> np.ndarray:
        """Sample one token per row of ``logits`` with the slots' params."""
        sp = [self.sched.slots[s].req.sampling if self.sched.slots[s]
              else SamplingParams() for s in slots]
        key = jax.random.fold_in(self._key, self._nsample)
        self._nsample += 1
        toks = self._sample_jit(
            logits, key,
            jnp.asarray([p.temperature for p in sp], jnp.float32),
            jnp.asarray([p.top_k for p in sp], jnp.int32),
            jnp.asarray([p.top_p for p in sp], jnp.float32))
        return np.asarray(toks)

    def _do_prefill(self, slot: int, st) -> None:
        plen = st.prompt_len
        t0 = self.trace.clock() if self.trace is not None else 0.0
        self._ledger_update("prefill")
        table = jnp.asarray(self.sched.page_table)
        stateful = bool(self._state_keys)
        if stateful:
            # reset-on-admit: the slot may hold a retired/preempted
            # request's state. The first prefill chunk overwrites every
            # tensor anyway, so this is hygiene against future partial-
            # write paths (e.g. restore_slot interplay), not correctness
            # today — and the donated jit makes it an in-place scatter,
            # not a pool copy.
            self.spool = self._reset_state_jit(self.spool, jnp.int32(slot))
        # MoE capacity-parity mode: every prefill shape of this request
        # (whole or chunked) derives expert capacity from the full prompt
        cap = plen if self.ecfg.moe_capacity_by_prompt else None
        resume = st.prefix_len
        if resume > 0:
            # prefix-cache hit: positions < resume are already resident on
            # shared pages (plus an optional COW-forked partial page whose
            # int8 codes were copied verbatim). Adopt the donor's scales so
            # those codes decode on their own grid, then compute only the
            # suffix via the chunked path — exactly the numerics a cache-off
            # engine with a chunk boundary at ``resume`` would produce.
            if self.pcfg.quantized and st.prefix_scales is not None:
                snap = {key: {n: jnp.asarray(v) for n, v in kinds.items()}
                        for key, kinds in st.prefix_scales.items()}
                self.pool = self._adopt_jit(self.pool, jnp.int32(slot), snap)
            if st.fork is not None:
                src, dst = st.fork
                self.pool = self._fork_jit(self.pool, jnp.int32(src),
                                           jnp.int32(dst))
                self.metrics.cow_forked()
                if self.trace is not None:
                    self.trace.emit("cow_fork", rid=st.req.rid, slot=slot,
                                    src_page=src, dst_page=dst,
                                    tokens=resume % self.pcfg.page_size)
            self.metrics.prefix_hit(resume, resume // self.pcfg.page_size)
            if self.trace is not None:
                self.trace.emit("cache_hit", rid=st.req.rid, slot=slot,
                                hit_tokens=resume, prompt_len=plen)
            c = self.ecfg.prefill_chunk
            chunks = ([(s, min(s + c, plen)) for s in range(resume, plen, c)]
                      if c > 0 else [(resume, plen)])
        else:
            chunks = self.sched.prefill_chunks(plen)
        last_logits = None
        for ci, (c0, c1) in enumerate(chunks):
            toks = st.req.prompt[c0:c1]
            if self.trace is not None and len(chunks) > 1:
                self.trace.emit("prefill_chunk", rid=st.req.rid, slot=slot,
                                start=c0, len=c1 - c0)
            if ci == 0 and c0 == 0:
                # whole-chunk model forward (exact reference numerics),
                # then scatter the returned cache into the pools. Stateful
                # archs run exact-length (a pad token would contaminate the
                # scan-carried state; see module docstring) — bucket
                # padding applies to attention-only archs, masked out of
                # MoE capacity via lm_forward's token_mask.
                bucket = 0 if stateful else self.ecfg.prefill_bucket
                padded = toks + [0] * (bucket_len(len(toks), bucket)
                                       - len(toks))
                tok_arr = jnp.asarray(padded, jnp.int32)[None]
                last_logits, cache = self._prefill_fns.get(
                    (len(padded), cap))(self.params, tok_arr,
                                        jnp.int32(len(toks)))
                if self._attn_keys:
                    self.pool = self._write_prefill_jit(
                        self.pool, {k: cache[k] for k in self._attn_keys},
                        table[slot], jnp.int32(slot),
                        jnp.int32(len(toks)), pcfg=self.pcfg)
                if stateful:
                    self.spool = self._write_state_jit(
                        self.spool, {k: cache[k] for k in self._state_keys},
                        jnp.int32(slot), scfg=self.scfg)
            else:
                # later chunks — and the whole computed suffix of a prefix
                # hit — go through the chunked step, padded to a stable
                # width (the chunk size, or the bucketed suffix length when
                # chunking is off) so compiled shapes stay bounded
                if self.ecfg.prefill_chunk > 0:
                    width = self.ecfg.prefill_chunk
                else:
                    width = bucket_len(len(toks), self.ecfg.prefill_bucket)
                pad = 0 if stateful else (width - len(toks))
                padded = toks + [0] * pad
                tok_arr = jnp.asarray(padded, jnp.int32)[None]
                last_logits, self.pool, self.spool = self._chunk_fns.get(
                    (len(padded), cap))(
                    self.params, self.pool, self.spool, tok_arr, table,
                    jnp.int32(slot), jnp.int32(c0), jnp.int32(len(toks)))
        self.metrics.prefill(plen, computed=plen - resume)
        if self._spec:
            # the draft tracks the slot from position 0: full-prompt
            # prefill into its private pool (a preempted request re-enters
            # here with its generated prefix folded in, so the draft cache
            # is rebuilt consistently too)
            self._draft_prefill(slot, st)
        tok = int(self._sample(last_logits, [slot])[0])
        st.generated.append(tok)
        st.last_token = tok
        self.metrics.request_first_token(st.req.rid)
        if self._prefix is not None:
            # donate the fully-covered prompt pages to the radix tree so
            # future requests can share them (codes + scales as written)
            scales = (KC.snapshot_scales(self.pool, slot)
                      if self.pcfg.quantized else None)
            self.sched.commit_prefix(slot, scales)
        if self.trace is not None:
            self.trace.emit("prefill", rid=st.req.rid, slot=slot, len=plen,
                            dur=self.trace.clock() - t0)
            self.trace.emit("first_token", rid=st.req.rid, slot=slot)

    def _finish(self, slot: int) -> None:
        st = self.sched.retire(slot)
        rid = st.req.rid
        full = st.req.prompt + st.generated
        orig = self._orig_prompt[rid]
        tokens = full[len(orig):]
        self._completions[rid] = Completion(rid, orig, tokens)
        self.metrics.request_finished(rid, len(tokens))
        if self.trace is not None:
            reason = ("max_new"
                      if len(st.generated) >= st.req.max_new_tokens
                      else "eos")
            self.trace.emit("retire", rid=rid, slot=slot,
                            new_tokens=len(tokens), reason=reason)

    # ---- engine iteration ---------------------------------------------
    def step(self) -> None:
        """One engine iteration: admit + prefill, then one batched decode."""
        sched = self.sched
        while True:
            adm = sched.try_admit()
            if adm is None:
                break
            slot, st = adm
            self.metrics.request_admitted(st.req.rid, st.prompt_len)
            if self.trace is not None:
                self.trace.emit("admit", rid=st.req.rid, slot=slot,
                                pages=len(sched.slot_pages[slot]))
            self._do_prefill(slot, st)
            if st.done():
                self._finish(slot)

        active_slots = [i for i, s in enumerate(sched.slots) if s is not None]
        if not active_slots:
            return
        # lazily map the page(s) each active slot is about to write — one
        # for plain decode, the k+1 verify span for speculative decoding;
        # preempt the youngest slot if the pool is exhausted
        span = self.ecfg.spec_k + 1 if self._spec else 1
        for slot in list(active_slots):
            if sched.slots[slot] is None:
                continue
            while not (sched.ensure_page(slot) if span == 1
                       else sched.ensure_span(slot, span)):
                # capture the victim before retire clears its slot state
                yst = (sched.slots[sched.admission_order[-1]]
                       if len(sched.admission_order) > 1 else None)
                evicted = sched.preempt_youngest()
                if evicted is None:
                    raise RuntimeError(
                        "KV pool exhausted and nothing to preempt — "
                        "increase num_pages/pages_per_slot")
                self.metrics.preempted()
                if self.trace is not None:
                    self.trace.emit("preempt", rid=yst.req.rid, slot=evicted,
                                    gen_len=len(yst.generated))
                if evicted == slot:
                    break
        active_slots = [i for i, s in enumerate(sched.slots) if s is not None]
        if not active_slots:
            return
        if self._spec:
            self._spec_step(active_slots)
            return

        table = jnp.asarray(sched.page_table)
        lens = jnp.asarray(sched.lens_vector())
        active = jnp.asarray(sched.active_mask())
        tokens = jnp.asarray(sched.tokens_vector())
        t0 = self.trace.clock() if self.trace is not None else 0.0
        health = None
        if self._health:
            logits, self.pool, self.spool, health = self._decode_jit(
                self.params, self.pool, self.spool, table, lens, active,
                tokens)
        else:
            logits, self.pool, self.spool = self._decode_jit(
                self.params, self.pool, self.spool, table, lens, active,
                tokens)
        toks = self._sample(logits, list(range(self.pcfg.num_slots)))
        dur = (self.trace.clock() - t0) if self.trace is not None else None
        free_pages = sched.alloc.free_pages if sched.paged else None
        for slot in active_slots:
            st = sched.slots[slot]
            tok = int(toks[slot])
            st.generated.append(tok)
            st.last_token = tok
            if st.done():
                self._finish(slot)
        self.metrics.decode_step(len(active_slots), free_pages=free_pages,
                                 dur=dur)
        self._ledger_update("decode")
        if self.trace is not None:
            self.trace.emit("decode_step", step=self.metrics.decode_steps,
                            n_active=len(active_slots),
                            free_pages=free_pages, dur=dur)
        if health is not None:
            if self._health_kv:
                self.metrics.record_health(
                    "kv_cache", int(health["kv_clipped"]),
                    int(health["kv_total"]))
            if self._health_state:
                self.metrics.record_health(
                    "ssm_state", int(health["state_clipped"]),
                    int(health["state_total"]),
                    float(health["state_drift_sum"]),
                    float(health["state_drift_n"]))

    def run(self) -> dict[int, Completion]:
        """Drive until every submitted request has completed."""
        while self.sched.has_work():
            self.step()
        return dict(self._completions)

    def summary(self) -> dict:
        # fold lazily-owned counters into the metrics before summarizing
        if self._prefix is not None:
            self.metrics.prefix_evictions = self._prefix.evictions
        self.metrics.compile_evictions = (self._prefill_fns.evictions
                                          + self._chunk_fns.evictions)
        if self.trace is not None:
            self.metrics.trace_dropped = self.trace.dropped
        from ..obs import registry
        self.metrics.counter_totals = registry.snapshot()
        self._ledger_update()
        if self.plan.mesh is not None:
            self.ledger.record_devices(self.pool, self.spool, self.params)
        out = self.metrics.summary()
        mem = self.ledger.summary()
        mem["reconcile"] = self.ledger.reconcile()
        out["memory"] = mem
        return out

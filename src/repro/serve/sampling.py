"""jit-safe per-slot token sampling: greedy / temperature / top-k / top-p,
plus the speculative-decoding verify/accept math.

One compiled function serves every slot mix: the sampling knobs are *data*
(per-slot vectors), not static configuration, so requests with different
temperatures/top-k/top-p batch into the same decode step. ``temperature <=
0`` selects greedy argmax for that slot (the deterministic serving mode the
fp32-parity tests rely on).

Knob semantics (vLLM order): top-k truncates to the k largest logits FIRST,
then the nucleus is computed over the renormalized truncated distribution.
``top_p = 0`` degenerates to greedy-within-the-temperature-distribution:
the argmax is always kept. Greedy rows never divide by the temperature
floor, so their processed distribution (an argmax one-hot) is exact — the
speculative accept/residual math reads these probabilities directly, which
is what makes greedy spec-decode token-identical to non-speculative greedy.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SamplingParams(NamedTuple):
    """Per-request sampling knobs (host-side; vectorized by the engine)."""
    temperature: float = 0.0    # <= 0: greedy
    top_k: int = 0              # 0: disabled
    top_p: float = 1.0          # 1.0: disabled


def _masked_row(logits: jax.Array, temp: jax.Array, top_k: jax.Array,
                top_p: jax.Array) -> jax.Array:
    """Temperature-scale one row (V,) of logits and -inf-mask everything
    outside the top-k / nucleus truncation. Greedy rows (temp <= 0) skip
    the temperature divide entirely — ``logits / 1e-6`` would overflow
    large logits to ±inf and poison the probabilities read by the
    speculative accept path."""
    v = logits.shape[-1]
    scaled = jnp.where(temp > 0.0,
                       logits.astype(jnp.float32)
                       / jnp.maximum(temp, 1e-6),
                       logits.astype(jnp.float32))
    desc = jnp.sort(scaled)[::-1]
    # top-k first: keep the k largest sorted positions (k=0 disables)
    keep_k = (top_k <= 0) | (jnp.arange(v) < top_k)
    desc_k = jnp.where(keep_k, desc, -jnp.inf)
    # nucleus over the RENORMALIZED truncated distribution: softmax of the
    # top-k-masked sorted logits, so top-p thresholds on surviving mass
    # only (mass top-k discarded never counts toward p)
    probs = jax.nn.softmax(desc_k)
    cum = jnp.cumsum(probs)
    keep = (cum - probs < top_p) & keep_k
    # the top logit is ALWAYS kept — at top_p = 0 the prefix test is
    # all-False and the cutoff would otherwise mask every logit
    keep = keep.at[0].set(True)
    cutoff = jnp.min(jnp.where(keep, desc, jnp.inf))
    return jnp.where(scaled < cutoff, -jnp.inf, scaled)


def _probs_row(logits: jax.Array, temp: jax.Array, top_k: jax.Array,
               top_p: jax.Array) -> jax.Array:
    """The processed sampling distribution of one row (V,): an argmax
    one-hot for greedy rows, else softmax over the masked logits."""
    masked = _masked_row(logits, temp, top_k, top_p)
    onehot = jax.nn.one_hot(jnp.argmax(logits, axis=-1), logits.shape[-1],
                            dtype=jnp.float32)
    return jnp.where(temp <= 0.0, onehot, jax.nn.softmax(masked))


def _sample_row(logits: jax.Array, key: jax.Array, temp: jax.Array,
                top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Sample one token from one slot's logits (V,)."""
    masked = _masked_row(logits, temp, top_k, top_p)
    sampled = jax.random.categorical(key, masked)
    return jnp.where(temp <= 0.0, jnp.argmax(logits, axis=-1), sampled)


def _fold_keys(key: jax.Array, b: int) -> jax.Array:
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(b))


def sample_tokens(logits: jax.Array, key: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Vectorized sampling. logits: (B, V); per-slot knob vectors (B,).

    Each slot gets an independent stream derived from ``key`` by fold-in, so
    slot outcomes don't depend on which other requests share the batch.
    """
    return jax.vmap(_sample_row)(
        logits, _fold_keys(key, logits.shape[0]),
        temperature.astype(jnp.float32), top_k.astype(jnp.int32),
        top_p.astype(jnp.float32)).astype(jnp.int32)


def processed_probs(logits: jax.Array, temperature: jax.Array,
                    top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Per-slot processed sampling distributions.

    logits: (B, V) or (B, S, V) — knob vectors are (B,) either way (one
    request's knobs govern every position of its verify block). Greedy
    slots yield exact argmax one-hots.
    """
    t = temperature.astype(jnp.float32)
    k = top_k.astype(jnp.int32)
    p = top_p.astype(jnp.float32)
    if logits.ndim == 3:
        return jax.vmap(
            lambda row, ti, ki, pi: jax.vmap(
                lambda r: _probs_row(r, ti, ki, pi))(row))(logits, t, k, p)
    return jax.vmap(_probs_row)(logits, t, k, p)


def sample_from_probs(probs: jax.Array, key: jax.Array) -> jax.Array:
    """Sample one token per slot from processed distributions (B, V); used
    by the draft side of speculative decoding so the proposal really is
    drawn from the same Q the accept test reads. One-hot rows (greedy)
    sample their argmax deterministically."""
    keys = _fold_keys(key, probs.shape[0])
    return jax.vmap(
        lambda p, k: jax.random.categorical(k, jnp.log(p))
    )(probs, keys).astype(jnp.int32)


def _spec_accept_row(tprobs: jax.Array, qprobs: jax.Array,
                     dtok: jax.Array, key: jax.Array):
    """Rejection-sample one slot. tprobs: (k+1, V) target distributions at
    positions 0..k (row k is the bonus position past the last draft token),
    qprobs: (k, V) draft distributions, dtok: (k,) draft tokens.

    Returns (accept_len in [0, k], next_token). The accepted prefix plus
    ``next_token`` is distributed exactly as k+1 sequential target samples
    (Leviathan et al. 2023): position i accepts with prob min(1, p_i/q_i);
    on the first rejection the replacement is drawn from the normalized
    residual max(P - Q, 0); if all k accept, the bonus token is drawn from
    the target's position-k distribution.
    """
    k = dtok.shape[0]
    ukey, skey = jax.random.split(key)
    pos = jnp.arange(k)
    p_tok = tprobs[pos, dtok]
    q_tok = qprobs[pos, dtok]
    u = jax.random.uniform(ukey, (k,))
    # strict <: greedy mismatch has p_tok = 0, so u*q < 0 never accepts;
    # greedy match has p = q = 1 and u < 1 always accepts
    accept = u * q_tok < p_tok
    a = jnp.sum(jnp.cumprod(accept.astype(jnp.int32))).astype(jnp.int32)
    p_a = jnp.take(tprobs, a, axis=0)
    # Q at the rejection position; zero when all k accepted (a == k), which
    # turns the residual into the plain bonus distribution P_k
    q_a = jnp.where(a < k,
                    jnp.take(qprobs, jnp.minimum(a, k - 1), axis=0), 0.0)
    resid = jnp.maximum(p_a - q_a, 0.0)
    # numerical guard: a rejection implies P != Q so the residual has mass,
    # but fall back to P_a if roundoff zeroes it out
    dist = jnp.where(jnp.sum(resid) > 0.0, resid, p_a)
    nxt = jax.random.categorical(skey, jnp.log(dist)).astype(jnp.int32)
    return a, nxt


def spec_accept(target_logits: jax.Array, draft_probs: jax.Array,
                draft_tokens: jax.Array, key: jax.Array,
                temperature: jax.Array, top_k: jax.Array,
                top_p: jax.Array):
    """Batched speculative verify/accept.

    target_logits: (B, k+1, V) — the target model's logits at the incoming
    token plus the k draft tokens; draft_probs: (B, k, V) — the processed
    draft distributions each proposal was sampled from; draft_tokens:
    (B, k). Per-slot knob vectors (B,) are applied to the target logits
    with the same processing as normal decode, so every emitted token is a
    valid sample of the target's per-position distribution.

    Returns (accept_len (B,) int32, next_token (B,) int32): slot b emits
    draft_tokens[b, :accept_len[b]] followed by next_token[b].
    """
    tprobs = processed_probs(target_logits, temperature, top_k, top_p)
    keys = _fold_keys(key, target_logits.shape[0])
    return jax.vmap(_spec_accept_row)(
        tprobs, draft_probs.astype(jnp.float32),
        draft_tokens.astype(jnp.int32), keys)

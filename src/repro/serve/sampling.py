"""jit-safe per-slot token sampling: greedy / temperature / top-k / top-p.

One compiled function serves every slot mix: the sampling knobs are *data*
(per-slot vectors), not static configuration, so requests with different
temperatures/top-k/top-p batch into the same decode step. ``temperature <=
0`` selects greedy argmax for that slot (the deterministic serving mode the
fp32-parity tests rely on).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SamplingParams(NamedTuple):
    """Per-request sampling knobs (host-side; vectorized by the engine)."""
    temperature: float = 0.0    # <= 0: greedy
    top_k: int = 0              # 0: disabled
    top_p: float = 1.0          # 1.0: disabled


def _sample_row(logits: jax.Array, key: jax.Array, temp: jax.Array,
                top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Sample one token from one slot's logits (V,)."""
    v = logits.shape[-1]
    greedy = temp <= 0.0
    scaled = logits.astype(jnp.float32) / jnp.maximum(temp, 1e-6)
    desc = jnp.sort(scaled)[::-1]
    # top-k: drop logits below the k-th largest (k=0 disables)
    kth = desc[jnp.clip(top_k - 1, 0, v - 1)]
    masked = jnp.where((top_k > 0) & (scaled < kth), -jnp.inf, scaled)
    # top-p (nucleus): keep the smallest prefix of the sorted distribution
    # whose mass reaches p; implemented as a logit threshold so the mask
    # applies in unsorted order. The top logit is always kept.
    probs = jax.nn.softmax(desc)
    cum = jnp.cumsum(probs)
    keep = cum - probs < top_p
    cutoff = jnp.min(jnp.where(keep, desc, jnp.inf))
    masked = jnp.where(masked < cutoff, -jnp.inf, masked)
    sampled = jax.random.categorical(key, masked)
    return jnp.where(greedy, jnp.argmax(logits, axis=-1), sampled)


def sample_tokens(logits: jax.Array, key: jax.Array, temperature: jax.Array,
                  top_k: jax.Array, top_p: jax.Array) -> jax.Array:
    """Vectorized sampling. logits: (B, V); per-slot knob vectors (B,).

    Each slot gets an independent stream derived from ``key`` by fold-in, so
    slot outcomes don't depend on which other requests share the batch.
    """
    b = logits.shape[0]
    keys = jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(b))
    return jax.vmap(_sample_row)(
        logits, keys, temperature.astype(jnp.float32),
        top_k.astype(jnp.int32), top_p.astype(jnp.float32)).astype(jnp.int32)

"""Prefill-shape policy for ragged open-loop traffic: length bucketing +
a bounded LRU of live jitted prefill shapes.

Open-loop traffic brings arbitrary prompt lengths.  Two mechanisms keep
compilation bounded:

- **Bucketing** (``bucket_len``): prompts pad up to a multiple of
  ``prefill_bucket`` so nearby lengths share one compiled shape (pad
  positions are trash-paged and masked out of MoE routing — the engine's
  existing contract).
- **Compile-cache eviction** (``CompileCache``): each distinct prefill
  shape still costs a live compiled executable.  The engine keys one
  ``jax.jit`` wrapper per shape signature; when ``max_live`` is exceeded
  the least-recently-used wrapper is dropped, releasing its executable to
  the garbage collector.  A re-arriving shape recompiles — eviction trades
  bounded memory for occasional recompiles, and the ``evictions`` counter
  (surfaced in ServeMetrics as ``compile_evictions``) shows the churn so
  an operator can size ``max_prefill_shapes``/``prefill_bucket`` sanely.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable


def bucket_len(n: int, bucket: int) -> int:
    """Smallest multiple of ``bucket`` >= n (n itself when bucket <= 0)."""
    if bucket <= 0:
        return n
    return n + (-n) % bucket


class CompileCache:
    """LRU map of shape-signature key -> jitted callable.

    One wrapper per key means one compiled executable per key (the engine
    keys include every static component of the shape: padded length or
    chunk width, plus the MoE capacity override), so evicting a wrapper
    frees exactly that shape's executable.  ``max_live <= 0`` disables
    eviction (the pre-policy unbounded behavior)."""

    def __init__(self, factory: Callable[[tuple], Callable],
                 max_live: int = 0):
        self._factory = factory
        self._max = max_live
        self._live: "OrderedDict[tuple, Callable]" = OrderedDict()
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._live)

    @property
    def keys(self) -> list:
        return list(self._live)

    def site(self) -> dict:
        """Ledger raw material for the ``compile_cache`` site.  XLA exposes
        no portable executable-size API, so the site reports live-entry
        count and eviction churn with bytes=0 — the *bound* (max_live) is
        what keeps this site's real memory finite."""
        return {"entries": len(self._live), "max_live": self._max,
                "evictions": self.evictions}

    def get(self, key: tuple) -> Callable:
        fn = self._live.pop(key, None)
        if fn is None:
            fn = self._factory(key)
            if self._max > 0:
                while len(self._live) >= self._max:
                    self._live.popitem(last=False)
                    self.evictions += 1
        self._live[key] = fn
        return fn

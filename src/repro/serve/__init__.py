"""repro.serve — continuous-batching inference engine over a slot-paged,
pow-2 quantized KV-cache pool plus a slot-indexed quantized recurrent-state
cache for SSM/RWKV mixers (the paper's low-precision numerics applied to
the serving memory bottleneck)."""
from .bucketing import CompileCache, bucket_len  # noqa: F401
from .engine import Completion, Engine, EngineConfig  # noqa: F401
from .kv_cache import PageRefs, PoolConfig, init_pool, pool_bytes  # noqa: F401
from .metrics import ServeMetrics  # noqa: F401
from .prefix import PrefixMatch, RadixPrefixCache  # noqa: F401
from .sampling import (SamplingParams, processed_probs,  # noqa: F401
                       sample_from_probs, sample_tokens, spec_accept)
from .scheduler import Request, Scheduler  # noqa: F401
from .state_cache import StateCacheConfig, init_state_pool  # noqa: F401

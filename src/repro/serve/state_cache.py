"""Slot-indexed recurrent-state cache for SSM / RWKV serving — the peer of
the paged KV pool (``serve/kv_cache.py``) for mixers whose serving memory is
an O(1) per-request *state* instead of an O(T) token cache.

Layout: one device tensor per (sublayer, state tensor) with shape
``(L, num_slots, *feat)`` — ``L`` the period-stack depth consumed by the
engine's layer scan, ``num_slots`` the decode-batch lanes.  A slot's state
is overwritten every decode step (there is no paging: state does not grow
with sequence length), so the pool's resident bytes are fixed at
construction.  On a TP mesh the engine places the pool by
``ShardPlan.state_pool_pspec``: the feature axis carrying d_inner / heads
shards over ``model`` (mamba ``conv``/``h``, rwkv6 ``shift``/``wkv``);
the slot axis and the per-(layer, slot) scales stay replicated.

Quantization (the ``ssm_state`` site of ``NumericsPolicy``): states are
stored as int8 codes on the pow-2 grid with one ``scale_log2`` per (layer,
slot, tensor), dequantized on read immediately before the recurrence step.
Unlike the KV pool — whose scale is chosen once at prefill and reused for
appends — the state scale is **re-chosen at every overwrite** from the
tensor being written (``per_tensor_max``): recurrent state amplitude drifts
with the decay dynamics, and a stale scale would either clip or waste the
grid.  The scale tree rides next to the codes exactly like the KV pool's
(one managed owner, zero-carried in fp mode so the engine's step pytree is
mode-independent).

Lifecycle hooks the engine drives:

- ``reset_slot``       zero a slot's state on admission (a recycled slot
                       must never leak its previous request's state);
- ``write_prefill``    scatter the post-prompt state ``lm_forward`` returns
                       into one slot (whole-prompt prefill);
- ``read_layer`` / ``write_layer``  the per-layer decode primitives used
                       inside the engine's layer scan (active-masked:
                       inactive lanes keep their stored state);
- ``snapshot_slot`` / ``restore_slot``  host-driven park/unpark of one
                       slot's (codes, scales) — preemption itself needs
                       neither (state is rebuilt by re-prefill, so evicting
                       a slot is page-free + slot invalidation), but the
                       pair makes suspend-without-recompute possible and is
                       the isolation test's round-trip primitive;
- ``pool_bytes`` / ``pool_bytes_fp32``  resident-byte telemetry folded into
                       ``ServeMetrics`` (state_bytes next to cache_bytes).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..numerics import QTensor, QuantSpec, get_codec, per_tensor_max_scale_log2
from .kv_cache import codec_backend


def _state_spec(bits: int) -> QuantSpec:
    """The ``ssm_state`` site: pow-2 int8 codes, per-tensor-max scale
    re-derived at every overwrite."""
    return QuantSpec("pow2", bits, 0, "int8", "per_tensor_max")


@dataclass(frozen=True)
class StateCacheConfig:
    """Numerics of the recurrent-state pool (geometry comes from the model:
    every sublayer's state shapes are fixed by its mixer definition)."""
    quantized: bool = False     # int8 pow-2 storage vs natural-dtype storage
    bits: int = 8

    @property
    def spec(self) -> QuantSpec:
        return _state_spec(self.bits)


# ---------------------------------------------------------------------------
# Pool construction
# ---------------------------------------------------------------------------

def state_feature_shapes(sub, cfg) -> dict[str, tuple[tuple[int, ...], str]]:
    """Per-slot trailing feature shape and natural dtype kind ("model" |
    "f32") of each state tensor of one sublayer (the layouts the mixers in
    ``models/ssm.py`` carry). Attention sublayers have no recurrent state."""
    if sub.mixer_kind == "mamba":
        d = sub.mixer
        return {"conv": ((d.d_conv - 1, d.d_inner), "model"),
                "h": ((d.d_inner, d.d_state), "f32")}
    if sub.mixer_kind == "rwkv6":
        d = sub.mixer
        return {"shift": ((1, cfg.d_model), "model"),
                "wkv": ((d.num_heads, d.head_dim, d.head_dim), "f32"),
                "shift_ffn": ((1, cfg.d_model), "model")}
    return {}


def natural_dtype(kind: str, cfg):
    return jnp.float32 if kind == "f32" else jnp.dtype(cfg.dtype)


def init_state_pool(lm, num_slots: int, scfg: StateCacheConfig) -> dict:
    """Allocate the device half of the state pool for every sublayer.

    Returns {"data": {sub_i: {name: (L, num_slots, *feat)}},
             "scale_log2": {sub_i: {name: (L, num_slots) f32}}}.
    Attention sublayers get empty dicts so the pytree keys mirror the KV
    pool's and the engine's layer scan consumes both uniformly."""
    L = lm.n_periods
    data: dict = {}
    scale: dict = {}
    for i, sub in enumerate(lm.period):
        feats = state_feature_shapes(sub, lm.cfg)
        data[f"sub_{i}"] = {
            name: jnp.zeros(
                (L, num_slots) + f,
                jnp.int8 if scfg.quantized else natural_dtype(kind, lm.cfg))
            for name, (f, kind) in feats.items()}
        scale[f"sub_{i}"] = {
            name: jnp.zeros((L, num_slots), jnp.float32) for name in feats}
    return {"data": data, "scale_log2": scale}


def pool_bytes(pool: dict) -> int:
    """Resident bytes of the state pool (storage + scales)."""
    return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(pool))


def pool_bytes_fp32(pool: dict) -> int:
    """What the same state pool would cost stored in fp32 (no scales)."""
    import numpy as np
    return 4 * sum(int(np.prod(a.shape))
                   for a in jax.tree_util.tree_leaves(pool["data"]))


# ---------------------------------------------------------------------------
# Quantize / dequantize — the ``ssm_state`` site
# ---------------------------------------------------------------------------

def _encode(vals: jax.Array, scfg: StateCacheConfig):
    """fp -> (codes, scale_log2) with one scale per leading row (the
    per-(layer-or-slot) axis), re-derived from max|vals| per row."""
    spec = scfg.spec
    step = per_tensor_max_scale_log2(
        vals, spec, reduce_axes=tuple(range(1, vals.ndim)))
    codes = get_codec(spec, codec_backend()).encode(
        vals, spec, step.reshape((-1,) + (1,) * (vals.ndim - 1))).codes
    return codes, step


def _decode(codes: jax.Array, scale_log2: jax.Array, dtype, scfg):
    spec = scfg.spec
    return get_codec(spec, codec_backend()).decode(
        QTensor(codes, scale_log2.reshape((-1,) + (1,) * (codes.ndim - 1)),
                spec), dtype)


# ---------------------------------------------------------------------------
# Per-layer decode primitives (used inside the engine's layer scan)
# ---------------------------------------------------------------------------

def read_layer(data_l: jax.Array, scale_l: jax.Array, dtype,
               scfg: StateCacheConfig) -> jax.Array:
    """One layer's state for every slot, dequantized on read.
    data_l: (num_slots, *feat); scale_l: (num_slots,). Returns ``dtype``."""
    if scfg.quantized:
        return _decode(data_l, scale_l, dtype, scfg)
    return data_l.astype(dtype)


def write_layer(data_l: jax.Array, scale_l: jax.Array, new: jax.Array,
                active: jax.Array, scfg: StateCacheConfig
                ) -> tuple[jax.Array, jax.Array]:
    """Overwrite every active slot's state for one layer; inactive lanes
    keep their stored codes AND scale (a parked snapshot must survive junk
    decode traffic). new: (num_slots, *feat) fp; active: (num_slots,)."""
    amask = active.reshape((-1,) + (1,) * (new.ndim - 1))
    if scfg.quantized:
        codes, step = _encode(new, scfg)
        return (jnp.where(amask, codes, data_l),
                jnp.where(active, step, scale_l))
    return jnp.where(amask, new.astype(data_l.dtype), data_l), scale_l


def write_health(scale_l: jax.Array, new: jax.Array, active: jax.Array,
                 scfg: StateCacheConfig
                 ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """(clipped, total, drift_sum, drift_n) of one state overwrite — the
    ``ssm_state`` quant-health signal (repro.obs).

    The scale is re-chosen per write (``per_tensor_max``), so the signal is
    scale *drift*: |Δlog2| between the stored and fresh per-slot scales over
    active lanes (how fast state amplitude walks the pow-2 grid). Clip
    counts vs the fresh scale are ~0 by construction and reported for
    schema uniformity."""
    from ..obs.counters import pow2_clip_stats, scale_drift_stats
    step = per_tensor_max_scale_log2(
        new, scfg.spec, reduce_axes=tuple(range(1, new.ndim)))
    amask = active.reshape((-1,) + (1,) * (new.ndim - 1))
    clipped, total = pow2_clip_stats(new, step, scfg.bits, valid=amask)
    dsum, dn = scale_drift_stats(scale_l, step, valid=active)
    return clipped, total, dsum, dn


def write_slot(data_l: jax.Array, scale_l: jax.Array, new: jax.Array,
               slot: jax.Array, scfg: StateCacheConfig
               ) -> tuple[jax.Array, jax.Array]:
    """Overwrite ONE slot's state for one layer (the chunked-prefill write:
    end-of-chunk state carried to the next chunk). new: (*feat) fp."""
    if scfg.quantized:
        codes, step = _encode(new[None], scfg)
        return data_l.at[slot].set(codes[0]), scale_l.at[slot].set(step[0])
    return data_l.at[slot].set(new.astype(data_l.dtype)), scale_l


# ---------------------------------------------------------------------------
# Slot lifecycle (whole-pool, jit-safe)
# ---------------------------------------------------------------------------

def reset_slot(pool: dict, slot: jax.Array) -> dict:
    """Zero one slot's state across all layers/tensors (admission hygiene:
    a recycled slot never sees its previous occupant's state)."""
    return {
        "data": jax.tree.map(lambda a: a.at[:, slot].set(
            jnp.zeros((), a.dtype)), pool["data"]),
        "scale_log2": jax.tree.map(lambda a: a.at[:, slot].set(0.0),
                                   pool["scale_log2"]),
    }


def write_prefill(pool: dict, state: dict, slot: jax.Array,
                  scfg: StateCacheConfig) -> dict:
    """Scatter a whole-prompt prefill state (from ``lm_forward``) into one
    slot, all layers at once. state leaves: (L, 1, *feat) — the stacked
    per-layer states the model returns for batch 1."""
    data, scale = dict(pool["data"]), dict(pool["scale_log2"])
    for key, kinds in state.items():
        new_d = dict(data[key])
        new_s = dict(scale[key])
        for name, arr in kinds.items():
            vals = arr[:, 0]                             # (L, *feat)
            if scfg.quantized:
                codes, step = _encode(vals, scfg)        # scale per layer
                new_d[name] = new_d[name].at[:, slot].set(codes)
                new_s[name] = new_s[name].at[:, slot].set(step)
            else:
                new_d[name] = new_d[name].at[:, slot].set(
                    vals.astype(new_d[name].dtype))
        data[key] = new_d
        scale[key] = new_s
    return {"data": data, "scale_log2": scale}


def snapshot_slot(pool: dict, slot: int, trace=None) -> dict:
    """One slot's (codes, scales) across all layers — the park half of
    suspend-without-recompute. Returns the same tree structure with the
    slot axis indexed out. ``trace``: optional obs.TraceRecorder — emits a
    ``state_snapshot`` event with the parked byte count."""
    snap = jax.tree.map(lambda a: a[:, slot], pool)
    if trace is not None:
        trace.emit("state_snapshot", slot=int(slot),
                   nbytes=sum(l.nbytes
                              for l in jax.tree_util.tree_leaves(snap)))
    return snap


def restore_slot(pool: dict, snap: dict, slot: jax.Array, trace=None) -> dict:
    """Write a ``snapshot_slot`` capture back into ``slot`` (unpark)."""
    if trace is not None:
        trace.emit("state_restore", slot=int(slot),
                   nbytes=sum(l.nbytes
                              for l in jax.tree_util.tree_leaves(snap)))
    return jax.tree.map(lambda a, s: a.at[:, slot].set(s), pool, snap)

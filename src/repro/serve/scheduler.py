"""Host-side continuous-batching scheduler: admission queue, slot + page
allocation, chunked-prefill planning, preemption.

The device sees a fixed-shape world (``num_slots`` lanes, a page table, a
length vector); this module owns the mutable bookkeeping that feeds it:

- **Admission**: FIFO queue; a request is admitted when a slot is free and
  the pool can page its prompt (+1 decode page). Retired slots are refilled
  on the next engine iteration — decode never drains the whole batch to
  let one request in.
- **Paging**: pages are allocated lazily as a slot's length crosses page
  boundaries, so pool memory tracks live tokens. If the pool is exhausted
  mid-decode the *youngest* slot is preempted: its pages return to the free
  list and the request re-queues with its generated prefix folded into the
  prompt (it re-prefills later — standard recompute-style preemption).
- **Chunked prefill**: prompts longer than ``prefill_chunk`` are split into
  fixed-size chunks so admission work is bounded per engine iteration and
  compiled prefill shapes stay reusable.

Mesh invariance: all bookkeeping here is in *logical* slot/page ids. When
the engine places the pool on a mesh (``ShardPlan.kv_pool_pspec``) only
feature axes (KV heads / d_inner) are sharded — the page axis never is —
so one global page id addresses the same page on every shard and this
scheduler (and the radix prefix cache above it) runs unchanged whether the
pool lives on one device or eight.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field

import numpy as np

from .kv_cache import PoolConfig
from .sampling import SamplingParams

_rid_counter = itertools.count()


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 32
    sampling: SamplingParams = field(default_factory=SamplingParams)
    eos_id: int = -1                    # -1: never stop on a token
    rid: int = field(default_factory=lambda: next(_rid_counter))


@dataclass
class SlotState:
    req: Request
    prompt_len: int
    generated: list[int] = field(default_factory=list)
    last_token: int = -1
    # prefix-cache admission outcome (serve/prefix.py): positions below
    # ``prefix_len`` are already resident (shared pages + an optional COW
    # fork) and prefill resumes there.  ``fork`` is the pending (src, dst)
    # page copy the engine must perform before the first suffix chunk;
    # ``prefix_scales`` the matched node's scale snapshot to adopt.
    prefix_len: int = 0
    fork: tuple[int, int] | None = None
    prefix_scales: dict | None = None

    @property
    def cur_len(self) -> int:
        return self.prompt_len + len(self.generated)

    @property
    def next_pos(self) -> int:
        """Cache position of the *incoming* decode token (= the last sampled
        token, which has not been written to the cache yet)."""
        return self.prompt_len + len(self.generated) - 1

    def done(self) -> bool:
        if len(self.generated) >= self.req.max_new_tokens:
            return True
        return bool(self.generated) and self.generated[-1] == self.req.eos_id


class PageAllocator:
    """Free-list allocator over the pool's physical pages."""

    def __init__(self, num_pages: int):
        self._free = list(range(num_pages - 1, -1, -1))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list[int] | None:
        if n > len(self._free):
            return None
        return [self._free.pop() for _ in range(n)]

    def free(self, pages: list[int]) -> None:
        self._free.extend(pages)


class Scheduler:
    """Slot/page bookkeeping for one engine. All state is host-side.

    ``paged=False`` (pure-SSM archs: every mixer carries O(1) recurrent
    state, nothing token-paged lives in the pool): admission needs only a
    free slot — no page reservation, no slot-capacity bound on
    prompt+max_new_tokens — and ``ensure_page`` is trivially satisfied.
    Preemption still works (a preempted request re-queues with its
    generated prefix folded into the prompt; its state is rebuilt by
    re-prefill on re-admission)."""

    def __init__(self, pcfg: PoolConfig, prefill_chunk: int = 0,
                 paged: bool = True, trace=None, prefix=None):
        self.pcfg = pcfg
        self.prefill_chunk = prefill_chunk
        self.paged = paged
        self.trace = trace      # optional obs.TraceRecorder (page events)
        self.prefix = prefix    # optional serve.prefix.RadixPrefixCache
        if prefix is not None and not paged:
            raise ValueError("prefix cache requires the paged pool")
        self.queue: deque[Request] = deque()
        self.slots: list[SlotState | None] = [None] * pcfg.num_slots
        self.alloc = PageAllocator(pcfg.total_pages)
        # slot_pages: pages PRIVATE to the slot (freed at retire).
        # slot_shared: tree-owned pages mapped in the slot's row (stay in the
        # prefix cache at retire).  slot_refs: pages this slot holds refcounts
        # on (shared pages + a pending COW-fork source) — released at retire.
        self.slot_pages: list[list[int]] = [[] for _ in range(pcfg.num_slots)]
        self.slot_shared: list[list[int]] = [[] for _ in
                                             range(pcfg.num_slots)]
        self.slot_refs: list[list[int]] = [[] for _ in range(pcfg.num_slots)]
        # device-facing page table; unmapped entries point at the trash page
        self.page_table = np.full((pcfg.num_slots, pcfg.pages_per_slot),
                                  pcfg.trash_page, np.int32)
        self.admission_order: list[int] = []   # slot ids, oldest first

    # ---- admission ----------------------------------------------------
    def submit(self, req: Request) -> int:
        if req.max_new_tokens < 1:
            raise ValueError(f"request {req.rid}: max_new_tokens must be "
                             f">= 1 (the first token comes from prefill)")
        if not self.paged:
            # recurrent state is O(1): no page capacity to bound against
            self.queue.append(req)
            return req.rid
        if len(req.prompt) + req.max_new_tokens > self.pcfg.max_len:
            raise ValueError(
                f"request {req.rid}: prompt+max_new_tokens "
                f"{len(req.prompt)}+{req.max_new_tokens} exceeds slot "
                f"capacity {self.pcfg.max_len}")
        # the full horizon must be pageable or the request can never finish
        # (preemption frees other slots' pages, not physical capacity)
        need = self.pcfg.pages_for(len(req.prompt) + req.max_new_tokens)
        if need > self.pcfg.total_pages:
            raise ValueError(
                f"request {req.rid}: horizon needs {need} pages but the "
                f"pool has {self.pcfg.total_pages}")
        self.queue.append(req)
        return req.rid

    def alloc_pages(self, n: int) -> list[int] | None:
        """Allocate ``n`` pages, evicting cold prefix-cache leaves first if
        the free list alone cannot cover it.  Eviction only ever reclaims
        refcount-0 spans, so pages mapped (or matched-and-acquired) by any
        live slot are untouchable — running requests are reclaimed by
        *preemption*, never by cache eviction."""
        got = self.alloc.alloc(n)
        if got is None and self.prefix is not None:
            freed = self.prefix.evict(n - self.alloc.free_pages)
            if freed:
                self.alloc.free(freed)
                got = self.alloc.alloc(n)
        return got

    def try_admit(self) -> tuple[int, SlotState] | None:
        """Admit the head-of-queue request if a slot + pages are available.

        With a prefix cache, the longest cached prefix is matched first and
        its pages acquired (refcounted) *before* the private-page
        allocation, so eviction triggered by that very allocation can never
        free the matched span.  Only the non-cached remainder of the prompt
        needs fresh pages; prefill will resume at ``st.prefix_len``."""
        if not self.queue:
            return None
        free_slots = [i for i, s in enumerate(self.slots) if s is None]
        if not free_slots:
            return None
        req = self.queue[0]
        # reserve the prompt's pages plus one decode page up front
        pages: list[int] = []
        shared: list[int] = []
        refs: list[int] = []
        m = None
        if self.paged:
            if self.prefix is not None:
                m = self.prefix.match(req.prompt)
            if m is not None:
                self.prefix.acquire(m)
                shared = list(m.shared_pages)
                refs = shared + ([m.fork_src] if m.fork_src is not None
                                 else [])
            need = self.pcfg.pages_for(len(req.prompt) + 1) - len(shared)
            got = self.alloc_pages(need)
            if got is None:
                if refs:
                    self.prefix.release(refs)
                return None
            pages = got
        self.queue.popleft()
        slot = free_slots[0]
        self.slot_pages[slot] = pages
        self.slot_shared[slot] = shared
        self.slot_refs[slot] = refs
        row = shared + pages
        if row:
            self.page_table[slot, :len(row)] = row
        st = SlotState(req, prompt_len=len(req.prompt))
        if m is not None:
            st.prefix_len = m.resume
            st.prefix_scales = m.scales
            if m.fork_src is not None:
                # the first private page sits right after the shared span —
                # it is the COW destination the engine copies into
                st.fork = (m.fork_src, pages[0])
        self.slots[slot] = st
        self.admission_order.append(slot)
        return slot, st

    def commit_prefix(self, slot: int, scales: dict | None) -> list[int]:
        """After prefill: donate the slot's fully-prompt-covered private
        pages to the prefix tree.  Donated pages move from the private list
        (freed at retire) to the acquired-shared lists (refs released at
        retire), so retirement stays symmetric.  Returns donated pages."""
        if self.prefix is None:
            return []
        st = self.slots[slot]
        ps = self.pcfg.page_size
        n_full = st.prompt_len // ps
        if n_full <= len(self.slot_shared[slot]):
            return []       # nothing beyond the already-shared span
        row = self.slot_shared[slot] + self.slot_pages[slot]
        donated = self.prefix.insert(st.req.prompt, row[:n_full], scales)
        for p in donated:
            self.slot_pages[slot].remove(p)
        if donated:
            self.prefix.refs.acquire(donated)
            self.slot_refs[slot].extend(donated)
            self.slot_shared[slot].extend(donated)
        return donated

    def prefill_chunks(self, prompt_len: int) -> list[tuple[int, int]]:
        """(start, end) chunks covering the prompt."""
        if self.prefill_chunk <= 0 or prompt_len <= self.prefill_chunk:
            return [(0, prompt_len)]
        c = self.prefill_chunk
        return [(s, min(s + c, prompt_len)) for s in range(0, prompt_len, c)]

    # ---- decode-time growth / retirement ------------------------------
    def ensure_page(self, slot: int) -> bool:
        """Make sure the page holding the *next* token position is mapped.
        Returns False when the pool is exhausted (caller should preempt)."""
        if not self.paged:
            return True
        st = self.slots[slot]
        page_idx = st.next_pos // self.pcfg.page_size
        if page_idx < len(self.slot_shared[slot]) + len(self.slot_pages[slot]):
            return True
        pages = self.alloc_pages(1)
        if pages is None:
            return False
        self.slot_pages[slot].append(pages[0])
        self.page_table[slot, page_idx] = pages[0]
        if self.trace is not None:
            self.trace.emit("page_alloc", slot=slot, page=pages[0],
                            pos=int(st.next_pos))
        return True

    def ensure_span(self, slot: int, n: int) -> bool:
        """Map every page covering positions ``next_pos .. next_pos+n-1``
        — the k+1-token speculative write span (``ensure_page`` is the
        n=1 case). Positions at/above the slot horizon are clamped: their
        writes go to the trash page, so they need no mapping. Returns
        False when the pool is exhausted (caller should preempt)."""
        if not self.paged:
            return True
        st = self.slots[slot]
        ps = self.pcfg.page_size
        last = min(st.next_pos + n - 1, self.pcfg.max_len - 1)
        need = last // ps + 1           # mapped-page count required
        while True:
            have = len(self.slot_shared[slot]) + len(self.slot_pages[slot])
            if have >= need:
                return True
            pages = self.alloc_pages(1)
            if pages is None:
                return False
            self.slot_pages[slot].append(pages[0])
            self.page_table[slot, have] = pages[0]
            if self.trace is not None:
                self.trace.emit("page_alloc", slot=slot, page=pages[0],
                                pos=int(have * ps))

    def trim_unused(self, slot: int) -> int:
        """Free trailing private pages above the page holding ``next_pos``
        — the rollback half of speculative decoding: pages mapped for a
        draft span whose tokens were rejected return to the free list
        (their junk K/V sits above the slot's length and is never read).
        Shared prefix pages are never trimmed. Returns the count freed."""
        if not self.paged:
            return 0
        st = self.slots[slot]
        keep = st.next_pos // self.pcfg.page_size + 1
        n_shared = len(self.slot_shared[slot])
        keep_private = max(0, keep - n_shared)
        extra = self.slot_pages[slot][keep_private:]
        if not extra:
            return 0
        self.slot_pages[slot] = self.slot_pages[slot][:keep_private]
        have = n_shared + keep_private
        self.page_table[slot, have:have + len(extra)] = self.pcfg.trash_page
        self.alloc.free(extra)
        if self.trace is not None:
            self.trace.emit("page_free", slot=slot, n=len(extra))
        return len(extra)

    def retire(self, slot: int) -> SlotState:
        st = self.slots[slot]
        if self.trace is not None and self.slot_pages[slot]:
            self.trace.emit("page_free", slot=slot,
                            n=len(self.slot_pages[slot]))
        self.alloc.free(self.slot_pages[slot])
        if self.slot_refs[slot]:
            # shared/acquired pages stay in the prefix tree; dropping the
            # refs merely makes them evictable once no other reader remains
            self.prefix.release(self.slot_refs[slot])
        self.slot_pages[slot] = []
        self.slot_shared[slot] = []
        self.slot_refs[slot] = []
        self.page_table[slot, :] = self.pcfg.trash_page
        self.slots[slot] = None
        self.admission_order.remove(slot)
        return st

    def preempt_youngest(self) -> int | None:
        """Evict the most recently admitted slot; its request re-queues with
        the generated prefix folded into the prompt (recompute on re-admit).
        Returns the evicted slot id, or None if nothing is evictable."""
        if len(self.admission_order) <= 1:
            return None     # never preempt the last running request
        slot = self.admission_order[-1]
        st = self.retire(slot)
        req = st.req
        self.queue.appendleft(Request(
            prompt=req.prompt + st.generated,
            max_new_tokens=req.max_new_tokens - len(st.generated),
            sampling=req.sampling, eos_id=req.eos_id, rid=req.rid))
        return slot

    # ---- device-facing vectors ----------------------------------------
    def lens_vector(self) -> np.ndarray:
        """Per-slot position of the incoming decode token (see next_pos)."""
        return np.asarray([s.next_pos if s else 0 for s in self.slots],
                          np.int32)

    def active_mask(self) -> np.ndarray:
        return np.asarray([s is not None for s in self.slots], bool)

    def tokens_vector(self) -> np.ndarray:
        return np.asarray([[s.last_token if s else 0] for s in self.slots],
                          np.int32)

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.slots)

    # ---- memory-ledger introspection ----------------------------------
    def mapped_page_stats(self) -> tuple[int, int]:
        """(logical, physical) mapped-page counts over live slots.

        Logical counts every slot's mapped pages — a page shared by k
        readers counts k times (what k independent engines would have
        allocated); physical counts distinct page ids.  The difference is
        the pages prefix sharing is saving *right now*: ``obs.ledger``
        multiplies it by ``kv_cache.page_nbytes`` to turn the cumulative
        ``pages_saved`` counter into a verified bytes figure."""
        logical = 0
        phys: set[int] = set()
        for slot, st in enumerate(self.slots):
            if st is None:
                continue
            row = self.slot_shared[slot] + self.slot_pages[slot]
            logical += len(row)
            phys.update(row)
        return logical, len(phys)

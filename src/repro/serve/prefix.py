"""Radix-tree copy-on-write prefix cache over the slot-paged KV pool.

Production traffic is dominated by shared prefixes — system prompts,
few-shot preambles, multi-turn history.  The slot-paged pool is already
page-indirect (a slot's row of the page table is just a list of physical
page ids), so two requests whose prompts agree on the first ``k`` pages can
map the *same* physical pages and skip prefill for those tokens entirely —
the vLLM/SGLang idea, grown over this repo's int8 pool.  The tree is pure
host-side bookkeeping over global page ids, and the pool's page axis is
never mesh-sharded (``ShardPlan.kv_page_spec``) — on a TP mesh a COW fork
(``kv_cache.fork_page``) indexes pages only, so every device forks its own
KV-head slice locally and sharing works unchanged on head-sharded pools
(tests/test_sharded_serve.py::prefix).

Structure
---------
A token-keyed radix tree.  Every edge label is a run of whole pages: node
keys are token tuples whose length is a multiple of ``page_size``, and a
node owns exactly ``len(key)/page_size`` physical pages, written once at
insertion and **never written again** (decode and suffix chunks of readers
land on their own private pages; the scheduler maps shared pages strictly
below each reader's first computed position).  Children are keyed by the
token tuple of their edge's first page for O(1) exact descent, with a
linear longest-common-prefix scan as the fallback that finds mid-page
divergences.

Lifecycle of a request (scheduler/engine side):

- **match**: walk the tree along the prompt, capped at ``len(prompt)-1``
  (at least one token must be computed to produce sampling logits).  Full
  pages on the matched path are *shared*; a divergence (or cap) inside a
  page yields a COW **fork**: the partially-matching physical page is
  copied codes-and-scales-verbatim into a private page of the reader
  (``kv_cache.fork_page``) and prefill resumes at the divergence position.
- **acquire**: refcounts (``kv_cache.PageRefs``) are bumped on every shared
  page *and* the fork source before any allocation can fail, so eviction
  can never free a page a matched request is about to map.
- **release**: retirement and preemption drop the refs; the pages stay in
  the tree (count 0 = evictable, not freed).
- **insert**: after prefill the slot's fully-prompt-covered private pages
  (pages whose every position holds a prompt token: the page receiving the
  first decode write is excluded) are donated to the tree, splitting edges
  at page boundaries where the new path diverges.  The inserting slot keeps
  reading them, so ownership transfer re-tags them as acquired-shared.
- **evict**: when the allocator runs dry the scheduler asks for LRU leaves
  whose pages all have refcount 0; their pages return to the free list.
  This composes with preemption: eviction only reclaims cold cache, while
  preemption reclaims a *running* request's pages (which are either private
  or refcounted, hence invisible to eviction until released).

Stateful archs (mamba/rwkv6 mixers) carry O(1) recurrent state that is not
per-token addressable, so there is nothing page-shaped to share: the engine
simply does not construct a cache for them and every request takes the
ordinary full-prefill miss path (see ``engine.Engine.__init__``).
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .kv_cache import PageRefs


@dataclass
class RadixNode:
    key: tuple[int, ...]                   # edge label, len % page_size == 0
    pages: list[int]                       # len(key) // page_size page ids
    children: dict[tuple[int, ...], "RadixNode"] = field(default_factory=dict)
    parent: "RadixNode | None" = None
    scales: dict | None = None             # kv_cache.snapshot_scales leaves
    last_used: int = 0


@dataclass
class PrefixMatch:
    """Result of matching a prompt against the tree (resume > 0 only)."""
    shared_pages: list[int]                # full shared pages, path order
    fork_src: int | None                   # physical page to COW-copy
    fork_tokens: int                       # valid tokens in the forked page
    resume: int                            # first position prefill computes
    scales: dict | None                    # deepest matched node's snapshot

    @property
    def hit_tokens(self) -> int:
        return self.resume


class RadixPrefixCache:
    """The tree + LRU eviction.  Page refcounts live in ``self.refs``;
    page *ownership* (tree holds the page ⇔ page not on the free list and
    not private to a slot) lives in ``self._owner``."""

    def __init__(self, page_size: int, num_pages: int, trace=None):
        if page_size < 2:
            raise ValueError("prefix cache needs page_size >= 2 "
                             "(a 1-token page can never be fully shared)")
        self.page_size = page_size
        self.refs = PageRefs(num_pages)
        self.trace = trace
        self.root = RadixNode(key=(), pages=[])
        self._owner: dict[int, RadixNode] = {}   # page id -> owning node
        self._clock = 0
        # counters surfaced into ServeMetrics by the engine
        self.evictions = 0          # evicted leaf nodes
        self.pages_evicted = 0

    # ---- introspection ------------------------------------------------
    @property
    def owned_pages(self) -> set[int]:
        return set(self._owner)

    def num_nodes(self) -> int:
        def count(n):
            return 1 + sum(count(c) for c in n.children.values())
        return count(self.root) - 1

    def bytes_stats(self, page_nbytes: int) -> dict:
        """Ledger raw material: how many physical pages the tree owns, how
        many of those are pinned by live readers, and what they cost given
        one page's bytes (``kv_cache.page_nbytes``).  Tree-owned pages live
        inside the KV pool, so the ledger registers this as an *uncounted*
        overlay of the ``kv_pool`` site."""
        owned = list(self._owner)
        pinned = sum(1 for p in owned if self.refs.count(p) > 0)
        return {"pages": len(owned), "pages_pinned": pinned,
                "bytes": len(owned) * int(page_nbytes),
                "nodes": self.num_nodes()}

    # ---- matching -----------------------------------------------------
    def _tick(self, node: RadixNode) -> None:
        self._clock += 1
        node.last_used = self._clock

    def _best_child(self, node: RadixNode, tokens, pos: int
                    ) -> RadixNode | None:
        """Child whose edge shares the longest prefix with tokens[pos:].
        Exact first-page key wins immediately; otherwise scan for any
        partial first-page overlap (the mid-page COW case)."""
        ps = self.page_size
        exact = node.children.get(tuple(tokens[pos:pos + ps]))
        if exact is not None:
            return exact
        best, best_l = None, 0
        for child in node.children.values():
            l = _lcp(child.key, tokens, pos, pos + ps)
            if l > best_l:
                best, best_l = child, l
        return best

    def match(self, prompt: list[int]) -> PrefixMatch | None:
        """Longest cached prefix of ``prompt``, capped at len(prompt)-1.
        Pure lookup — refcounts are untouched until ``acquire``."""
        limit = len(prompt) - 1
        ps = self.page_size
        node, pos = self.root, 0
        shared: list[int] = []
        fork_src, fork_tokens = None, 0
        deepest: RadixNode | None = None
        while pos < limit:
            child = self._best_child(node, prompt, pos)
            if child is None:
                break
            common = _lcp(child.key, prompt, pos, limit)
            self._tick(child)
            if common == len(child.key):
                shared.extend(child.pages)
                deepest = child
                node, pos = child, pos + common
                continue
            full = common // ps
            if full:
                shared.extend(child.pages[:full])
                deepest = child
            rem = common % ps
            if rem:
                fork_src = child.pages[full]
                fork_tokens = rem
                deepest = child
            break
        resume = len(shared) * ps + fork_tokens
        if resume == 0:
            return None
        return PrefixMatch(shared_pages=shared, fork_src=fork_src,
                           fork_tokens=fork_tokens, resume=resume,
                           scales=deepest.scales if deepest else None)

    def acquire(self, m: PrefixMatch) -> None:
        """Pin every matched page (shared + fork source) against eviction."""
        self.refs.acquire(m.shared_pages)
        if m.fork_src is not None:
            self.refs.acquire([m.fork_src])

    def release(self, pages: list[int]) -> None:
        self.refs.release(pages)

    # ---- insertion ----------------------------------------------------
    def insert(self, prompt: list[int], row_pages: list[int],
               scales: dict | None) -> list[int]:
        """Donate a freshly prefilled slot's full-prompt pages to the tree.

        ``row_pages`` is the slot's page-table row prefix covering the
        insertable region: only pages every position of which holds a prompt
        token are eligible (``(p+1)*page_size <= prompt_len``) — the page
        that will receive the first decode write must stay private.  Where
        the path already exists the existing pages are kept (the caller's
        row already maps them — they were shared at admission); where it
        diverges, edges split at page boundaries and the slot's private
        pages transfer to tree ownership.  Returns the newly-owned pages
        (the caller re-tags them from private to acquired-shared)."""
        ps = self.page_size
        n_full = len(prompt) // ps
        if n_full == 0:
            return []
        if n_full > len(row_pages):
            raise AssertionError("row shorter than insertable prefix")
        tokens = tuple(prompt[:n_full * ps])
        node, pos, pi = self.root, 0, 0
        donated: list[int] = []
        while pos < len(tokens):
            child = self._best_child(node, tokens, pos)
            common = _lcp(child.key, tokens, pos, len(tokens)) if child else 0
            if common == 0:
                node = self._attach(node, tokens[pos:], row_pages[pi:n_full],
                                    scales, donated)
                break
            self._tick(child)
            if common == len(child.key):
                node, pos, pi = child, pos + common, pi + common // ps
                continue
            full = common // ps
            if full:
                child = self._split(child, full)
                self._tick(child)
                node, pos, pi = child, pos + full * ps, pi + full
            if pos < len(tokens):
                node = self._attach(node, tokens[pos:], row_pages[pi:n_full],
                                    scales, donated)
            break
        else:
            # fully matched an existing path: nothing donated; refresh the
            # terminal node's scales only if it had none (scale snapshots on
            # a path are mutually consistent by construction)
            pass
        if node.scales is None and scales is not None:
            node.scales = scales
        return donated

    def _attach(self, parent: RadixNode, key: tuple[int, ...],
                pages: list[int], scales: dict | None,
                donated: list[int]) -> RadixNode:
        if len(key) != len(pages) * self.page_size:
            raise AssertionError("edge key/pages length mismatch")
        node = RadixNode(key=key, pages=list(pages), parent=parent,
                         scales=scales)
        self._tick(node)
        parent.children[key[:self.page_size]] = node
        for p in pages:
            self._owner[p] = node
        donated.extend(pages)
        return node

    def _split(self, child: RadixNode, full_pages: int) -> RadixNode:
        """Split ``child``'s edge after ``full_pages`` pages; returns the
        new upper node.  LRU stamp and scales are inherited both ways (the
        upper node's pages were written under the same snapshot)."""
        ps = self.page_size
        parent = child.parent
        upper = RadixNode(key=child.key[:full_pages * ps],
                          pages=child.pages[:full_pages], parent=parent,
                          scales=child.scales, last_used=child.last_used)
        del parent.children[child.key[:ps]]
        parent.children[upper.key[:ps]] = upper
        child.key = child.key[full_pages * ps:]
        child.pages = child.pages[full_pages:]
        child.parent = upper
        upper.children[child.key[:ps]] = child
        for p in upper.pages:
            self._owner[p] = upper
        return upper

    # ---- eviction -----------------------------------------------------
    def evict(self, n_pages: int) -> list[int]:
        """Free >= n_pages by removing LRU leaves whose pages are all
        unreferenced.  Returns the freed page ids (possibly fewer than
        requested when the tree is hot).  Chains upward: a parent that
        becomes a cold leaf is immediately eligible."""
        freed: list[int] = []
        while len(freed) < n_pages:
            victim = self._coldest_free_leaf()
            if victim is None:
                break
            parent = victim.parent
            del parent.children[victim.key[:self.page_size]]
            for p in victim.pages:
                del self._owner[p]
            freed.extend(victim.pages)
            self.evictions += 1
            self.pages_evicted += len(victim.pages)
            if self.trace is not None:
                self.trace.emit("prefix_evict", pages=len(victim.pages),
                                tokens=len(victim.key))
        return freed

    def _coldest_free_leaf(self) -> RadixNode | None:
        best: RadixNode | None = None

        def walk(n: RadixNode):
            nonlocal best
            if n is not self.root and not n.children:
                if self.refs.unreferenced(n.pages):
                    if best is None or n.last_used < best.last_used:
                        best = n
                return
            for c in n.children.values():
                walk(c)

        walk(self.root)
        return best


def _lcp(key: tuple[int, ...], tokens, start: int, stop: int) -> int:
    """Length of the common prefix of ``key`` and ``tokens[start:stop]``."""
    n = min(len(key), stop - start, len(tokens) - start)
    i = 0
    while i < n and key[i] == tokens[start + i]:
        i += 1
    return i

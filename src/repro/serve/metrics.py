"""Serving telemetry: throughput, time-to-first-token, request latency
percentiles, cache-pool byte accounting — and, since repro.obs, a per-step
timeline plus per-site quant-health aggregates.

The engine calls the ``request_*`` hooks as requests move through their
lifecycle and ``decode_step`` once per batched step; ``summary()`` folds
everything into a JSON-friendly dict (the schema the throughput benchmark
emits). The clock is injectable for deterministic tests.

The timeline is the aggregate's raw material: one row per decode step
(batch fill, free pages, step duration), kept in a bounded ring buffer
(like ``TraceRecorder``) so a long-running engine cannot grow host memory
without bound — the aggregates (``batch_fill_mean``, ``free_pages_min``)
are maintained as exact running values, so ``summary()`` is unaffected by
rows the ring dropped (``timeline_dropped`` counts them). TTFT is
attributed into queue wait (submitted→admitted) and compute
(admitted→first token) — the split that tells an operator whether to add
capacity or speed up prefill.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable

import numpy as np


@dataclass
class _ReqTiming:
    submitted: float
    admitted: float | None = None
    first_token: float | None = None
    finished: float | None = None
    prompt_len: int = 0
    gen_len: int = 0


def _pct(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


def _mean(xs) -> float:
    return float(np.mean(np.asarray(xs))) if len(xs) else 0.0


@dataclass
class _SiteHealth:
    clipped: int = 0
    total: int = 0
    drift_sum: float = 0.0
    drift_n: float = 0.0

    def as_dict(self) -> dict:
        return {
            "clipped": self.clipped,
            "total": self.total,
            "clip_fraction": self.clipped / self.total if self.total else 0.0,
            "scale_drift_log2": (self.drift_sum / self.drift_n
                                 if self.drift_n else 0.0),
        }


@dataclass
class ServeMetrics:
    clock: Callable[[], float] = time.monotonic
    _req: dict[int, _ReqTiming] = field(default_factory=dict)
    _t0: float | None = None
    _t_end: float | None = None
    decode_steps: int = 0
    decode_tokens: int = 0      # tokens produced by batched decode steps
    prefill_tokens: int = 0     # prompt tokens actually COMPUTED by prefill
    prompt_tokens: int = 0      # prompt tokens submitted through prefill
                                # (computed + prefix-cache hits); equals
                                # prefill_tokens when no cache is attached
    preemptions: int = 0
    # speculative-decoding counters (engine-maintained; see spec_step)
    spec_steps: int = 0         # batched verify steps run
    spec_slots: int = 0         # slot-steps verified (slots x steps)
    spec_proposed: int = 0      # draft tokens proposed to the target
    spec_accepted: int = 0      # draft tokens that passed rejection
    spec_emitted: int = 0       # tokens emitted by spec steps (post-trunc)
    # prefix-cache counters (serve/prefix.py; engine-maintained)
    prefix_hit_tokens: int = 0  # prompt tokens served from cached pages
    cow_forks: int = 0          # copy-on-write page copies (mid-page hits)
    prefix_evictions: int = 0   # LRU leaf evictions under page pressure
    pages_saved: int = 0        # physical pages NOT allocated thanks to
                                # sharing (sum of shared spans at admission)
    compile_evictions: int = 0  # jitted prefill shapes dropped by the
                                # bounded compile cache (serve/bucketing.py)
    num_slots: int = 0          # pool width (set by the engine; 0: unknown)
    cache_bytes: int = 0        # resident KV pool bytes (set by the engine)
    cache_bytes_fp32: int = 0   # what the same pool would cost unquantized
    state_bytes: int = 0        # resident recurrent-state pool bytes
                                # (SSM/RWKV sublayers; 0 for attn-only archs)
    state_bytes_fp32: int = 0   # fp32 cost of the same state pool
    # one row per decode step: {"t", "step", "n_active", "free_pages", "dur"}
    # — a bounded ring (oldest rows dropped past capacity; aggregates stay
    # exact via the running values below)
    timeline_capacity: int = 65536
    timeline: deque = None  # type: ignore[assignment]
    timeline_dropped: int = 0
    _free_min: int | None = None
    # surfaced by the engine before summary(): trace-ring drops and the
    # process CounterRegistry snapshot (codec fallbacks, kernel calls)
    trace_dropped: int = 0
    counter_totals: dict = field(default_factory=dict)
    _health: dict[str, _SiteHealth] = field(default_factory=dict)

    def __post_init__(self):
        if self.timeline is None:
            self.timeline = deque(maxlen=self.timeline_capacity)

    # ---- lifecycle hooks ----------------------------------------------
    def _timing(self, rid: int) -> _ReqTiming:
        # robust to hooks firing out of order (a caller driving the engine
        # directly may admit/finish a request it never "submitted")
        t = self._req.get(rid)
        if t is None:
            t = self._req[rid] = _ReqTiming(submitted=self.clock())
        return t

    def request_submitted(self, rid: int) -> None:
        self._req[rid] = _ReqTiming(submitted=self.clock())

    def request_admitted(self, rid: int, prompt_len: int) -> None:
        t = self._timing(rid)
        # a re-admitted (preempted) request keeps its original timings
        if t.admitted is None:
            t.admitted = self.clock()
            t.prompt_len = prompt_len
        if self._t0 is None:
            self._t0 = self.clock()

    def request_first_token(self, rid: int) -> None:
        t = self._timing(rid)
        if t.first_token is None:
            t.first_token = self.clock()

    def request_finished(self, rid: int, gen_len: int) -> None:
        t = self._timing(rid)
        t.finished = self.clock()
        t.gen_len = gen_len
        self._t_end = t.finished

    def decode_step(self, n_active: int, free_pages: int | None = None,
                    dur: float | None = None) -> None:
        self.decode_steps += 1
        self.decode_tokens += n_active
        if free_pages is not None:
            self._free_min = free_pages if self._free_min is None \
                else min(self._free_min, free_pages)
        if self.timeline.maxlen is not None \
                and len(self.timeline) == self.timeline.maxlen:
            self.timeline_dropped += 1
        self.timeline.append({
            "t": self.clock(), "step": self.decode_steps,
            "n_active": n_active, "free_pages": free_pages, "dur": dur})

    def prefill(self, n_tokens: int, computed: int | None = None) -> None:
        """One request prefilled: ``n_tokens`` prompt positions, of which
        ``computed`` were actually run through the model (the rest were
        served from the prefix cache; default: all of them)."""
        self.prompt_tokens += n_tokens
        self.prefill_tokens += n_tokens if computed is None else computed

    def prefix_hit(self, hit_tokens: int, pages: int) -> None:
        self.prefix_hit_tokens += hit_tokens
        self.pages_saved += pages

    def cow_forked(self) -> None:
        self.cow_forks += 1

    def preempted(self) -> None:
        self.preemptions += 1

    def spec_step(self, n_slots: int, proposed: int, accepted: int,
                  emitted: int) -> None:
        """One speculative verify step: ``n_slots`` slots verified
        ``proposed`` draft tokens total, of which ``accepted`` passed the
        rejection test; ``emitted`` tokens actually left the engine
        (accepted + the bonus/replacement token per slot, truncated by
        max_new/eos)."""
        self.spec_steps += 1
        self.spec_slots += n_slots
        self.spec_proposed += proposed
        self.spec_accepted += accepted
        self.spec_emitted += emitted

    # ---- quant health ---------------------------------------------------
    def record_health(self, site: str, clipped: int, total: int,
                      drift_sum: float = 0.0, drift_n: float = 0.0) -> None:
        """Accumulate one step's (clipped, total) counts — host ints, the
        engine converts the device aggregates — and optional scale-drift
        (|Δlog2| sum, count) for sites that re-choose scales."""
        h = self._health.setdefault(site, _SiteHealth())
        h.clipped += int(clipped)
        h.total += int(total)
        h.drift_sum += float(drift_sum)
        h.drift_n += float(drift_n)

    # ---- summary -------------------------------------------------------
    def summary(self) -> dict:
        done = [t for t in self._req.values() if t.finished is not None]
        ttft = [t.first_token - t.submitted for t in done
                if t.first_token is not None]
        ttft_queue = [t.admitted - t.submitted for t in done
                      if t.admitted is not None]
        ttft_compute = [t.first_token - t.admitted for t in done
                        if t.first_token is not None and t.admitted is not None]
        lat = [t.finished - t.submitted for t in done]
        # wall clock must include still-running requests — using the last
        # *finished* time while work is in flight inflates tokens_per_s
        running = any(t.admitted is not None and t.finished is None
                      for t in self._req.values())
        t_end = self.clock() if (running or self._t_end is None) \
            else self._t_end
        wall = (t_end - self._t0) if self._t0 is not None else 0.0
        total_gen = sum(t.gen_len for t in done)
        # exact running aggregates — independent of timeline-ring drops:
        # every decode_step added n_active to decode_tokens, so the mean
        # fill is decode_tokens / decode_steps
        fill_mean = (self.decode_tokens / self.decode_steps
                     if self.decode_steps else 0.0)
        return {
            "requests_completed": len(done),
            "generated_tokens": total_gen,
            "prefill_tokens": self.prefill_tokens,
            "prompt_tokens": self.prompt_tokens,
            "decode_steps": self.decode_steps,
            "preemptions": self.preemptions,
            # prefix cache: hit rate over submitted prompt tokens, plus the
            # raw counters (PR 6 span schema: flat keys, JSON scalars)
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_hit_rate": (self.prefix_hit_tokens / self.prompt_tokens
                                if self.prompt_tokens else 0.0),
            "cow_forks": self.cow_forks,
            "prefix_evictions": self.prefix_evictions,
            "pages_saved": self.pages_saved,
            "compile_evictions": self.compile_evictions,
            "wall_s": wall,
            "tokens_per_s": total_gen / wall if wall > 0 else 0.0,
            "ttft_p50_s": _pct(ttft, 50), "ttft_p95_s": _pct(ttft, 95),
            "ttft_p99_s": _pct(ttft, 99),
            "ttft_queue_p50_s": _pct(ttft_queue, 50),
            "ttft_compute_p50_s": _pct(ttft_compute, 50),
            "latency_p50_s": _pct(lat, 50), "latency_p95_s": _pct(lat, 95),
            "batch_fill_mean": fill_mean,
            "batch_fill_frac": (fill_mean / self.num_slots
                                if self.num_slots else 0.0),
            "free_pages_min": int(self._free_min)
                              if self._free_min is not None else 0,
            "timeline_dropped": self.timeline_dropped,
            "trace_dropped": self.trace_dropped,
            "counter_totals": dict(self.counter_totals),
            "cache_bytes": self.cache_bytes,
            "cache_bytes_fp32": self.cache_bytes_fp32,
            "cache_reduction": (self.cache_bytes_fp32 / self.cache_bytes
                                if self.cache_bytes else 0.0),
            "state_bytes": self.state_bytes,
            "state_bytes_fp32": self.state_bytes_fp32,
            "state_reduction": (self.state_bytes_fp32 / self.state_bytes
                                if self.state_bytes else 0.0),
            "quant_health": {s: h.as_dict()
                             for s, h in sorted(self._health.items())},
            # speculative decoding: acceptance rate over proposed draft
            # tokens and mean tokens emitted per verified slot-step (the
            # >1.0 figure is the whole point of drafting)
            "spec": {
                "steps": self.spec_steps,
                "proposed": self.spec_proposed,
                "accepted": self.spec_accepted,
                "emitted": self.spec_emitted,
                "acceptance_rate": (self.spec_accepted / self.spec_proposed
                                    if self.spec_proposed else 0.0),
                "tokens_per_step": (self.spec_emitted / self.spec_slots
                                    if self.spec_slots else 0.0),
            },
        }

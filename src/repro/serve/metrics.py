"""Serving telemetry: throughput, time-to-first-token, request latency
percentiles, and cache-pool byte accounting.

The engine calls the ``request_*`` hooks as requests move through their
lifecycle and ``decode_step`` once per batched step; ``summary()`` folds
everything into a JSON-friendly dict (the schema the throughput benchmark
emits). The clock is injectable for deterministic tests.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable

import numpy as np


@dataclass
class _ReqTiming:
    submitted: float
    admitted: float | None = None
    first_token: float | None = None
    finished: float | None = None
    prompt_len: int = 0
    gen_len: int = 0


def _pct(xs: list[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if xs else 0.0


@dataclass
class ServeMetrics:
    clock: Callable[[], float] = time.monotonic
    _req: dict[int, _ReqTiming] = field(default_factory=dict)
    _t0: float | None = None
    _t_end: float | None = None
    decode_steps: int = 0
    decode_tokens: int = 0      # tokens produced by batched decode steps
    prefill_tokens: int = 0
    preemptions: int = 0
    cache_bytes: int = 0        # resident KV pool bytes (set by the engine)
    cache_bytes_fp32: int = 0   # what the same pool would cost unquantized
    state_bytes: int = 0        # resident recurrent-state pool bytes
                                # (SSM/RWKV sublayers; 0 for attn-only archs)
    state_bytes_fp32: int = 0   # fp32 cost of the same state pool

    # ---- lifecycle hooks ----------------------------------------------
    def request_submitted(self, rid: int) -> None:
        self._req[rid] = _ReqTiming(submitted=self.clock())

    def request_admitted(self, rid: int, prompt_len: int) -> None:
        t = self._req[rid]
        # a re-admitted (preempted) request keeps its original timings
        if t.admitted is None:
            t.admitted = self.clock()
            t.prompt_len = prompt_len
        if self._t0 is None:
            self._t0 = self.clock()

    def request_first_token(self, rid: int) -> None:
        t = self._req[rid]
        if t.first_token is None:
            t.first_token = self.clock()

    def request_finished(self, rid: int, gen_len: int) -> None:
        t = self._req[rid]
        t.finished = self.clock()
        t.gen_len = gen_len
        self._t_end = t.finished

    def decode_step(self, n_active: int) -> None:
        self.decode_steps += 1
        self.decode_tokens += n_active

    def prefill(self, n_tokens: int) -> None:
        self.prefill_tokens += n_tokens

    def preempted(self) -> None:
        self.preemptions += 1

    # ---- summary -------------------------------------------------------
    def summary(self) -> dict:
        done = [t for t in self._req.values() if t.finished is not None]
        ttft = [t.first_token - t.submitted for t in done
                if t.first_token is not None]
        lat = [t.finished - t.submitted for t in done]
        wall = ((self._t_end or self.clock()) - self._t0) \
            if self._t0 is not None else 0.0
        total_gen = sum(t.gen_len for t in done)
        return {
            "requests_completed": len(done),
            "generated_tokens": total_gen,
            "prefill_tokens": self.prefill_tokens,
            "decode_steps": self.decode_steps,
            "preemptions": self.preemptions,
            "wall_s": wall,
            "tokens_per_s": total_gen / wall if wall > 0 else 0.0,
            "ttft_p50_s": _pct(ttft, 50), "ttft_p95_s": _pct(ttft, 95),
            "latency_p50_s": _pct(lat, 50), "latency_p95_s": _pct(lat, 95),
            "cache_bytes": self.cache_bytes,
            "cache_bytes_fp32": self.cache_bytes_fp32,
            "cache_reduction": (self.cache_bytes_fp32 / self.cache_bytes
                                if self.cache_bytes else 0.0),
            "state_bytes": self.state_bytes,
            "state_bytes_fp32": self.state_bytes_fp32,
            "state_reduction": (self.state_bytes_fp32 / self.state_bytes
                                if self.state_bytes else 0.0),
        }

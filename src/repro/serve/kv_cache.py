"""Slot-paged KV-cache pool with pow-2 symmetric fixed-point storage.

The serving cache is a pool of fixed-size *pages* shared by all request
slots.  A slot owns an ordered list of pages (its row of the page table);
token position ``t`` of a slot lives at ``(page_table[slot, t // page_size],
t % page_size)``.  Pages are allocated lazily as a request's length crosses
page boundaries and returned to the free list when the request retires, so
pool memory scales with *live tokens*, not ``num_slots * max_len``.

Quantization (the paper's §3.2 numerics applied to serving): K/V entries are
stored as ``int8`` codes on a power-of-2 grid, ``x ≈ q * 2^scale_log2`` with
``q ∈ [-2^{b-1}, 2^{b-1}-1]``, one ``scale_log2`` per (layer, slot, tensor)
chosen from the prompt's K/V range at prefill and reused for decode appends
(decode K/V share the prompt's amplitude).  Dequantization happens on read,
immediately before the attention einsums — the resident cache is 1 byte per
element instead of 4, the ≥3.5× serving-memory version of the paper's 292×
training-memory result.

Everything here is jit-safe: writes are batched scatters via ``.at[]``,
reads are page-table gathers.  Inactive slots write to a reserved *trash
page* (index ``num_pages``) so one compiled step serves any live/dead slot
mix.  Host-side page accounting lives in ``serve/scheduler.py``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..numerics import (QTensor, QuantSpec, get_codec,
                        per_tensor_max_scale_log2, qrange)


def codec_backend() -> str:
    """Codec backend for the pool's encode/decode: the fused Pallas
    multi-scale kernels where they run natively (TPU, or forced kernel
    validation via JAX_PALLAS_INTERPRET=1), the jnp reference elsewhere —
    the two are bit-identical (tests/test_numerics.py), this only picks the
    faster lowering. (Deferred import: the pallas backend only loads when
    it is actually the selected lowering.)"""
    from ..numerics.pallas_backend import native_backend
    return "pallas" if native_backend() else "reference"


def _kv_spec(bits: int) -> QuantSpec:
    """The ``kv_cache`` site: pow-2 int8 codes, per-tensor-max scale chosen
    at prefill. One constructor so PoolConfig, the scale chooser, and the
    encode/decode paths can never diverge."""
    return QuantSpec("pow2", bits, 0, "int8", "per_tensor_max")


@dataclass(frozen=True)
class PoolConfig:
    """Geometry + numerics of the paged pool."""
    num_slots: int              # max concurrent requests (decode batch)
    page_size: int = 16         # tokens per page
    pages_per_slot: int = 8     # max pages one slot may hold
    num_pages: int = 0          # physical pages shared by all slots
                                # (0 => num_slots * pages_per_slot, no sharing)
    quantized: bool = False     # int8 pow-2 storage vs model-dtype storage
    bits: int = 8

    @property
    def spec(self) -> QuantSpec:
        """The ``kv_cache`` site spec this pool stores under."""
        return _kv_spec(self.bits)

    @property
    def max_len(self) -> int:
        return self.page_size * self.pages_per_slot

    @property
    def total_pages(self) -> int:
        return self.num_pages or self.num_slots * self.pages_per_slot

    @property
    def trash_page(self) -> int:
        """Reserved page absorbing writes from inactive/padded positions."""
        return self.total_pages

    def pages_for(self, n_tokens: int) -> int:
        return -(-max(n_tokens, 1) // self.page_size)


# ---------------------------------------------------------------------------
# Pool construction
# ---------------------------------------------------------------------------

def kv_feature_shapes(sub) -> dict[str, tuple[int, ...]]:
    """Per-token trailing feature shape of each cached tensor of a sublayer
    (the same layouts ``models/attention.py`` caches). Recurrent mixers
    (mamba/rwkv6) cache no per-token tensors — their O(1) state lives in
    the slot-indexed pool of ``serve/state_cache.py`` — so they map to {}."""
    if sub.mixer_kind == "attn_gqa":
        d = sub.mixer
        return {"k": (d.num_kv_heads, d.head_dim),
                "v": (d.num_kv_heads, d.head_dim)}
    if sub.mixer_kind == "attn_mla":
        m = sub.mixer.m
        return {"c_kv": (m.kv_lora_rank,), "k_rope": (m.qk_rope_head_dim,)}
    if sub.mixer_kind in ("mamba", "rwkv6"):
        return {}
    raise ValueError(f"unknown mixer kind {sub.mixer_kind!r}")


def init_pool(lm, pcfg: PoolConfig) -> dict:
    """Allocate the device half of the pool for every attention sublayer of
    ``lm`` (recurrent sublayers get empty dicts: their state lives in the
    ``state_cache`` pool, keyed identically for the engine's layer scan).

    Returns {"data": {sub_i: {name: (L, P+1, page, *feat) int8|dtype}},
             "scale_log2": {sub_i: {name: (L, num_slots) f32}}}.
    ``scale_log2`` is carried (zero) in fp mode too so the step function's
    pytree structure is independent of the numerics mode.
    """
    fp_dtype = jnp.dtype(lm.cfg.dtype)
    store = jnp.int8 if pcfg.quantized else fp_dtype
    L = lm.n_periods
    data: dict = {}
    scale: dict = {}
    for i, sub in enumerate(lm.period):
        feats = kv_feature_shapes(sub)
        data[f"sub_{i}"] = {
            name: jnp.zeros((L, pcfg.total_pages + 1, pcfg.page_size) + f,
                            store)
            for name, f in feats.items()}
        scale[f"sub_{i}"] = {
            name: jnp.zeros((L, pcfg.num_slots), jnp.float32)
            for name in feats}
    return {"data": data, "scale_log2": scale}


def pool_bytes(pool: dict) -> int:
    """Resident bytes of the cache pool (storage + scales)."""
    return sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(pool))


def pool_bytes_fp32(pool: dict) -> int:
    """What the same pool's data would cost stored as f32 (scales excluded:
    an fp32 pool carries none) — the denominator of the cache-reduction
    figure and the ledger's ``kv_pool`` fp32 shadow."""
    return 4 * sum(int(np.prod(a.shape))
                   for a in jax.tree_util.tree_leaves(pool["data"]))


def page_nbytes(pool: dict, pcfg: PoolConfig) -> int:
    """Physical bytes of ONE page summed across every cached tensor of
    every layer (data leaves are (L, P+1, page, *feat): each of the P+1
    physical pages owns an equal 1/(P+1) slice).  Per-slot scale rows are
    page-independent and excluded.  This is the unit that turns the page
    table's logical-vs-physical mapped counts into verified bytes
    (``obs.ledger``: ``prefix_bytes_saved``)."""
    n = pcfg.total_pages + 1
    return sum(leaf.nbytes // n
               for leaf in jax.tree_util.tree_leaves(pool["data"]))


# ---------------------------------------------------------------------------
# Quantize / dequantize — the ``kv_cache`` site of the unified quantization
# API (pow-2 codec of repro.numerics; same grid as core/quant.py)
# ---------------------------------------------------------------------------

def choose_scale_log2(x: jax.Array, valid: jax.Array, bits: int) -> jax.Array:
    """Smallest pow-2 step covering max|x| over valid rows
    (``scale_policy="per_tensor_max"``: one scale per layer, from the
    prompt's K/V range at prefill).

    x: (L, S, *feat); valid: (S,) bool. Returns (L,) f32 integer-valued."""
    mask = valid.reshape((1, -1) + (1,) * (x.ndim - 2))
    return per_tensor_max_scale_log2(x, _kv_spec(bits), valid=mask,
                                     reduce_axes=tuple(range(1, x.ndim)))


def quantize(x: jax.Array, scale_log2: jax.Array, bits: int) -> jax.Array:
    """fp -> int8 codes; scale_log2 broadcast against x's leading dims.
    On the native-kernel backend this is the fused multi-scale encode (the
    pool's scatter-on-append quantizes in one Pallas pass)."""
    spec = _kv_spec(bits)
    return get_codec(spec, codec_backend()).encode(x, spec, scale_log2).codes


def dequantize(q: jax.Array, scale_log2: jax.Array, dtype) -> jax.Array:
    # decode is bits-independent (codes * 2^scale); the 8-bit default spec
    # selects the pow2 codec
    spec = _kv_spec(8)
    return get_codec(spec, codec_backend()).decode(
        QTensor(q, scale_log2, spec), dtype)


# ---------------------------------------------------------------------------
# Per-layer jit primitives (used inside the engine's layer scan)
# ---------------------------------------------------------------------------

def gather_slots(data_l: jax.Array, scale_l: jax.Array, table: jax.Array,
                 pcfg: PoolConfig, dtype) -> jax.Array:
    """Materialize every slot's cache view for one layer.

    data_l: (P+1, page, *feat); scale_l: (num_slots,); table: (B, pages_per_
    slot). Returns (B, T=max_len, *feat) in ``dtype`` (dequantized on read).
    """
    g = data_l[table]                                    # (B, pp, page, *f)
    b = table.shape[0]
    g = g.reshape((b, pcfg.max_len) + g.shape[3:])
    if pcfg.quantized:
        return dequantize(g, scale_l.reshape((b,) + (1,) * (g.ndim - 1)),
                          dtype)
    return g.astype(dtype)


def fused_attend(kdata_l: jax.Array, vdata_l: jax.Array, kscale_l: jax.Array,
                 vscale_l: jax.Array, q: jax.Array, table: jax.Array,
                 lens: jax.Array, pcfg: PoolConfig,
                 impl: str = "auto", plan=None) -> jax.Array:
    """GQA decode attention straight off the paged pool — the fused
    alternative to ``gather_slots`` + ``models/attention.py::gqa_attend``.

    The pool's device layout IS the kernel's: ``kdata_l``/``vdata_l`` are
    one layer's (P+1, page, Hkv, Dh) page array (row P = trash page),
    ``table`` the (B, pages_per_slot) page-pointer rows, ``kscale_l``/
    ``vscale_l`` the (B,) per-slot pow-2 scales, ``lens`` the (B,) incoming
    token positions.  The kernel walks each slot's page list, dequantizes
    int8 pages in-kernel, and accumulates online-softmax attention per page
    — the (B, max_len, *feat) fp32 slot view is never materialized.

    q: (B, Hq, Dh) single-token decode, or (B, S, Hq, Dh) — a q-block
    (chunked prefill / k-token speculative verify) whose rows sit at
    positions ``lens .. lens + S - 1`` with a per-row causal mask. Returns
    the same rank in q.dtype.

    ``plan``: a ``ShardPlan`` whose mesh head-shards the pool
    (``plan.kv_page_spec``) makes the walk run shard_map'd per device on
    its local KV heads — see ``kernels/ops.py::paged_attention``.
    """
    from ..kernels.ops import paged_attention
    return paged_attention(q, kdata_l, vdata_l, kscale_l, vscale_l,
                           table, lens, page_size=pcfg.page_size,
                           quantized=pcfg.quantized, impl=impl, plan=plan)


def append_token(data_l: jax.Array, scale_l: jax.Array, new: jax.Array,
                 table: jax.Array, lens: jax.Array, active: jax.Array,
                 pcfg: PoolConfig) -> jax.Array:
    """Scatter one new token per slot at its own length.

    new: (B, 1, *feat) fp; inactive slots are redirected to the trash page.
    Decode appends reuse the slot's prefill scale (clipping into its range).
    """
    b = new.shape[0]
    page_idx = lens // pcfg.page_size
    pages = jnp.take_along_axis(table, page_idx[:, None], axis=1)[:, 0]
    pages = jnp.where(active, pages, pcfg.trash_page)
    offs = lens % pcfg.page_size
    vals = new[:, 0]
    if pcfg.quantized:
        vals = quantize(vals, scale_l.reshape((b,) + (1,) * (vals.ndim - 1)),
                        pcfg.bits)
    else:
        vals = vals.astype(data_l.dtype)
    return data_l.at[pages, offs].set(vals)


def append_tokens(data_l: jax.Array, scale_l: jax.Array, new: jax.Array,
                  table: jax.Array, lens: jax.Array, active: jax.Array,
                  pcfg: PoolConfig) -> jax.Array:
    """Scatter S new tokens per slot at positions lens..lens+S-1 (the
    speculative-verify write: the incoming token plus the k draft tokens
    land in one batched scatter).

    new: (B, S, *feat) fp. Inactive slots and positions at/above
    ``max_len`` (a draft block overhanging the slot horizon) are redirected
    to the trash page. Like decode appends, values clip into the slot's
    prefill scale. Rejected tokens' K/V stay in the pool as junk above the
    slot's advanced length — the kernel's causal length mask never reads
    them, and later writes at those positions overwrite in place, so
    rollback needs no data movement (page bookkeeping only, see
    ``Scheduler.trim_unused``)."""
    b, s = new.shape[:2]
    pos = lens[:, None] + jnp.arange(s)[None, :]             # (B, S)
    page_idx = jnp.clip(pos // pcfg.page_size, 0, pcfg.pages_per_slot - 1)
    pages = jnp.take_along_axis(table, page_idx, axis=1)
    ok = active[:, None] & (pos < pcfg.max_len)
    pages = jnp.where(ok, pages, pcfg.trash_page)
    offs = pos % pcfg.page_size
    if pcfg.quantized:
        vals = quantize(new, scale_l.reshape((b,) + (1,) * (new.ndim - 1)),
                        pcfg.bits)
    else:
        vals = new.astype(data_l.dtype)
    return data_l.at[pages, offs].set(vals)


def append_health(new: jax.Array, scale_l: jax.Array, active: jax.Array,
                  pcfg: PoolConfig) -> tuple[jax.Array, jax.Array]:
    """(clipped, total) of one decode append against the slots' prefill-
    frozen scales — the ``kv_cache`` quant-health signal (repro.obs).

    Decode K/V reuse the prompt's scale (see ``append_token``), so a rising
    clip fraction means decode amplitudes outgrew the prefill range. Same
    shapes as ``append_token``: new (B, 1, *feat), scale_l (B,), active (B,)
    bool. Integer-exact — backends bit-agree."""
    from ..obs.counters import pow2_clip_stats
    vals = new[:, 0]
    valid = active.reshape((-1,) + (1,) * (vals.ndim - 1))
    return pow2_clip_stats(vals, scale_l, pcfg.bits, valid=valid)


def write_chunk(data_l: jax.Array, scale_l: jax.Array, vals: jax.Array,
                table_row: jax.Array, start: jax.Array, valid_len: jax.Array,
                slot: jax.Array, pcfg: PoolConfig
                ) -> tuple[jax.Array, jax.Array]:
    """Write a prefill chunk of one slot into one layer's pool.

    vals: (S, *feat) fp (positions start..start+S-1; only the first
    ``valid_len`` rows are real). The slot's scale must already be set (the
    first prefill chunk always goes through ``write_prefill``, which derives
    it); this chunk clips into that range. Returns (data_l, scale_l)."""
    s = vals.shape[0]
    pos = start + jnp.arange(s)
    valid = jnp.arange(s) < valid_len
    pages = table_row[pos // pcfg.page_size]
    pages = jnp.where(valid, pages, pcfg.trash_page)
    offs = pos % pcfg.page_size
    if pcfg.quantized:
        vals = quantize(vals, scale_l[slot][None], pcfg.bits)
    else:
        vals = vals.astype(data_l.dtype)
    return data_l.at[pages, offs].set(vals), scale_l


class PageRefs:
    """Host-side reference counts over the pool's physical pages.

    A page's count is the number of *readers* currently holding it mapped
    or reserved: every slot that acquired the page as a shared prefix page,
    plus the slot (if any) that reserved it as a COW-fork source.  Tree
    ownership itself (``serve/prefix.py``) is NOT a reference — a cached
    page with no live readers has count 0 and is evictable.  The allocator
    free list and this table are disjoint by construction: pages are handed
    to the refcount world only while allocated."""

    def __init__(self, num_pages: int):
        self._refs = np.zeros(num_pages, np.int32)

    def acquire(self, pages: list[int]) -> None:
        for p in pages:
            self._refs[p] += 1

    def release(self, pages: list[int]) -> None:
        for p in pages:
            self._refs[p] -= 1
            if self._refs[p] < 0:
                raise AssertionError(f"page {p} released below zero")

    def count(self, page: int) -> int:
        return int(self._refs[page])

    def unreferenced(self, pages: list[int]) -> bool:
        return all(self._refs[p] == 0 for p in pages)


def fork_page(pool: dict, src: jax.Array, dst: jax.Array) -> dict:
    """Copy-on-write page copy: duplicate physical page ``src`` into ``dst``
    for every cached tensor of every layer, codes (or fp values) verbatim.

    No dequant/requant round-trip happens — int8 codes are moved bit-exactly,
    so a forked page is indistinguishable from the donor's up to the fork
    point.  The reader's slot scale must be adopted from the donor's
    (``adopt_scales``) for those codes to decode to the donor's values."""
    data = dict(pool["data"])
    for key, kinds in data.items():
        new_d = dict(kinds)
        for name, arr in kinds.items():
            new_d[name] = arr.at[:, dst].set(arr[:, src])
        data[key] = new_d
    return {"data": data, "scale_log2": pool["scale_log2"]}


def snapshot_scales(pool: dict, slot: int) -> dict:
    """Host-side copy of one slot's per-layer scales: {key: {name: (L,) np}}.
    Taken after prefill so the prefix tree can hand the same decode grid to
    every future reader of the inserted pages."""
    return {key: {name: np.asarray(arr[:, slot])
                  for name, arr in kinds.items()}
            for key, kinds in pool["scale_log2"].items()}


def adopt_scales(pool: dict, slot: jax.Array, snap: dict) -> dict:
    """Set one slot's scale rows from a prefix node's snapshot (leaves (L,)).
    Shared int8 pages then decode under the exact grid they were written
    with; the reader's own suffix chunks and decode appends clip into it —
    the same contract chunked prefill already obeys."""
    scale = dict(pool["scale_log2"])
    for key, kinds in snap.items():
        new_s = dict(scale[key])
        for name, vals in kinds.items():
            new_s[name] = new_s[name].at[:, slot].set(vals)
        scale[key] = new_s
    return {"data": pool["data"], "scale_log2": scale}


def write_prefill(pool: dict, cache: dict, table_row: jax.Array,
                  slot: jax.Array, length: jax.Array, pcfg: PoolConfig
                  ) -> dict:
    """Scatter a whole-prompt prefill cache (from ``lm_forward``) into the
    pool for one slot, all layers at once.

    cache leaves: (L, 1, S, *feat) — the stacked per-layer caches the model
    returns. Rows past ``length`` (bucket padding) go to the trash page."""
    data, scale = dict(pool["data"]), dict(pool["scale_log2"])
    sample = next(iter(next(iter(cache.values())).values()))
    s = sample.shape[2]
    pos = jnp.arange(s)
    valid = pos < length
    pages = jnp.where(valid, table_row[pos // pcfg.page_size],
                      pcfg.trash_page)
    offs = pos % pcfg.page_size
    for key, kinds in cache.items():
        new_d = dict(data[key])
        new_s = dict(scale[key])
        for name, arr in kinds.items():
            vals = arr[:, 0]                             # (L, S, *feat)
            if pcfg.quantized:
                step = choose_scale_log2(vals, valid, pcfg.bits)   # (L,)
                new_s[name] = new_s[name].at[:, slot].set(step)
                vals = quantize(vals, step[:, None], pcfg.bits)
            else:
                vals = vals.astype(new_d[name].dtype)
            new_d[name] = new_d[name].at[:, pages, offs].set(vals)
        data[key] = new_d
        scale[key] = new_s
    return {"data": data, "scale_log2": scale}

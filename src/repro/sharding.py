"""Partition rules: DP / FSDP / TP / EP / SP expressed as one ShardPlan.

Two strategies (selectable per arch config; see DESIGN.md §5):

- ``tp``  — Megatron-style tensor parallelism over the ``model`` axis
            (heads / ffn / vocab / experts / d_inner), batch over
            ``(pod, data)``, FSDP of weights over ``data``.
- ``cp``  — context parallelism: activations sharded over ``model`` on the
            *sequence* dim; weights fully sharded (ZeRO-3) over
            ``(data, model)``. Used for archs whose head count does not
            divide the model axis (yi-34b / llava: 56 heads vs 16).

Decode adds SP: the KV cache / recurrent state is sharded over ``data`` on
the sequence dim when batch < data axis (long_500k, batch=1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def compat_shard_map(f, mesh, in_specs, out_specs):
    """Version-compat shard_map: ``jax.shard_map`` (new API, check_vma)
    with fallback to ``jax.experimental.shard_map`` (check_rep). One shim
    for every explicit-collective site (MoE expert dispatch, the int8
    gradient wire)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=False)


def _div(n: int, mesh: Mesh | None, axis) -> bool:
    """True when dim ``n`` can shard over mesh ``axis``: every named axis
    exists on the mesh (a dp-only 1-D mesh has no ``model`` axis — absent
    axes mean "don't shard", not KeyError) and ``n`` divides evenly."""
    if mesh is None or axis is None:
        return False
    axes = axis if isinstance(axis, tuple) else (axis,)
    if any(a not in mesh.shape for a in axes):
        return False
    size = int(np.prod([mesh.shape[a] for a in axes]))
    return n % size == 0 and n >= size


# Param-leaf names that stay replicated even when >= 2-D (stacking adds a
# leading layer axis to 1-D vectors): biases, norm gains, rwkv6 decay/bonus
# and token-shift mixes, mamba conv/A/D, TT wscales. Projection matrices
# ("w" under q/kv/o/gate/up/down/... sites) are deliberately absent — every
# one of them must receive a non-trivial spec (tests/test_sharding.py audits
# the whole zoo for this).
_REPLICATED_LEAVES = frozenset({
    "b", "bias", "scale", "wscale_log2", "ln_x_scale",
    "w0", "u", "mu_x", "mu_ffn", "A_log", "D", "conv_w", "conv_b",
})


@dataclass(frozen=True)
class ShardPlan:
    mesh: Mesh | None = None
    strategy: str = "tp"                  # "tp" | "cp"
    dp_axes: tuple[str, ...] = ("data",)  # ("pod","data") multi-pod
    seq_sharded_cache: bool = False       # long-context decode SP

    # ---- helpers -----------------------------------------------------
    def dp_axis(self) -> str | tuple[str, ...]:
        """Mesh axis name(s) for data-parallel collectives (``lax.psum`` /
        ``all_gather`` inside shard_map — e.g. the int8 gradient wire,
        ``optim.grad_compress.psum_int8``)."""
        return self.dp_axes if len(self.dp_axes) > 1 else self.dp_axes[0]

    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        n = 1
        for ax in self.dp_axes:
            n *= self.mesh.shape[ax]
        return n

    def ns(self, spec: P) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, spec)

    def constrain(self, x: jax.Array, spec: P) -> jax.Array:
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(x, self.ns(spec))

    # ---- activations -------------------------------------------------
    def hidden(self, x: jax.Array) -> jax.Array:
        """(B, S, D) residual stream.

        Both strategies shard the sequence dim over ``model`` between blocks
        (Megatron-LM sequence parallelism): residuals and the remat/scan
        checkpoints shrink 16×, which is what lets train_4k fit HBM. GSPMD
        inserts the all-gather before attention/FFN and the reduce-scatter
        after (same wire volume as the classic TP all-reduce pair)."""
        if _div(x.shape[1], self.mesh, "model"):
            return self.constrain(x, P(self.dp_axes, "model", None))
        return self.constrain(x, P(self.dp_axes, None, None))

    def heads_act(self, x: jax.Array) -> jax.Array:
        """(B, S, H, Dh) attention interior."""
        if self.mesh is None:
            return x
        if self.strategy == "tp" and _div(x.shape[2], self.mesh, "model"):
            return self.constrain(x, P(self.dp_axes, None, "model", None))
        if self.strategy == "cp" and _div(x.shape[1], self.mesh, "model"):
            return self.constrain(x, P(self.dp_axes, "model", None, None))
        return self.constrain(x, P(self.dp_axes, None, None, None))

    def kv_full(self, x: jax.Array) -> jax.Array:
        """KV replicated along seq (cp strategy all-gathers before attention)."""
        if self.mesh is None:
            return x
        if self.strategy == "tp" and _div(x.shape[2], self.mesh, "model"):
            return self.constrain(x, P(self.dp_axes, None, "model", None))
        return self.constrain(x, P(self.dp_axes, None, None, None))

    def ffn_act(self, x: jax.Array) -> jax.Array:
        """(B, S, F)"""
        if self.mesh is None:
            return x
        if self.strategy == "tp" and _div(x.shape[-1], self.mesh, "model"):
            return self.constrain(x, P(self.dp_axes, None, "model"))
        if self.strategy == "cp" and _div(x.shape[1], self.mesh, "model"):
            return self.constrain(x, P(self.dp_axes, "model", None))
        return self.constrain(x, P(self.dp_axes, None, None))

    def logits(self, x: jax.Array) -> jax.Array:
        """(B, S, V)"""
        if self.mesh is None:
            return x
        if _div(x.shape[-1], self.mesh, "model"):
            return self.constrain(x, P(self.dp_axes, None, "model"))
        return self.constrain(x, P(self.dp_axes, None, None))

    def cache_kv(self, x: jax.Array) -> jax.Array:
        """(B, T, H, Dh) or (B, T, L) decode caches."""
        if self.mesh is None:
            return x
        if self.seq_sharded_cache and _div(x.shape[1], self.mesh, "data"):
            rest = (None,) * (x.ndim - 2)
            return self.constrain(x, P(None, "data", *rest))
        if x.ndim >= 3 and self.strategy == "tp" \
                and _div(x.shape[2], self.mesh, "model"):
            rest = (None,) * (x.ndim - 3)
            return self.constrain(x, P(self.dp_axes, None, "model", *rest))
        rest = (None,) * (x.ndim - 1)
        return self.constrain(x, P(self.dp_axes, *rest))

    # ---- serving pools -------------------------------------------------
    def model_size(self) -> int:
        if self.mesh is None or "model" not in self.mesh.shape:
            return 1
        return int(self.mesh.shape["model"])

    def shards_kv_heads(self, hkv: int) -> bool:
        """True when the paged pool's KV-head axis is sharded over ``model``
        — the condition under which the fused page walk runs per-device on
        its local heads (query heads group contiguously per KV head, so a
        head-shard of q attends exactly to its own head-shard of pages)."""
        return self.strategy == "tp" and _div(hkv, self.mesh, "model")

    def kv_page_spec(self, shape: tuple[int, ...]) -> P:
        """One KV-pool data leaf (L, P+1, page, *feat): GQA leaves
        (..., Hkv, Dh) shard the KV-head axis over ``model``; MLA latent
        leaves (..., latent) and non-divisible head counts replicate. The
        page axis is never sharded — COW forks (``kv_cache.fork_page``) and
        trash-page scatters address whole pages and stay shard-local."""
        dims = [None] * len(shape)
        if len(shape) == 5 and self.shards_kv_heads(shape[3]):
            dims[3] = "model"
        return P(*dims)

    def state_spec(self, name: str, shape: tuple[int, ...]) -> P:
        """One state-pool data leaf (L, num_slots, *feat): the feature axis
        carrying d_inner / heads shards over ``model`` — mamba ``conv``
        (..., d_inner) and ``h`` (..., d_inner, d_state); rwkv6 ``shift``
        (..., 1, d_model) and ``wkv`` (..., H, hd, hd)."""
        dims = [None] * len(shape)
        if self.strategy != "tp" or len(shape) < 3:
            return P(*dims)
        ax = 2 if name in ("h", "wkv") else len(shape) - 1
        if _div(shape[ax], self.mesh, "model"):
            dims[ax] = "model"
        return P(*dims)

    def kv_pool_pspec(self, pool) -> Any:
        """PartitionSpec tree for a ``serve/kv_cache.py`` pool: data leaves
        by ``kv_page_spec``; per-(layer, slot) scale rows replicated (every
        head shard decodes its codes under the same pow-2 grid)."""
        return {"data": jax.tree.map(lambda a: self.kv_page_spec(a.shape),
                                     pool["data"]),
                "scale_log2": jax.tree.map(lambda a: P(*([None] * a.ndim)),
                                           pool["scale_log2"])}

    def state_pool_pspec(self, pool) -> Any:
        """PartitionSpec tree for a ``serve/state_cache.py`` pool."""
        def leaf(path, a):
            name = str(getattr(path[-1], "key", path[-1]))
            return self.state_spec(name, a.shape)

        return {"data": jax.tree_util.tree_map_with_path(leaf, pool["data"]),
                "scale_log2": jax.tree.map(lambda a: P(*([None] * a.ndim)),
                                           pool["scale_log2"])}

    def kv_pool_sharding(self, pool) -> Any:
        return jax.tree.map(self.ns, self.kv_pool_pspec(pool),
                            is_leaf=lambda s: isinstance(s, P))

    def state_pool_sharding(self, pool) -> Any:
        return jax.tree.map(self.ns, self.state_pool_pspec(pool),
                            is_leaf=lambda s: isinstance(s, P))

    # ---- parameters ---------------------------------------------------
    def param_spec(self, path: str, shape: tuple[int, ...]) -> P:
        """PartitionSpec for one param leaf, identified by its tree path."""
        if self.mesh is None:
            return P()
        # stacked layer/period/expert leading axes are never sharded except
        # the explicit expert axis handled below.
        n_lead = 0
        parts = path.split("/")
        name = parts[-1]
        is_expert = any(p in ("gate", "up", "down") for p in parts) and \
            "moe" in parts
        is_stacked = "layers" in parts
        if len(shape) < 2:
            return P()
        # TT cores / lambdas / norms / small vectors: replicated. Exact
        # names (not prefixes): a prefix match would silently replicate any
        # future >= 2-D leaf that happens to share a first letter ("up" vs
        # "u", "beta" vs "b", "damp" vs "D"). Only the genuinely numbered
        # TT families (core_N / lambda_N) match by prefix.
        if name in _REPLICATED_LEAVES or name.startswith(("core_", "lambda_")):
            return P()

        dims: list[Any] = [None] * len(shape)
        body = shape
        lead = 0
        if is_stacked:
            lead += 1
        if is_expert:
            # (..., E, in, out): expert axis sharded over model
            if _div(shape[lead], self.mesh, "model"):
                dims[lead] = "model"
            eff = shape[lead + 1:]
            if len(eff) == 2:
                if self.strategy == "tp":
                    if _div(eff[0], self.mesh, "data"):
                        dims[lead + 1] = "data"
                else:
                    if _div(eff[0], self.mesh, "data"):
                        dims[lead + 1] = "data"
            return P(*dims)
        body = shape[lead:]
        if len(body) != 2:
            return P(*dims)
        din, dout = body
        if self.strategy == "cp":
            # ZeRO-3: fully shard the larger dim over (data, model)
            if _div(din, self.mesh, ("data", "model")) and din >= dout:
                dims[lead] = ("data", "model")
            elif _div(dout, self.mesh, ("data", "model")):
                dims[lead + 1] = ("data", "model")
            elif _div(din, self.mesh, "data"):
                dims[lead] = "data"
            return P(*dims)
        # tp: decide which dim is the "parallel" one by site name
        out_parallel = any(k in parts for k in
                           ("q", "kv", "gate", "up", "in_proj", "dt_proj",
                            "head", "r", "k", "v", "g", "ffn_k", "ffn_r",
                            "x_proj", "q_up", "k_up", "v_up", "q_down",
                            "kv_down", "router"))
        in_parallel = any(k in parts for k in
                          ("o", "down", "out_proj", "ffn_v"))
        if "embed" in parts:
            # (V, D): vocab over model, D over data (fsdp)
            if _div(din, self.mesh, "model"):
                dims[lead] = "model"
            if _div(dout, self.mesh, "data"):
                dims[lead + 1] = "data"
            return P(*dims)
        if out_parallel and _div(dout, self.mesh, "model"):
            dims[lead + 1] = "model"
            if _div(din, self.mesh, "data"):
                dims[lead] = "data"
        elif in_parallel and _div(din, self.mesh, "model"):
            dims[lead] = "model"
            if _div(dout, self.mesh, "data"):
                dims[lead + 1] = "data"
        else:
            # fallback FSDP over data on the larger divisible dim
            if _div(din, self.mesh, "data") and din >= dout:
                dims[lead] = "data"
            elif _div(dout, self.mesh, "data"):
                dims[lead + 1] = "data"
        return P(*dims)

    def params_pspec_tree(self, params) -> Any:
        """PartitionSpec tree matching a params pytree. A single
        ``tree_map_with_path`` pass: each leaf's spec is computed in place
        from its own path, so distinct paths can never collide (the previous
        implementation keyed a dict by "/"-joined path strings and rebuilt
        the tree from it — two paths stringifying identically silently
        overwrote each other's spec)."""
        def spec(path, leaf):
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in path)
            return self.param_spec(key, leaf.shape)

        return jax.tree_util.tree_map_with_path(spec, params)

    def params_sharding_tree(self, params) -> Any:
        spec_tree = self.params_pspec_tree(params)
        return jax.tree.map(lambda s: self.ns(s), spec_tree,
                            is_leaf=lambda s: isinstance(s, P))


def make_plan(mesh: Mesh | None, strategy: str = "tp",
              multi_pod: bool = False,
              seq_sharded_cache: bool = False) -> ShardPlan:
    dp = ("pod", "data") if multi_pod else ("data",)
    return ShardPlan(mesh=mesh, strategy=strategy, dp_axes=dp,
                     seq_sharded_cache=seq_sharded_cache)

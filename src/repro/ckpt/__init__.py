from . import checkpoint  # noqa: F401
from .checkpoint import (AsyncCheckpointer, install_preemption_handler,  # noqa: F401
                         latest_step, load, save, step_path)

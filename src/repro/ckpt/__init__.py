from . import checkpoint  # noqa: F401
from .checkpoint import (AsyncCheckpointer, export_tt_deploy,  # noqa: F401
                         install_preemption_handler, latest_step, load,
                         load_tt_deploy, save, step_path)

"""Fault-tolerant checkpointing.

- Format: flattened path->array dict, msgpack + zstd, one file per save.
- Atomic: write to ``.tmp`` then rename; a crash mid-write never corrupts
  the latest checkpoint.
- Async: a writer thread snapshots (device_get) synchronously (cheap) and
  serializes/compresses/writes in the background so the train loop never
  blocks on disk.
- Mesh-agnostic (elastic): arrays are saved unsharded (fully addressable
  host copies); ``load`` reshards onto whatever mesh/sharding the new job
  uses — restart on a different pod count just works.
- SIGTERM hook: ``install_preemption_handler`` flushes an emergency save on
  preemption (the standard cloud-TPU eviction signal).
"""
from __future__ import annotations

import os
import queue
import signal
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                                  # optional: fall back to uncompressed
    import zstandard
except ImportError:                   # pragma: no cover - env dependent
    zstandard = None

_SEP = "§"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"     # zstd frame header (RFC 8878)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in kp)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _encode(arrays: dict[str, np.ndarray], meta: dict) -> bytes:
    payload = {
        "meta": meta,
        "arrays": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": v.tobytes()}
            for k, v in arrays.items()
        },
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    if zstandard is None:
        return raw
    return zstandard.ZstdCompressor(level=3).compress(raw)


def _decode(blob: bytes) -> tuple[dict[str, np.ndarray], dict]:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but the zstandard module is "
                "not installed")
        raw = zstandard.ZstdDecompressor().decompress(blob)
    else:
        raw = blob
    payload = msgpack.unpackb(raw, raw=False, strict_map_key=False)
    arrays = {
        k: np.frombuffer(v["data"], dtype=v["dtype"]).reshape(v["shape"])
        for k, v in payload["arrays"].items()
    }
    return arrays, payload["meta"]


def save(path: str, tree, meta: dict | None = None):
    """Synchronous atomic save of a pytree."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blob = _encode(_flatten(tree), meta or {})
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load(path: str, like=None, sharding_tree=None):
    """Load a checkpoint. With ``like`` (a pytree of the target structure),
    arrays are restored into that structure (and cast to the target dtypes);
    with ``sharding_tree`` they are device_put with the given shardings —
    this is the elastic-restart reshard point."""
    with open(path, "rb") as f:
        arrays, meta = _decode(f.read())
    if like is None:
        return arrays, meta
    flat = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = None
    if sharding_tree is not None:
        shard_flat = jax.tree_util.tree_flatten(sharding_tree,
                                                is_leaf=lambda x: x is None)[0]
    leaves = []
    for i, (kp, leaf) in enumerate(flat[0]):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in kp)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key].astype(leaf.dtype) if hasattr(leaf, "dtype") \
            else arrays[key]
        if shard_flat is not None and shard_flat[i] is not None:
            arr = jax.device_put(arr, shard_flat[i])
        else:
            arr = jnp.asarray(arr)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat[1], leaves), meta


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f.split("_")[1].split(".")[0])
             for f in os.listdir(ckpt_dir)
             if f.startswith("step_") and f.endswith(".ckpt")]
    return max(steps) if steps else None


def step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step}.ckpt")


class AsyncCheckpointer:
    """Snapshot on the caller thread, serialize+write in the background."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        self._last_exc: Exception | None = None

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, arrays, meta = item
            try:
                blob = _encode(arrays, meta)
                path = step_path(self.ckpt_dir, step)
                os.makedirs(self.ckpt_dir, exist_ok=True)
                tmp = path + ".tmp"
                with open(tmp, "wb") as f:
                    f.write(blob)
                os.replace(tmp, path)
                self._gc()
            except Exception as e:            # pragma: no cover
                self._last_exc = e
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(int(f.split("_")[1].split(".")[0])
                       for f in os.listdir(self.ckpt_dir)
                       if f.startswith("step_") and f.endswith(".ckpt"))
        for s in steps[:-self.keep]:
            try:
                os.remove(step_path(self.ckpt_dir, s))
            except OSError:
                pass

    def save(self, step: int, tree, meta: dict | None = None):
        arrays = _flatten(tree)                  # synchronous snapshot
        meta = dict(meta or {})
        meta["step"] = step
        self._q.put((step, arrays, meta))        # async write

    def wait(self):
        self._q.join()
        if self._last_exc:
            raise self._last_exc

    def close(self):
        self._q.put(None)
        self._thread.join(timeout=10)


# ---------------------------------------------------------------------------
# TT-factor deploy export (packed int4)
# ---------------------------------------------------------------------------

def export_tt_deploy(path: str, params, policy=None) -> dict:
    """Export trained TT cores in the packed-int4 deploy format.

    Every ``core_n`` leaf is encoded through the policy's ``tt_factor``
    codec with ``storage_dtype="int4x2"`` (two codes per byte, the
    3U-EdgeAI-style int4 deploy layout) at its fixed per-core
    ``wscale_log2`` step; stacked (vmapped-over-layer) cores carry their
    per-stack scale via the codec's leading-dim broadcast. All other leaves
    (biases, λ, norms, scale exponents) are stored as-is.

    Saved with the standard msgpack(+zstd) container: codes under
    ``<key>§q``, scales under ``<key>§scale``, the spec + logical shape in
    ``meta["tt_deploy"]``. Returns byte accounting:
    ``{"packed_bytes", "fp32_bytes", "reduction_x"}`` over the core leaves.
    """
    import dataclasses as _dc

    from ..numerics import QuantSpec, encode
    from ..numerics.policy import NumericsPolicy

    spec = (policy or NumericsPolicy(enable=True)).spec_for("tt_factor")
    spec = _dc.replace(spec, storage_dtype="int4x2")

    arrays: dict[str, np.ndarray] = {}
    deploy_meta: dict[str, dict] = {}
    packed_bytes = 0
    fp32_bytes = 0

    def visit(tree, prefix: str):
        nonlocal packed_bytes, fp32_bytes
        if not isinstance(tree, dict):
            return
        steps = tree.get("wscale_log2")
        for k, v in tree.items():
            key = f"{prefix}{_SEP}{k}" if prefix else k
            if isinstance(v, dict):
                visit(v, key)
            elif k.startswith("core_") and steps is not None:
                n = int(k.split("_")[1])
                scale = jnp.asarray(steps)[..., n].astype(jnp.float32)
                core = jnp.asarray(v)
                # flatten each (R, J, I, R') core (keeping any stacked
                # leading dims) so the nibble pairing runs over the whole
                # core — a trailing rank of 1 would otherwise store one
                # nibble per byte
                stack = core.shape[:-4]
                qt = encode(core.reshape(stack + (-1,)), spec, scale)
                arrays[key + _SEP + "q"] = np.asarray(qt.codes)
                arrays[key + _SEP + "scale"] = np.asarray(qt.scale)
                deploy_meta[key] = {"spec": spec.to_json_dict(),
                                    "shape": list(core.shape)}
                packed_bytes += qt.nbytes()
                fp32_bytes += int(core.size) * 4
            elif hasattr(v, "shape"):
                arrays[key] = np.asarray(jax.device_get(v))
            else:
                # container leaves (e.g. ActQuant scale sites): flatten to
                # per-leaf arrays; load_tt_deploy returns them dict-shaped
                for kp, leaf in jax.tree_util.tree_flatten_with_path(v)[0]:
                    sub = _SEP.join(str(getattr(p, "key",
                                                getattr(p, "idx", p)))
                                    for p in kp)
                    arrays[key + _SEP + sub] = \
                        np.asarray(jax.device_get(leaf))

    visit(params, "")
    stats = {"packed_bytes": int(packed_bytes), "fp32_bytes": int(fp32_bytes),
             "reduction_x": fp32_bytes / max(packed_bytes, 1)}
    blob = _encode(arrays, {"format": "tt_deploy", "tt_deploy": deploy_meta,
                            "stats": stats})
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
    os.replace(tmp, path)
    return stats


def load_tt_deploy(path: str, dequantize: bool = True):
    """Load a deploy export. With ``dequantize`` the cores come back as f32
    values on the 4-bit grid in their original (R, J, I, R') shapes (ready
    for ``ttm_matvec``); otherwise as ``numerics.QTensor`` packed
    containers in the flattened-per-core export layout. Returns
    (params, meta)."""
    from ..numerics import QTensor, QuantSpec, decode

    with open(path, "rb") as f:
        arrays, meta = _decode(f.read())
    deploy = meta.get("tt_deploy", {})

    out: dict = {}

    def put(key: str, value):
        parts = key.split(_SEP)
        node = out
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = value

    seen = set()
    for key in arrays:
        base = key[:-len(_SEP + "q")] if key.endswith(_SEP + "q") else None
        if base is not None and base in deploy:
            if base in seen:
                continue
            seen.add(base)
            info = deploy[base]
            spec = QuantSpec.from_json_dict(info["spec"])
            shape = tuple(info["shape"])
            flat_shape = shape[:-4] + (int(np.prod(shape[-4:])),)
            qt = QTensor(jnp.asarray(arrays[base + _SEP + "q"]),
                         jnp.asarray(arrays[base + _SEP + "scale"]),
                         spec, flat_shape)
            put(base, decode(qt).reshape(shape) if dequantize else qt)
        elif key.endswith(_SEP + "scale") and key[:-len(_SEP + "scale")] \
                in deploy:
            continue
        else:
            put(key, jnp.asarray(arrays[key]))
    return out, meta


def install_preemption_handler(fn: Callable[[], None]):
    """Run ``fn`` (an emergency checkpoint flush) on SIGTERM."""
    def handler(signum, frame):
        fn()
        raise SystemExit(143)

    signal.signal(signal.SIGTERM, handler)

"""Fault-tolerant checkpointing.

- Format: flattened path->array dict, msgpack + zstd, one file per save.
- Atomic: write to ``.tmp`` then rename; a crash mid-write never corrupts
  the latest checkpoint.
- Async: a writer thread snapshots (device_get) synchronously (cheap) and
  serializes/compresses/writes in the background so the train loop never
  blocks on disk.
- Mesh-agnostic (elastic): arrays are saved unsharded (fully addressable
  host copies); ``load`` reshards onto whatever mesh/sharding the new job
  uses — restart on a different pod count just works.
- SIGTERM hook: ``install_preemption_handler`` flushes an emergency save on
  preemption (the standard cloud-TPU eviction signal).
"""
from __future__ import annotations

import os
import queue
import signal
import threading
from typing import Any, Callable

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                                  # optional: fall back to uncompressed
    import zstandard
except ImportError:                   # pragma: no cover - env dependent
    zstandard = None

_SEP = "§"
_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"     # zstd frame header (RFC 8878)


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for kp, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in kp)
        out[key] = np.asarray(jax.device_get(leaf))
    return out


def _encode(arrays: dict[str, np.ndarray], meta: dict) -> bytes:
    payload = {
        "meta": meta,
        "arrays": {
            k: {"dtype": str(v.dtype), "shape": list(v.shape),
                "data": v.tobytes()}
            for k, v in arrays.items()
        },
    }
    raw = msgpack.packb(payload, use_bin_type=True)
    if zstandard is None:
        return raw
    return zstandard.ZstdCompressor(level=3).compress(raw)


def _decode(blob: bytes) -> tuple[dict[str, np.ndarray], dict]:
    if blob[:4] == _ZSTD_MAGIC:
        if zstandard is None:
            raise RuntimeError(
                "checkpoint is zstd-compressed but the zstandard module is "
                "not installed")
        raw = zstandard.ZstdDecompressor().decompress(blob)
    else:
        raw = blob
    payload = msgpack.unpackb(raw, raw=False, strict_map_key=False)
    arrays = {
        k: np.frombuffer(v["data"], dtype=v["dtype"]).reshape(v["shape"])
        for k, v in payload["arrays"].items()
    }
    return arrays, payload["meta"]


def save(path: str, tree, meta: dict | None = None):
    """Synchronous atomic save of a pytree."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    blob = _encode(_flatten(tree), meta or {})
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(blob)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def load(path: str, like=None, sharding_tree=None):
    """Load a checkpoint. With ``like`` (a pytree of the target structure),
    arrays are restored into that structure (and cast to the target dtypes);
    with ``sharding_tree`` they are device_put with the given shardings —
    this is the elastic-restart reshard point."""
    with open(path, "rb") as f:
        arrays, meta = _decode(f.read())
    if like is None:
        return arrays, meta
    flat = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = None
    if sharding_tree is not None:
        shard_flat = jax.tree_util.tree_flatten(sharding_tree,
                                                is_leaf=lambda x: x is None)[0]
    leaves = []
    for i, (kp, leaf) in enumerate(flat[0]):
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in kp)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key].astype(leaf.dtype) if hasattr(leaf, "dtype") \
            else arrays[key]
        if shard_flat is not None and shard_flat[i] is not None:
            arr = jax.device_put(arr, shard_flat[i])
        else:
            arr = jnp.asarray(arr)
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(flat[1], leaves), meta


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(f.split("_")[1].split(".")[0])
             for f in os.listdir(ckpt_dir)
             if f.startswith("step_") and f.endswith(".ckpt")]
    return max(steps) if steps else None


def step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step}.ckpt")


class AsyncCheckpointer:
    """Snapshot on the caller thread, serialize+write in the background."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=2)
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        self._last_exc: Exception | None = None

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                self._q.task_done()
                return
            step, arrays, meta = item
            try:
                blob = _encode(arrays, meta)
                path = step_path(self.ckpt_dir, step)
                os.makedirs(self.ckpt_dir, exist_ok=True)
                tmp = path + ".tmp"
                with open(tmp, "wb") as f:
                    f.write(blob)
                os.replace(tmp, path)
                self._gc()
            except Exception as e:            # pragma: no cover
                self._last_exc = e
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(int(f.split("_")[1].split(".")[0])
                       for f in os.listdir(self.ckpt_dir)
                       if f.startswith("step_") and f.endswith(".ckpt"))
        for s in steps[:-self.keep]:
            try:
                os.remove(step_path(self.ckpt_dir, s))
            except OSError:
                pass

    def save(self, step: int, tree, meta: dict | None = None):
        arrays = _flatten(tree)                  # synchronous snapshot
        meta = dict(meta or {})
        meta["step"] = step
        self._q.put((step, arrays, meta))        # async write

    def wait(self):
        self._q.join()
        if self._last_exc:
            raise self._last_exc

    def close(self):
        self._q.put(None)
        self._thread.join(timeout=10)


def install_preemption_handler(fn: Callable[[], None]):
    """Run ``fn`` (an emergency checkpoint flush) on SIGTERM."""
    def handler(signum, frame):
        fn()
        raise SystemExit(143)

    signal.signal(signal.SIGTERM, handler)

from . import adam, binaryconnect, grad_compress, schedule  # noqa: F401
from .adam import AdamState, adam_update, clip_by_global_norm, init_adam  # noqa: F401
from .schedule import lr_at  # noqa: F401

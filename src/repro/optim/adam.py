"""AdamW with optional block-wise 8-bit first/second moments.

No optax dependency. The 8-bit state path (Dettmers-style block-wise absmax
quantization) is the ``optimizer_moment`` site of the unified quantization
API: moments are ``numerics.QTensor``s produced by the blockwise codec
(shape-preserving along the last axis), which is what lets
deepseek-v2-236B optimizer state fit a 256-chip pod (DESIGN.md §5).

λ ("lambda_*") and integer leaves are excluded from Adam: λ gets the
closed-form Eq.(4) update, integers (scale exponents) are managed by the
scale manager.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig
from ..numerics import QTensor, QuantSpec, decode, encode
from ..numerics.codecs import blockwise_geometry

# the optimizer_moment spec (NumericsPolicy default): blockwise int8 along
# the last axis. Shape preservation matters at scale: the q8 state then
# carries the SAME sharding as its parameter, so the optimizer update is
# fully local. A flat layout forces GSPMD to reshard the whole moment
# tensor every step (measured 75 GB all-gathers per expert leaf on
# deepseek-v2 — see EXPERIMENTS.md §Perf iteration 1).
MOMENT_SPEC = QuantSpec("blockwise", 8, 256, "int8", "per_tensor_max")
BLOCK = MOMENT_SPEC.block


def _is_adam_leaf(path: str, leaf) -> bool:
    if not jnp.issubdtype(leaf.dtype, jnp.floating):
        return False
    name = path.split("/")[-1]
    if name.startswith(("lambda_", "wscale")):
        return False
    return True


def _path_str(kp) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)


def _q8_init(x: jax.Array) -> QTensor:
    shape = x.shape if x.ndim > 0 else (1,)
    b, nb, _ = blockwise_geometry(MOMENT_SPEC, shape[-1])
    return QTensor(jnp.zeros(shape[:-1] + (nb * b,), jnp.int8),
                   jnp.zeros(shape[:-1] + (nb,), jnp.float32),
                   MOMENT_SPEC, shape)


def _q8_encode(v: jax.Array) -> QTensor:
    return encode(v, MOMENT_SPEC)


def _q8_decode(qt: QTensor, shape, n=None):
    return decode(qt, jnp.float32).reshape(shape)


class AdamState(NamedTuple):
    """Moments stored as tuples aligned with the flattened params tree
    (element = None | f32 array | blockwise-int8 ``numerics.QTensor``).
    Tuples keep flattening unambiguous in the presence of container-valued
    8-bit states."""
    step: jax.Array
    m: tuple
    v: tuple


def init_adam(params, cfg: TrainConfig) -> AdamState:
    int8 = cfg.opt_state_dtype == "int8"
    flat = jax.tree_util.tree_flatten_with_path(params)[0]

    def init_leaf(kp, leaf):
        if not _is_adam_leaf(_path_str(kp), leaf):
            return None
        if int8:
            return _q8_init(leaf)
        return jnp.zeros(leaf.shape, jnp.float32)

    leaves = [init_leaf(kp, l) for kp, l in flat]
    m = tuple(leaves)
    v = tuple(None if l is None else jax.tree.map(jnp.copy, l) for l in leaves)
    return AdamState(jnp.zeros((), jnp.int32), m, v)


def adam_update(params, grads, state: AdamState, lr: jax.Array,
                cfg: TrainConfig):
    """Returns (new_params, new_state). Supports f32 and int8 moment states."""
    int8 = cfg.opt_state_dtype == "int8"
    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
    treedef = jax.tree_util.tree_structure(params)
    g_leaves = jax.tree_util.tree_flatten(grads)[0]

    new_p, new_m, new_v = [], [], []
    for (kp, p), g, m, v in zip(flat_p, g_leaves, state.m, state.v):
        if m is None or g is None \
                or getattr(g, "dtype", None) == jax.dtypes.float0 \
                or not jnp.issubdtype(g.dtype, jnp.floating):
            new_p.append(p)
            new_m.append(m)
            new_v.append(v)
            continue
        g32 = g.astype(jnp.float32)
        if int8:
            m32 = _q8_decode(m, p.shape, p.size)
            v32 = _q8_decode(v, p.shape, p.size)
        else:
            m32, v32 = m, v
        m32 = b1 * m32 + (1 - b1) * g32
        v32 = b2 * v32 + (1 - b2) * jnp.square(g32)
        update = (m32 / c1) / (jnp.sqrt(v32 / c2) + eps)
        name = _path_str(kp).split("/")[-1]
        decay = 0.0 if name in ("scale", "b", "bias") or p.ndim < 2 else wd
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (update + decay * p32)
        new_p.append(p32.astype(p.dtype))
        if int8:
            new_m.append(_q8_encode(m32))
            new_v.append(_q8_encode(v32))
        else:
            new_m.append(m32)
            new_v.append(v32)

    params_out = jax.tree_util.tree_unflatten(treedef, new_p)
    return params_out, AdamState(step, tuple(new_m), tuple(new_v))


def moment_nbytes(state: AdamState) -> tuple[int, int]:
    """(resident, fp32-shadow) bytes of the optimizer moments — the
    ``optimizer_moment`` site of ``obs.ledger``.  QTensor moments count
    codes + block scales as actually stored; the shadow is what the same
    moments would cost as two f32 arrays per tracked parameter leaf."""
    import math
    resident = fp32 = 0
    for mm in (*state.m, *state.v):
        if mm is None:
            continue
        if isinstance(mm, QTensor):
            resident += mm.nbytes()
            fp32 += 4 * math.prod(mm.shape)
        else:
            resident += int(mm.nbytes)
            fp32 += 4 * int(mm.size)
    return resident, fp32


def global_norm(grads) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(grads)
              if hasattr(g, "dtype") and jnp.issubdtype(g.dtype, jnp.floating)]
    return jnp.sqrt(sum(leaves) + 1e-20)


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / gn)
    return jax.tree.map(
        lambda g: (g * scale).astype(g.dtype)
        if hasattr(g, "dtype") and jnp.issubdtype(g.dtype, jnp.floating) else g,
        grads), gn

"""BinaryConnect training loop glue (paper Eq. 3).

The *buffer* (full-precision master copy) is the params tree itself; the
forward/backward pass sees quantized weights via the fake-quant in
``tt_layer.effective_cores``. Eq. (3) is then exactly: SGD/Adam applies the
gradient (taken w.r.t. the quantized cores, STE) to the full-precision
buffer; the next forward re-quantizes. This module adds the explicit
"deploy" quantization used at export (the ``tt_factor`` site of the unified
quantization API, routed through ``core.quant.quantize_store`` ->
``numerics`` pow2 codec), and the λ closed-form update hook.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import QuantConfig
from ..core import quant as Q


def quantize_for_deploy(params, qc: QuantConfig):
    """Hard-quantize TT cores (and biases) for inference export: the trained
    model deploys with weight_bits cores / act_bits biases (paper §3.2)."""
    def visit(tree):
        if not isinstance(tree, dict):
            return tree
        out = {}
        steps = tree.get("wscale_log2")
        for k, v in tree.items():
            if isinstance(v, dict):
                out[k] = visit(v)
            elif k.startswith("core_") and steps is not None:
                n = int(k.split("_")[1])
                out[k] = Q.quantize_store(
                    v, steps[n].astype(jnp.float32), qc.weight_bits)
            elif k in ("bias", "b"):
                out[k] = Q.quantize_store(
                    v, jnp.asarray(0.0 - (qc.act_bits - 1), jnp.float32),
                    qc.act_bits)
            else:
                out[k] = v
        return out

    return visit(params)

"""Gradient compression for the DP all-reduce path: int8 block-quantized
gradients with error feedback (residual carried to the next step).

On-theme distributed-optimization trick: the paper trains with 16-bit
gradients on-chip; at multi-pod scale the analogous saving is on the wire —
the data-parallel reduce moves 1/4 the bytes (int8 vs f32) at the cost of a
residual buffer. Error feedback keeps the scheme unbiased over time
(Karimireddy et al. 2019).

Usage (inside the jitted train step, before the optimizer):
    grads_c, residual = compress_decompress(grads, residual)
XLA then all-reduces the (already quantized-valued) tensors; on real
multi-host meshes the int8 wire format is achieved by casting the
quantized values to int8 for the psum under shard_map (``psum_int8``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 1024


def _quant_block(v: jax.Array):
    n = v.size
    nb = (n + BLOCK - 1) // BLOCK
    flat = jnp.pad(v.reshape(-1), (0, nb * BLOCK - n)).reshape(nb, BLOCK)
    scale = jnp.max(jnp.abs(flat), axis=1, keepdims=True) / 127.0
    q = jnp.round(flat / jnp.maximum(scale, 1e-20))
    deq = (jnp.clip(q, -127, 127) * scale).reshape(-1)[:n].reshape(v.shape)
    return deq


def compress_decompress(grads, residual):
    """Returns (compressed grads, new residual). residual=None initializes."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if residual is None:
        res_leaves = [jnp.zeros_like(g, jnp.float32)
                      if jnp.issubdtype(g.dtype, jnp.floating) else None
                      for g in leaves]
    else:
        res_leaves = list(residual)
    out, new_res = [], []
    for g, r in zip(leaves, res_leaves):
        if r is None or not jnp.issubdtype(g.dtype, jnp.floating):
            out.append(g)
            new_res.append(r)
            continue
        corrected = g.astype(jnp.float32) + r
        deq = _quant_block(corrected)
        out.append(deq.astype(g.dtype))
        new_res.append(corrected - deq)
    return jax.tree_util.tree_unflatten(treedef, out), tuple(new_res)

"""Gradient compression for the DP all-reduce path: int8 block-quantized
gradients with error feedback (residual carried to the next step).

On-theme distributed-optimization trick: the paper trains with 16-bit
gradients on-chip; at multi-pod scale the analogous saving is on the wire —
the data-parallel reduce moves 1/4 the bytes (int8 vs f32) at the cost of a
residual buffer. Error feedback keeps the scheme unbiased over time
(Karimireddy et al. 2019).

The quantizer is the ``dp_wire`` site of the unified quantization API:
each gradient leaf is flattened and round-tripped through the blockwise
int8 codec (block 1024 — coarser than the optimizer-moment block because
the wire format amortizes one f32 scale per 1 KiB payload).

Two entry points:

- ``compress_decompress``: the single-program path — quantize-dequantize
  each leaf locally; XLA's automatic all-reduce then moves the (already
  quantized-valued) tensors in f32. Values are int8-representable; bytes
  are not.
- ``psum_int8`` / ``psum_int8_tree``: the explicit shard_map collective
  that puts the int8 CODES themselves on the wire. Per block: the local
  absmax scale is shared across devices (``lax.pmax`` — f32, 1/block of
  the payload), every device encodes onto the shared grid, the int8 codes
  cross the wire (``lax.all_gather``), and the sum runs in a widened int32
  accumulator before one decode back onto the grid. The error-feedback
  residual stays device-local (each device's own quantization error), so
  the scheme remains unbiased over time exactly as in the local path.

Usage (inside the jitted train step, before the optimizer):
    grads_c, residual = compress_decompress(grads, residual)
or, under ``sharding.compat_shard_map`` over the plan's dp axes:
    grads_sum, residual = psum_int8_tree(grads, residual, plan.dp_axis())
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..numerics import QuantSpec, roundtrip, spec_nbytes
from ..numerics.codecs import blockwise_geometry

WIRE_SPEC = QuantSpec("blockwise", 8, 1024, "int8", "per_tensor_max")
BLOCK = WIRE_SPEC.block


def residual_nbytes(residual) -> int:
    """Resident bytes of an error-feedback residual tuple (the
    ``grad_residual`` site of ``obs.ledger``; None entries are non-float
    leaves that carry no residual)."""
    if residual is None:
        return 0
    return sum(int(r.nbytes) for r in residual if r is not None)


def wire_nbytes(grads, spec: QuantSpec = WIRE_SPEC) -> tuple[int, int]:
    """(encoded, fp32) bytes of one gradient all-reduce payload — the
    ``dp_wire`` site of ``obs.ledger``.  Matches the codec's layout exactly:
    each float leaf flattens and encodes blockwise (codes padded to a block
    multiple + one f32 scale per block), which is what ``psum_int8`` puts
    on the wire."""
    enc = fp32 = 0
    for g in jax.tree_util.tree_leaves(grads):
        if hasattr(g, "dtype") and jnp.issubdtype(g.dtype, jnp.floating):
            enc += spec_nbytes(spec, (int(g.size),))
            fp32 += 4 * int(g.size)
    return enc, fp32


def compress_decompress(grads, residual, spec: QuantSpec = WIRE_SPEC):
    """Returns (compressed grads, new residual). residual=None initializes."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if residual is None:
        res_leaves = [jnp.zeros_like(g, jnp.float32)
                      if jnp.issubdtype(g.dtype, jnp.floating) else None
                      for g in leaves]
    else:
        res_leaves = list(residual)
    out, new_res = [], []
    for g, r in zip(leaves, res_leaves):
        if r is None or not jnp.issubdtype(g.dtype, jnp.floating):
            out.append(g)
            new_res.append(r)
            continue
        corrected = g.astype(jnp.float32) + r
        deq = roundtrip(corrected.reshape(-1), spec).reshape(g.shape)
        out.append(deq.astype(g.dtype))
        new_res.append(corrected - deq)
    return jax.tree_util.tree_unflatten(treedef, out), tuple(new_res)


def psum_int8(g: jax.Array, residual: jax.Array | None, axis_name,
              spec: QuantSpec = WIRE_SPEC):
    """int8-wire all-reduce of one gradient leaf. MUST run inside shard_map
    (``axis_name`` is the mesh axis of the data-parallel replicas).

    Returns ``(summed, new_residual)``: the cross-device SUM of the
    quantized gradients (divide by the dp size for the mean) and the
    device-local error-feedback residual. The only payload-sized tensor
    that crosses a collective is int8 (asserted by
    tests/test_distributed.py against the jaxpr).
    """
    shape, dtype = g.shape, g.dtype
    corrected = g.astype(jnp.float32) + \
        (residual if residual is not None else 0.0)
    flat = corrected.reshape(-1)
    b, nb, pad = blockwise_geometry(spec, flat.shape[0])
    if pad:
        flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(nb, b)
    qmax = spec.qmax
    # shared per-block grid: pmax of the local absmax scales, so codes from
    # different devices are integers on ONE grid and sum exactly
    sc = jnp.max(jnp.abs(blocks), axis=-1) / qmax
    sc = jnp.maximum(jax.lax.pmax(sc, axis_name), 1e-20)
    codes = jnp.clip(jnp.round(blocks / sc[:, None]), -qmax, qmax)
    wire = codes.astype(spec.jnp_storage)              # THE wire tensor
    gathered = jax.lax.all_gather(wire, axis_name)     # (ndev, nb, b) int8
    total = jnp.sum(gathered.astype(jnp.int32), axis=0)  # widened accumulator
    n = flat.shape[0] - pad
    summed = (total.astype(jnp.float32) * sc[:, None]).reshape(-1)[:n]
    deq_local = (codes * sc[:, None]).reshape(-1)[:n]
    new_residual = corrected - deq_local.reshape(shape)
    return summed.reshape(shape).astype(dtype), new_residual


def psum_int8_tree(grads, residual, axis_name, spec: QuantSpec = WIRE_SPEC):
    """Tree version of ``psum_int8`` with ``compress_decompress``'s residual
    conventions (tuple aligned with the flattened leaves; None residual
    initializes zeros; non-float leaves pass through untouched)."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if residual is None:
        res_leaves = [jnp.zeros_like(g, jnp.float32)
                      if jnp.issubdtype(g.dtype, jnp.floating) else None
                      for g in leaves]
    else:
        res_leaves = list(residual)
    out, new_res = [], []
    for g, r in zip(leaves, res_leaves):
        if r is None or not jnp.issubdtype(g.dtype, jnp.floating):
            out.append(g)
            new_res.append(r)
            continue
        s, nr = psum_int8(g, r, axis_name, spec)
        out.append(s)
        new_res.append(nr)
    return jax.tree_util.tree_unflatten(treedef, out), tuple(new_res)

"""Gradient compression for the DP all-reduce path: int8 block-quantized
gradients with error feedback (residual carried to the next step).

On-theme distributed-optimization trick: the paper trains with 16-bit
gradients on-chip; at multi-pod scale the analogous saving is on the wire —
the data-parallel reduce moves 1/4 the bytes (int8 vs f32) at the cost of a
residual buffer. Error feedback keeps the scheme unbiased over time
(Karimireddy et al. 2019).

The quantizer is the ``dp_wire`` site of the unified quantization API:
each gradient leaf is flattened and round-tripped through the blockwise
int8 codec (block 1024 — coarser than the optimizer-moment block because
the wire format amortizes one f32 scale per 1 KiB payload).

Usage (inside the jitted train step, before the optimizer):
    grads_c, residual = compress_decompress(grads, residual)
XLA then all-reduces the (already quantized-valued) tensors; on real
multi-host meshes the int8 wire format is achieved by casting the
quantized values to int8 for the psum under shard_map (``psum_int8``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..numerics import QuantSpec, roundtrip

WIRE_SPEC = QuantSpec("blockwise", 8, 1024, "int8", "per_tensor_max")
BLOCK = WIRE_SPEC.block


def compress_decompress(grads, residual, spec: QuantSpec = WIRE_SPEC):
    """Returns (compressed grads, new residual). residual=None initializes."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    if residual is None:
        res_leaves = [jnp.zeros_like(g, jnp.float32)
                      if jnp.issubdtype(g.dtype, jnp.floating) else None
                      for g in leaves]
    else:
        res_leaves = list(residual)
    out, new_res = [], []
    for g, r in zip(leaves, res_leaves):
        if r is None or not jnp.issubdtype(g.dtype, jnp.floating):
            out.append(g)
            new_res.append(r)
            continue
        corrected = g.astype(jnp.float32) + r
        deq = roundtrip(corrected.reshape(-1), spec).reshape(g.shape)
        out.append(deq.astype(g.dtype))
        new_res.append(corrected - deq)
    return jax.tree_util.tree_unflatten(treedef, out), tuple(new_res)

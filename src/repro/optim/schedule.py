"""LR schedules (pure functions of the step)."""
from __future__ import annotations

import jax.numpy as jnp

from ..configs.base import TrainConfig


def lr_at(step, cfg: TrainConfig):
    """Linear warmup then cosine decay to 10%."""
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    total = max(cfg.total_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(total - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)

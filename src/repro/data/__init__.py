from . import pipeline, synthetic  # noqa: F401
from .pipeline import Prefetcher, host_shard_info  # noqa: F401
from .synthetic import fashion_like, lm_batch  # noqa: F401

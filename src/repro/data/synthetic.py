"""Synthetic datasets (offline container — no downloads).

1. ``lm_batches`` — Zipf-distributed token streams with a learnable
   structure (next token correlated with a linear hash of the previous two)
   so that training loss demonstrably decreases.
2. ``fashion_like`` — FashionMNIST drop-in for the paper reproduction:
   28×28 grayscale 10-class images synthesized from class-specific low-rank
   templates + noise; padded to 28×32 and TT-reshaped exactly as the paper
   (Appendix B).
"""
from __future__ import annotations

import numpy as np


def lm_batch(step: int, *, batch: int, seq: int, vocab: int,
             shard: int = 0, num_shards: int = 1, seed: int = 0):
    """Deterministic, stateless-resumable: batch content is a pure function
    of (step, shard) — restart-safe and elastic (resharding changes only the
    shard index mapping)."""
    rng = np.random.default_rng(
        np.random.SeedSequence([seed, step, shard, num_shards]))
    b = batch // num_shards
    # zipf-ish marginal + markov structure
    base = rng.zipf(1.3, size=(b, seq + 1)).astype(np.int64) % vocab
    a1, a2, c = 6364136223846793005, 1442695040888963407, 1013904223
    for t in range(2, seq + 1):
        mix = (base[:, t - 1] * a1 + base[:, t - 2] * a2 + c) % vocab
        use = rng.random(b) < 0.5
        base[:, t] = np.where(use, mix, base[:, t])
    tokens = base[:, :seq].astype(np.int32)
    labels = base[:, 1:seq + 1].astype(np.int32)
    return {"tokens": tokens, "labels": labels}


_TEMPLATES = None


def _templates(vocab_classes: int = 10, rng=None):
    global _TEMPLATES
    if _TEMPLATES is None:
        r = np.random.default_rng(1234)
        # class templates: low-rank smooth structures, fixed across calls
        u = r.normal(size=(vocab_classes, 28, 3))
        v = r.normal(size=(vocab_classes, 3, 28))
        _TEMPLATES = np.einsum("cik,ckj->cij", u, v)
        _TEMPLATES /= np.abs(_TEMPLATES).max(axis=(1, 2), keepdims=True)
    return _TEMPLATES


def fashion_like(n: int, *, seed: int = 0, noise: float = 0.35):
    """(images (n, 28, 32) float32 in [-1,1] zero-padded cols, labels (n,))."""
    rng = np.random.default_rng(seed)
    t = _templates()
    labels = rng.integers(0, 10, size=n)
    imgs = t[labels] + noise * rng.normal(size=(n, 28, 28))
    imgs = np.clip(imgs, -1, 1)
    out = np.zeros((n, 28, 32), np.float32)
    out[:, :, 2:30] = imgs
    return out.reshape(n, -1), labels.astype(np.int32)

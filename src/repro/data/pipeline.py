"""Host data pipeline: per-host sharding + background prefetch.

- Each JAX process reads only its shard (``jax.process_index`` /
  ``jax.process_count``); single-host runs degenerate to shard 0/1.
- Prefetch thread keeps ``depth`` batches ready so host data generation
  overlaps device compute (straggler mitigation at the input layer).
- Stateless-resumable: the stream position is just the step counter, which
  the checkpoint stores — restart resumes mid-epoch with no replay.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax


class Prefetcher:
    def __init__(self, make_batch: Callable[[int], dict], start_step: int,
                 depth: int = 2):
        self._make = make_batch
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._make(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        while True:
            yield self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def host_shard_info() -> tuple[int, int]:
    return jax.process_index(), jax.process_count()

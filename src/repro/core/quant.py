"""Low-precision training numerics (paper §3.2-3.3) — the QAT-facing layer
over the unified ``repro.numerics`` codecs.

- Power-of-2-scaled symmetric fixed point: q = clip(round(x / 2^k), -2^{b-1}, 2^{b-1}-1)
- Fake-quant with clipped straight-through estimator (STE): gradient passes
  where the pre-quant value was inside the representable range, zero outside
  (the paper's "clipped ReLU" STE).
- Automatic scale selection (§3.3): track the running mean of |x / 2^k| and
  bump k up/down to keep it inside [0.1, 0.3]. Scales are shared across
  samples and neurons of the same tensor-site; TT-factor scales are fixed.
- BinaryConnect (Courbariaux et al. 2015): full-precision buffer updated with
  gradients taken w.r.t. the quantized parameters (see optim/binaryconnect.py).

The round/clip/scale math lives in ``numerics/codecs.py`` (one
implementation for training, optimizer state, the gradient wire, and the
KV-cache); this module re-exports the §3.2 primitives and keeps the fused
forward-activation/backward-gradient edge (``quant_edge``) plus the probe
plumbing the scale manager uses to observe backward magnitudes.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..numerics.codecs import pow2_fake_quant, pow2_qdq, roundtrip
from ..numerics.policy import (ScaleState, init_scale, step_log2,
                               update_scale)
from ..numerics.spec import QuantSpec, qrange

__all__ = ["qrange", "fake_quant", "quantize_store", "ScaleState",
           "init_scale", "update_scale", "quant_act", "ActQuant",
           "init_act_quant", "quant_edge", "quant_edge_shared",
           "update_act_quant"]

# canonical §3.2 Q(.) with clipped STE — one implementation, shared with the
# Pallas codec backend (numerics/pallas_backend.py wraps the same vjp)
fake_quant = pow2_fake_quant


def quantize_store(x: jax.Array, scale_log2: jax.Array, bits: int) -> jax.Array:
    """Pure quantize (no STE) — the Q(.) of paper Eq. (3); used on the
    BinaryConnect buffer after the optimizer step."""
    return roundtrip(x, QuantSpec("pow2", bits), scale_log2)


def quant_act(x: jax.Array, state: ScaleState, bits: int) -> jax.Array:
    """Fake-quant an activation with its managed scale.

    The *hardware* scale is 2^k relative to the fractional fixed-point grid:
    representable range = [-2^{b-1}, 2^{b-1}-1] * step where
    step = 2^{k-(b-1)}  (so "mean |x|/2^k in [0.1,0.3]" uses a healthy
    fraction of the range) — see ``numerics.policy.step_log2``.
    """
    return fake_quant(x, step_log2(state, bits), bits)


class ActQuant(NamedTuple):
    """A forward-activation + backward-gradient quantization site.

    The paper quantizes activations to 8 bits on the forward pass and
    gradients to 16 bits on the backward pass, with independently managed
    scales.
    """
    act: ScaleState
    grad: ScaleState
    probe: jax.Array     # 0-valued scalar; its *gradient* carries mean|g| stats


def init_act_quant() -> ActQuant:
    return ActQuant(init_scale(0), init_scale(0), jnp.zeros((), jnp.float32))


@partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _quant_edge(x, act_log2, grad_log2, probe, act_bits: int, grad_bits: int):
    step = act_log2.astype(jnp.float32) - (act_bits - 1)
    return pow2_qdq(x, step, act_bits)


def _qe_fwd(x, act_log2, grad_log2, probe, act_bits, grad_bits):
    step = act_log2.astype(jnp.float32) - (act_bits - 1)
    scale = jnp.exp2(step).astype(x.dtype)
    lo, hi = qrange(act_bits)
    inside = (x / scale >= lo) & (x / scale <= hi)
    return pow2_qdq(x, step, act_bits), (inside, grad_log2)


def _qe_bwd(act_bits, grad_bits, res, g):
    inside, grad_log2 = res
    # quantize the incoming activation-gradient to grad_bits (paper: 16-bit)
    step = grad_log2.astype(jnp.float32) - (grad_bits - 1)
    gq = pow2_qdq(g, step, grad_bits)
    gq = jnp.where(inside, gq, 0.0).astype(g.dtype)
    # probe cotangent = mean |g| / 2^k : the scale-manager statistic.
    stat = jnp.mean(jnp.abs(g.astype(jnp.float32))) / jnp.exp2(grad_log2.astype(jnp.float32))
    return (gq, jnp.zeros_like(grad_log2, jnp.float32),
            jnp.zeros_like(grad_log2, jnp.float32), stat)


_quant_edge.defvjp(_qe_fwd, _qe_bwd)


def quant_edge(x: jax.Array, site: ActQuant, act_bits: int, grad_bits: int) -> jax.Array:
    """Insert an (8-bit fwd, 16-bit bwd) quantization point on tensor ``x``.

    Differentiating the containing function w.r.t. ``site.probe`` yields the
    backward-gradient magnitude statistic used by ``update_act_quant``.
    """
    return _quant_edge(x, site.act.log2, site.grad.log2, site.probe,
                       act_bits, grad_bits)


def quant_edge_shared(x: jax.Array, act: ScaleState, grad: ScaleState,
                      act_bits: int, grad_bits: int) -> jax.Array:
    """The zoo-LM form of ``quant_edge``: an (act_bits fwd, grad_bits bwd)
    quantization point driven by the policy's SHARED managed scales (one
    ``ScaleState`` owner per site across the whole stack, no per-tensor
    probe — the §3.3 statistic is observed at the step level instead;
    see ``models/lm.py::_act_quant_edge`` / ``launch/steps.py``)."""
    site = ActQuant(act, grad, jnp.zeros((), jnp.float32))
    return quant_edge(x, site, act_bits, grad_bits)


def update_act_quant(site: ActQuant, x: jax.Array, grad_stat: jax.Array | None,
                     lo: float, hi: float, ema: float) -> ActQuant:
    """Scale-manager update for one site. ``grad_stat`` is the cotangent of
    ``site.probe`` (mean |g|/2^k observed on the backward pass)."""
    act = update_scale(site.act, x, lo=lo, hi=hi, ema=ema)
    grad = site.grad
    if grad_stat is not None:
        m = ema * grad.mean_abs + (1.0 - ema) * grad_stat
        up = (m > hi).astype(jnp.int32)
        dn = (m < lo).astype(jnp.int32)
        grad = ScaleState(grad.log2 + up - dn,
                          m * jnp.exp2(-(up - dn).astype(jnp.float32)))
    return ActQuant(act, grad, site.probe)

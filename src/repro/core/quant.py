"""Low-precision training numerics (paper §3.2-3.3).

- Power-of-2-scaled symmetric fixed point: q = clip(round(x / 2^k), -2^{b-1}, 2^{b-1}-1)
- Fake-quant with clipped straight-through estimator (STE): gradient passes
  where the pre-quant value was inside the representable range, zero outside
  (the paper's "clipped ReLU" STE).
- Automatic scale selection (§3.3): track the running mean of |x / 2^k| and
  bump k up/down to keep it inside [0.1, 0.3]. Scales are shared across
  samples and neurons of the same tensor-site; TT-factor scales are fixed.
- BinaryConnect (Courbariaux et al. 2015): full-precision buffer updated with
  gradients taken w.r.t. the quantized parameters (see optim/binaryconnect.py).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


def qrange(bits: int) -> tuple[float, float]:
    return -(2.0 ** (bits - 1)), 2.0 ** (bits - 1) - 1.0


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def fake_quant(x: jax.Array, scale_log2: jax.Array, bits: int) -> jax.Array:
    """Quantize-dequantize with pow-2 scale; STE in the backward pass."""
    scale = jnp.exp2(scale_log2).astype(x.dtype)
    lo, hi = qrange(bits)
    q = jnp.clip(jnp.round(x / scale), lo, hi)
    return q * scale


def _fq_fwd(x, scale_log2, bits):
    scale = jnp.exp2(scale_log2).astype(x.dtype)
    lo, hi = qrange(bits)
    inside = (x / scale >= lo) & (x / scale <= hi)
    q = jnp.clip(jnp.round(x / scale), lo, hi)
    return q * scale, inside


def _fq_bwd(bits, inside, g):
    # clipped STE: pass gradient only where |x| was representable
    return (jnp.where(inside, g, 0.0).astype(g.dtype), None)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


def quantize_store(x: jax.Array, scale_log2: jax.Array, bits: int) -> jax.Array:
    """Pure quantize (no STE) — the Q(.) of paper Eq. (3); used on the
    BinaryConnect buffer after the optimizer step."""
    scale = jnp.exp2(scale_log2).astype(x.dtype)
    lo, hi = qrange(bits)
    return jnp.clip(jnp.round(x / scale), lo, hi) * scale


# ---------------------------------------------------------------------------
# Scale manager (§3.3)
# ---------------------------------------------------------------------------

class ScaleState(NamedTuple):
    """Per-site dynamic scale: k (log2 scale) and the tracked mean |x/2^k|."""
    log2: jax.Array     # int32 scalar
    mean_abs: jax.Array  # f32 scalar, EMA of mean |x| / 2^k


def init_scale(log2: int = 0) -> ScaleState:
    return ScaleState(jnp.asarray(log2, jnp.int32), jnp.asarray(0.2, jnp.float32))


def update_scale(state: ScaleState, x: jax.Array, *, lo: float = 0.1,
                 hi: float = 0.3, ema: float = 0.9) -> ScaleState:
    """Track mean|x/2^k| and adjust k to hold it in [lo, hi] (paper §3.3).

    jit-friendly; runs on stop_gradient(x).
    """
    x = jax.lax.stop_gradient(x).astype(jnp.float32)
    m = jnp.mean(jnp.abs(x)) / jnp.exp2(state.log2.astype(jnp.float32))
    m = ema * state.mean_abs + (1.0 - ema) * m
    up = (m > hi).astype(jnp.int32)      # too large -> coarser scale (k+1)
    dn = (m < lo).astype(jnp.int32)      # too small -> finer scale (k-1)
    new_log2 = state.log2 + up - dn
    # after a bump the tracked statistic halves/doubles accordingly
    m = m * jnp.exp2(-(up - dn).astype(jnp.float32))
    return ScaleState(new_log2, m)


def quant_act(x: jax.Array, state: ScaleState, bits: int) -> jax.Array:
    """Fake-quant an activation with its managed scale.

    The *hardware* scale is 2^k relative to the fractional fixed-point grid:
    an 8-bit tensor with scale k holds values q*2^k/2^{b-1}*2^{b-1}... we fold
    everything into: representable range = [-2^{b-1}, 2^{b-1}-1] * step where
    step = 2^k / 2^{b-1}  (so "mean |x|/2^k in [0.1,0.3]" uses a healthy
    fraction of the range).
    """
    step_log2 = state.log2.astype(jnp.float32) - (bits - 1)
    return fake_quant(x, step_log2, bits)


class ActQuant(NamedTuple):
    """A forward-activation + backward-gradient quantization site.

    The paper quantizes activations to 8 bits on the forward pass and
    gradients to 16 bits on the backward pass, with independently managed
    scales.
    """
    act: ScaleState
    grad: ScaleState
    probe: jax.Array     # 0-valued scalar; its *gradient* carries mean|g| stats


def init_act_quant() -> ActQuant:
    return ActQuant(init_scale(0), init_scale(0), jnp.zeros((), jnp.float32))


@partial(jax.custom_vjp, nondiff_argnums=(4, 5))
def _quant_edge(x, act_log2, grad_log2, probe, act_bits: int, grad_bits: int):
    step = act_log2.astype(jnp.float32) - (act_bits - 1)
    scale = jnp.exp2(step).astype(x.dtype)
    lo, hi = qrange(act_bits)
    return jnp.clip(jnp.round(x / scale), lo, hi) * scale


def _qe_fwd(x, act_log2, grad_log2, probe, act_bits, grad_bits):
    step = act_log2.astype(jnp.float32) - (act_bits - 1)
    scale = jnp.exp2(step).astype(x.dtype)
    lo, hi = qrange(act_bits)
    inside = (x / scale >= lo) & (x / scale <= hi)
    y = jnp.clip(jnp.round(x / scale), lo, hi) * scale
    return y, (inside, grad_log2)


def _qe_bwd(act_bits, grad_bits, res, g):
    inside, grad_log2 = res
    # quantize the incoming activation-gradient to grad_bits (paper: 16-bit)
    step = grad_log2.astype(jnp.float32) - (grad_bits - 1)
    scale = jnp.exp2(step).astype(g.dtype)
    lo, hi = qrange(grad_bits)
    gq = jnp.clip(jnp.round(g / scale), lo, hi) * scale
    gq = jnp.where(inside, gq, 0.0).astype(g.dtype)
    # probe cotangent = mean |g| / 2^k : the scale-manager statistic.
    stat = jnp.mean(jnp.abs(g.astype(jnp.float32))) / jnp.exp2(grad_log2.astype(jnp.float32))
    return (gq, jnp.zeros_like(grad_log2, jnp.float32),
            jnp.zeros_like(grad_log2, jnp.float32), stat)


_quant_edge.defvjp(_qe_fwd, _qe_bwd)


def quant_edge(x: jax.Array, site: ActQuant, act_bits: int, grad_bits: int) -> jax.Array:
    """Insert an (8-bit fwd, 16-bit bwd) quantization point on tensor ``x``.

    Differentiating the containing function w.r.t. ``site.probe`` yields the
    backward-gradient magnitude statistic used by ``update_act_quant``.
    """
    return _quant_edge(x, site.act.log2, site.grad.log2, site.probe,
                       act_bits, grad_bits)


def update_act_quant(site: ActQuant, x: jax.Array, grad_stat: jax.Array | None,
                     lo: float, hi: float, ema: float) -> ActQuant:
    """Scale-manager update for one site. ``grad_stat`` is the cotangent of
    ``site.probe`` (mean |g|/2^k observed on the backward pass)."""
    act = update_scale(site.act, x, lo=lo, hi=hi, ema=ema)
    grad = site.grad
    if grad_stat is not None:
        m = ema * grad.mean_abs + (1.0 - ema) * grad_stat
        up = (m > hi).astype(jnp.int32)
        dn = (m < lo).astype(jnp.int32)
        grad = ScaleState(grad.log2 + up - dn,
                          m * jnp.exp2(-(up - dn).astype(jnp.float32)))
    return ActQuant(act, grad, site.probe)

"""TT-factorized linear layer: TTM algebra + rank adaptation + QAT composed.

Pure-functional: params are pytrees (dicts), specs are static. This is the
first-class layer type every model in the zoo can select per weight-site
(see ``models/common.py::linear``).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import QuantConfig, TTConfig
from ..numerics import QuantSpec, fake_quant
from . import rank_adapt as RA
from .ttm import TTMSpec, init_cores, make_spec, ttm_matvec

Params = dict[str, Any]


def weight_scale_log2(sigma: float, bits: int) -> int:
    """Fixed pow-2 *step* for TT factors: cover ~4 sigma with 2^{bits-1} levels."""
    full = 4.0 * max(sigma, 1e-8)
    return int(np.ceil(np.log2(full / 2 ** (bits - 1))))


def tt_linear_init(key: jax.Array, out_dim: int, in_dim: int, tt: TTConfig,
                   dtype=jnp.float32, use_bias: bool = True,
                   j_dims=None, i_dims=None, ranks=None) -> tuple[Params, TTMSpec]:
    spec = make_spec(out_dim, in_dim, tt.d, tt.max_rank,
                     j_dims=j_dims, i_dims=i_dims, ranks=ranks)
    cores = init_cores(key, spec, dtype=dtype)
    params: Params = {f"core_{n}": c for n, c in enumerate(cores)}
    if use_bias:
        params["bias"] = jnp.zeros((out_dim,), dtype)
    if tt.rank_adapt:
        for n, lam in enumerate(RA.init_lambdas(spec)):
            params[f"lambda_{n}"] = lam
    # fixed per-core quant step (paper: TT-factor scales are fixed);
    # sigma from the init formula (analytic — keeps init eval_shape-able)
    target_var = 2.0 / (spec.in_dim + spec.out_dim)
    rank_prod = math.prod(spec.ranks[1:spec.d]) if spec.d > 1 else 1.0
    sigma = ((target_var / rank_prod) ** (1.0 / spec.d)) ** 0.5
    params["wscale_log2"] = jnp.asarray(
        [weight_scale_log2(sigma, 4)] * spec.d, jnp.int32)
    return params, spec


def get_cores(params: Params, spec: TTMSpec) -> list[jax.Array]:
    return [params[f"core_{n}"] for n in range(spec.d)]


def get_lambdas(params: Params, spec: TTMSpec) -> list[jax.Array] | None:
    if f"lambda_0" not in params and spec.d > 1:
        return None
    return [params[f"lambda_{n}"] for n in range(spec.d - 1)]


def effective_cores(params: Params, spec: TTMSpec, tt: TTConfig,
                    qc: QuantConfig) -> list[jax.Array]:
    """Cores as seen by the forward pass: rank-masked then fake-quantized."""
    cores = get_cores(params, spec)
    if tt.rank_adapt and spec.d > 1:
        lambdas = get_lambdas(params, spec)
        masks = RA.rank_masks([jax.lax.stop_gradient(l) for l in lambdas],
                              tt.prune_threshold)
        cores = RA.apply_masks(cores, masks)
    if qc.enable:
        # the ``tt_factor`` site: pow-2 codec, fixed per-core scales (§3.2)
        spec = QuantSpec("pow2", qc.weight_bits, 0, "int8", "fixed")
        steps = params["wscale_log2"]
        cores = [fake_quant(c, spec, steps[n].astype(jnp.float32))
                 for n, c in enumerate(cores)]
    return cores


def tt_linear_apply(params: Params, x: jax.Array, spec: TTMSpec, tt: TTConfig,
                    qc: QuantConfig) -> jax.Array:
    cores = effective_cores(params, spec, tt, qc)
    y = ttm_matvec([c.astype(x.dtype) for c in cores], x, spec)
    if "bias" in params:
        y = y + params["bias"].astype(y.dtype)
    return y


def tt_prior_loss(params: Params, spec: TTMSpec, tt: TTConfig) -> jax.Array:
    """g(θ, λ) contribution of this layer (0 if rank adaptation disabled)."""
    if not tt.rank_adapt or spec.d < 2:
        return jnp.zeros((), jnp.float32)
    cores = get_cores(params, spec)
    lambdas = get_lambdas(params, spec)
    return tt.gamma * RA.prior_loss(cores, lambdas, spec)


def tt_lambda_update(params: Params, spec: TTMSpec, tt: TTConfig) -> Params:
    """Closed-form Eq.(4) update of the λ entries (applied post-step)."""
    if not tt.rank_adapt or spec.d < 2:
        return params
    cores = get_cores(params, spec)
    new = dict(params)
    for n, lam in enumerate(RA.update_lambdas(cores, spec)):
        new[f"lambda_{n}"] = lam
    return new


def tt_param_count(params: Params, spec: TTMSpec, tt: TTConfig) -> tuple[int, int]:
    """(live_params, total_params) after rank pruning by current λ."""
    lambdas = get_lambdas(params, spec)
    if lambdas is None:
        return spec.num_params, spec.num_params
    eff = RA.effective_ranks(lambdas, tt.prune_threshold)
    ranks = [1] + eff + [1]
    live = sum(ranks[n] * spec.j_dims[n] * spec.i_dims[n] * ranks[n + 1]
               for n in range(spec.d))
    return live, spec.num_params

"""Tensor-Train-Matrix (TTM) algebra (paper §2, Appendix A).

A weight matrix ``W ∈ R^{J×I}`` with ``I = ∏ I_n``, ``J = ∏ J_n`` is represented
by ``d`` cores ``G_n ∈ R^{R_{n-1} × J_n × I_n × R_n}`` with ``R_0 = R_d = 1``:

    W(j_1..j_d, i_1..i_d) = G_1(:,j_1,i_1,:) @ G_2(:,j_2,i_2,:) @ ... @ G_d(:,j_d,i_d,:)

Forward ``y = W x`` is the contraction chain of paper Eqs. (8)-(10): contract the
input tensor with G_d first, then G_{d-1}, ..., G_1.  We implement the chain with
einsum (XLA maps each step to an MXU matmul); the Pallas kernels in
``repro.kernels`` implement the same two canonical contraction forms the paper's
PE1/PE2 use, and ``ttm_matvec_pe`` below routes through them.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial, reduce

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Shape factorization helpers
# ---------------------------------------------------------------------------

def _factorize(n: int, d: int) -> tuple[int, ...]:
    """Split integer ``n`` into ``d`` factors, as balanced as possible.

    Uses the prime factorization and greedily assigns the largest primes to the
    currently-smallest bucket, so e.g. 7168 -> (16, 28, 16) for d=3.
    """
    if d == 1:
        return (n,)
    primes: list[int] = []
    m = n
    p = 2
    while p * p <= m:
        while m % p == 0:
            primes.append(p)
            m //= p
        p += 1
    if m > 1:
        primes.append(m)
    buckets = [1] * d
    for q in sorted(primes, reverse=True):
        buckets[int(np.argmin(buckets))] *= q
    return tuple(sorted(buckets))


def auto_factorize(out_dim: int, in_dim: int, d: int) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Choose (J_1..J_d), (I_1..I_d) for a (out_dim, in_dim) matrix."""
    return _factorize(out_dim, d), _factorize(in_dim, d)


def clip_ranks(j_dims: tuple[int, ...], i_dims: tuple[int, ...], max_rank: int) -> tuple[int, ...]:
    """TT-ranks R_0..R_d: R_n <= min(prod_left, prod_right, max_rank)."""
    d = len(j_dims)
    ranks = [1]
    for n in range(1, d):
        left = math.prod(j_dims[:n]) * math.prod(i_dims[:n])
        right = math.prod(j_dims[n:]) * math.prod(i_dims[n:])
        ranks.append(int(min(left, right, max_rank)))
    ranks.append(1)
    return tuple(ranks)


@dataclass(frozen=True)
class TTMSpec:
    """Static description of one TTM-factorized matrix (out = J, in = I)."""
    j_dims: tuple[int, ...]
    i_dims: tuple[int, ...]
    ranks: tuple[int, ...]          # length d+1, ranks[0] == ranks[-1] == 1

    @property
    def d(self) -> int:
        return len(self.j_dims)

    @property
    def out_dim(self) -> int:
        return math.prod(self.j_dims)

    @property
    def in_dim(self) -> int:
        return math.prod(self.i_dims)

    @property
    def core_shapes(self) -> tuple[tuple[int, int, int, int], ...]:
        return tuple(
            (self.ranks[n], self.j_dims[n], self.i_dims[n], self.ranks[n + 1])
            for n in range(self.d)
        )

    @property
    def num_params(self) -> int:
        return sum(math.prod(s) for s in self.core_shapes)

    @property
    def dense_params(self) -> int:
        return self.out_dim * self.in_dim

    @property
    def compression(self) -> float:
        return self.dense_params / max(self.num_params, 1)


def make_spec(out_dim: int, in_dim: int, d: int, max_rank: int,
              j_dims: tuple[int, ...] | None = None,
              i_dims: tuple[int, ...] | None = None,
              ranks: tuple[int, ...] | None = None) -> TTMSpec:
    if j_dims is None or i_dims is None:
        j_auto, i_auto = auto_factorize(out_dim, in_dim, d)
        j_dims = j_dims or j_auto
        i_dims = i_dims or i_auto
    assert math.prod(j_dims) == out_dim, (j_dims, out_dim)
    assert math.prod(i_dims) == in_dim, (i_dims, in_dim)
    if ranks is None:
        ranks = clip_ranks(j_dims, i_dims, max_rank)
    return TTMSpec(tuple(j_dims), tuple(i_dims), tuple(ranks))


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_cores(key: jax.Array, spec: TTMSpec, dtype=jnp.float32,
               scale: float | None = None) -> list[jax.Array]:
    """Initialize cores so that the reconstructed W has Glorot-like variance.

    var(W_elem) = prod_n var(G_n_elem) * prod_{n<d} R_n   (independent cores)
    Target var(W) = 2 / (I + J)  =>  per-core sigma solves the product.
    """
    d = spec.d
    target_var = scale if scale is not None else 2.0 / (spec.in_dim + spec.out_dim)
    rank_prod = math.prod(spec.ranks[1:d]) if d > 1 else 1.0
    per_core_var = (target_var / rank_prod) ** (1.0 / d)
    sigma = per_core_var ** 0.5
    keys = jax.random.split(key, d)
    return [
        (jax.random.normal(keys[n], spec.core_shapes[n], dtype=jnp.float32) * sigma).astype(dtype)
        for n in range(d)
    ]


# ---------------------------------------------------------------------------
# Contraction chain (paper Eqs. 8-10) — einsum path
# ---------------------------------------------------------------------------

def ttm_matvec(cores: list[jax.Array], x: jax.Array, spec: TTMSpec) -> jax.Array:
    """y = W x for batched input x: (..., I) -> (..., J).

    Contracts right-to-left exactly as paper Eqs. (8)-(10):
      Z_1(b, i_1..i_{d-1}, r_{d-1}, j_d)         = sum_{i_d}  X * G_d
      Z_2(b, i_1..i_{d-2}, r_{d-2}, j_{d-1} j_d) = sum_{i_{d-1} r_{d-1}} Z_1 * G_{d-1}
      ...
      Y(b, j_1..j_d)                             = sum_{i_1 r_1} Z_{d-1} * G_1

    Each step is a single reshaped matmul:
      (b*left, acc, i_n*r_in) @ (i_n*r_in, r_out*j_n)
    where acc is the accumulated trailing (j_{n+1}..j_{d}) block.
    """
    d = spec.d
    batch_shape = x.shape[:-1]
    b = math.prod(batch_shape) if batch_shape else 1
    z = x.reshape(b, spec.in_dim)   # layout (b, i_0 .. i_{d-1})
    acc = 1                         # accumulated J block (trailing)
    r_in = 1                        # == ranks[d]
    for n in range(d - 1, -1, -1):
        i_n, j_n, r_out = spec.i_dims[n], spec.j_dims[n], spec.ranks[n]
        left = math.prod(spec.i_dims[:n]) if n > 0 else 1
        # z layout: (b, i_0..i_{n-1}, i_n, r_in, acc) -> expose matmul dims
        z = z.reshape(b * left, i_n * r_in, acc)
        g = cores[n]                # (r_out, j_n, i_n, r_in)
        gm = g.transpose(2, 3, 0, 1).reshape(i_n * r_in, r_out * j_n)
        # (b*left, acc, i_n*r_in) @ (i_n*r_in, r_out*j_n)
        z = jnp.einsum("xkc,kd->xdc", z, gm)
        # output layout (b*left, r_out*j_n, acc): trailing = (r_out, j_n, acc)
        acc *= j_n
        r_in = r_out
        z = z.reshape(b * left, r_out * acc)
    return z.reshape(batch_shape + (spec.out_dim,))


def ttm_to_dense(cores: list[jax.Array], spec: TTMSpec) -> jax.Array:
    """Materialize W (J, I). Test/export only — O(J*I) memory."""
    d = spec.d
    # result tensor over (j_1, i_1, ..., j_n, i_n, R_n)
    w = cores[0].reshape(spec.j_dims[0] * spec.i_dims[0], spec.ranks[1])
    for n in range(1, d):
        g = cores[n].reshape(spec.ranks[n], -1)   # (R_n, J_n*I_n*R_{n+1})
        w = (w @ g).reshape(-1, spec.ranks[n + 1])
    # w: (j1,i1,j2,i2,...,jd,id) flattened -> permute to (j1..jd, i1..id)
    w = w.reshape(sum(((spec.j_dims[n], spec.i_dims[n]) for n in range(d)), ()))
    perm = list(range(0, 2 * d, 2)) + list(range(1, 2 * d, 2))
    w = w.transpose(perm)
    return w.reshape(spec.out_dim, spec.in_dim)


def ttm_flops_matvec(spec: TTMSpec, batch: int) -> int:
    """MACs*2 of the Eq.(8)-(10) chain for `batch` rows."""
    d = spec.d
    total = 0
    for k in range(d):
        n = d - 1 - k
        left = math.prod(spec.i_dims[:n])
        right_j = math.prod(spec.j_dims[n + 1:]) if n + 1 < d else 1
        # contraction: (b*left*right_j, i_n*r_in) x (i_n*r_in, r_out*j_n)
        total += 2 * batch * left * right_j * spec.i_dims[n] * spec.ranks[n + 1] \
            * spec.ranks[n] * spec.j_dims[n]
    return total


# ---------------------------------------------------------------------------
# Canonical PE forms (paper Eqs. 5-6) — pure-jnp references used by kernels
# and by the PE-routed matvec below.
# ---------------------------------------------------------------------------

def pe1_contract(z: jax.Array, g: jax.Array) -> jax.Array:
    """PE1 (Eq. 5): Z'(a,d) = sum_{b,c} Z(a,b,c) * G(b,d,c)."""
    return jnp.einsum("abc,bdc->ad", z, g)


def pe2_contract(z: jax.Array, g: jax.Array) -> jax.Array:
    """PE2 (Eq. 6): Z'(a,d,c) = sum_b Z(a,b,c) * G(b,d)."""
    return jnp.einsum("abc,bd->adc", z, g)


def pe3_outer(x: jax.Array, ybar: jax.Array) -> jax.Array:
    """PE3: batched outer product  What(j, i) = sum_b Ybar(b,j) * X(b,i).

    (On TPU this is a matmul over the batch dim — see DESIGN.md §2.)
    """
    return jnp.einsum("bj,bi->ji", ybar, x)


def core_grads_from_what(what: jax.Array, cores: list[jax.Array],
                         spec: TTMSpec) -> list[jax.Array]:
    """Per-core gradients from the full-weight gradient Ŵ (paper Appendix
    A.2, Eqs. 14-19): ĝ_n = Ŵ contracted with every core except n.

    This is the paper's PE3-fed gradient path ("more efficient [when] the
    batch size is [large]"); used at FMNIST scale and as the oracle that the
    autodiff path must match (tests/test_ttm.py).
    """
    d = spec.d
    wt = what.reshape(spec.j_dims + spec.i_dims)
    perm = [x for n in range(d) for x in (n, d + n)]
    wt = wt.transpose(perm).reshape(
        tuple(spec.j_dims[n] * spec.i_dims[n] for n in range(d)))
    cores3 = [c.reshape(spec.ranks[n], -1, spec.ranks[n + 1])
              for n, c in enumerate(cores)]
    m_l = "abcdef"           # mode letters (d <= 6)
    r_l = "uvwxyzs"          # rank letters (d+1 <= 7)
    grads = []
    for n in range(d):
        subs = [m_l[:d]]
        ops: list[jax.Array] = [wt.astype(jnp.float32)]
        for k in range(d):
            if k == n:
                continue
            subs.append(r_l[k] + m_l[k] + r_l[k + 1])
            ops.append(cores3[k].astype(jnp.float32))
        # boundary ranks R_0 == R_d == 1 never appear in the inputs when the
        # boundary core is the one being differentiated — drop the letter
        # and reshape instead.
        out = m_l[n]
        if n > 0:
            out = r_l[n] + out
        if n < d - 1:
            out = out + r_l[n + 1]
        g = jnp.einsum(",".join(subs) + "->" + out, *ops)
        grads.append(g.reshape(cores[n].shape).astype(cores[n].dtype))
    return grads


def ttm_matvec_pe(cores: list[jax.Array], x: jax.Array, spec: TTMSpec,
                  pe1=pe1_contract, pe2=pe2_contract) -> jax.Array:
    """Same result as ``ttm_matvec`` but routed through the two canonical PE
    forms with the exact reshapes of paper Table 3 (rows for Eqs. 8-10).

    Used to validate the Pallas kernels end-to-end: pass kernel impls as
    pe1/pe2.
    """
    d = spec.d
    batch_shape = x.shape[:-1]
    b = math.prod(batch_shape) if batch_shape else 1
    # Eq. (8): PE1 with a=b*I_1..I_{d-1}, b_dim=1, c=I_d, d_out=R_{d-1}*J_d
    g = cores[d - 1]                                    # (R_{d-1}, J_d, I_d, 1)
    rdm1, jd, idd = spec.ranks[d - 1], spec.j_dims[d - 1], spec.i_dims[d - 1]
    a = b * (math.prod(spec.i_dims[:d - 1]) if d > 1 else 1)
    z = x.reshape(a, 1, idd)
    gmat = g.reshape(rdm1, jd, idd).transpose(0, 1, 2).reshape(rdm1 * jd, idd)
    z = pe1(z, gmat.reshape(1, rdm1 * jd, idd))         # (a, R_{d-1}*J_d)
    acc_j = jd                                           # accumulated trailing J block
    # Eq. (9) steps: PE2 with c = accumulated J, b_dim = I_n*R_n, d_out = R_{n-1}*J_n
    for n in range(d - 2, -1, -1):
        r_in, r_out = spec.ranks[n + 1], spec.ranks[n]
        i_n, j_n = spec.i_dims[n], spec.j_dims[n]
        left = math.prod(spec.i_dims[:n]) if n > 0 else 1
        # z currently: (b*left*i_n, r_in*acc_j) -> (b*left, i_n*r_in, acc_j)
        z = z.reshape(b * left, i_n, r_in, acc_j).reshape(b * left, i_n * r_in, acc_j)
        g = cores[n]                                    # (r_out, j_n, i_n, r_in)
        gmat = g.transpose(2, 3, 0, 1).reshape(i_n * r_in, r_out * j_n)
        z = pe2(z, gmat)                                # (b*left, r_out*j_n, acc_j)
        z = z.reshape(b * left, r_out, j_n * acc_j)
        acc_j *= j_n
        z = z.reshape(b * left, r_out * acc_j) if n == 0 else \
            z.reshape(b * (math.prod(spec.i_dims[:n - 1]) if n - 1 > 0 else 1),
                      spec.i_dims[n - 1], r_out * acc_j).reshape(-1, r_out * acc_j)
    return z.reshape(batch_shape + (spec.out_dim,))

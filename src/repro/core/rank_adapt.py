"""Rank-adaptive tensorized training (paper §3.1, Eqs. 1-2 and 4).

The loss adds g(θ, λ) = Σ_{n=1}^{d-1} Σ_{r}  ‖G_n(:,:,:,r)‖_F² / λ_n(r)
                                         + (1 + R_{n-1} I_n J_n)/2 · log λ_n(r)

(negative log-posterior of the Hawkins-Liu-Zhang Bayesian model). λ is updated
in closed form each step (Eq. 4):

    λ_n(r) = 2 / (1 + R_{n-1} I_n J_n) · ‖G_n(:,:,:,r)‖_F²

which is exactly the stationary point of g in λ. Slices whose λ collapses
toward 0 are pruned (masked during jit training; physically sliced at export).
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from .ttm import TTMSpec


def slice_sqnorms(core: jax.Array) -> jax.Array:
    """‖G_n(:,:,:,r)‖_F² for every r along the last (rank) axis -> (R_n,)."""
    return jnp.sum(jnp.square(core.astype(jnp.float32)), axis=(0, 1, 2))


def group_size(spec: TTMSpec, n: int) -> int:
    """1 + R_{n-1} I_n J_n for core n (0-based)."""
    return 1 + spec.ranks[n] * spec.i_dims[n] * spec.j_dims[n]


# λ is floored to keep the prior gradient 2·G/λ bounded once a slice has
# collapsed (otherwise 1/λ → ∞ and SGD diverges; the floor turns the pull
# on dead slices into a stable exponential decay).
LAMBDA_FLOOR = 1e-8

# The absolute floor alone cannot deliver that stability: λ tracks the
# slice's squared norm (Eq. 4), so by the time λ reaches any fixed absolute
# floor the pull 2·G/λ has long exceeded the SGD stability limit — the
# slice overshoots zero, flips sign and *revives* (observed as effective
# ranks oscillating back to full and the fit degrading late in training).
# The prior therefore also floors λ RELATIVE to the core's largest λ:
# slices below PRIOR_REL_FLOOR · max λ are "dead" (the same relative scale
# ``rank_masks`` prunes at), and their pull saturates at a bounded,
# monotone exponential decay instead of growing without bound.
PRIOR_REL_FLOOR = 1e-2


def _prior_floor(lam: jax.Array) -> jax.Array:
    """λ as seen by the prior: floored at max(PRIOR_REL_FLOOR·max λ,
    LAMBDA_FLOOR) so the dead-slice pull is bounded and scale-free."""
    return jnp.maximum(lam, jnp.maximum(PRIOR_REL_FLOOR * jnp.max(lam),
                                        LAMBDA_FLOOR))


def init_lambdas(spec: TTMSpec) -> list[jax.Array]:
    """λ_n for n = 0..d-2 (no λ for the last core: R_d == 1)."""
    return [jnp.ones((spec.ranks[n + 1],), jnp.float32) for n in range(spec.d - 1)]


def update_lambdas(cores: Sequence[jax.Array], spec: TTMSpec,
                   eps: float = LAMBDA_FLOOR) -> list[jax.Array]:
    """Closed-form λ update (Eq. 4), floored for numerical stability."""
    return [
        jnp.maximum(2.0 / group_size(spec, n) * slice_sqnorms(cores[n]), eps)
        for n in range(spec.d - 1)
    ]


def prior_loss(cores: Sequence[jax.Array], lambdas: Sequence[jax.Array],
               spec: TTMSpec) -> jax.Array:
    """g(θ, λ) (Eq. 2). λ is treated as constant within the SGD step
    (stop_gradient), matching the paper's alternating update: SGD on θ,
    closed-form on λ."""
    total = jnp.zeros((), jnp.float32)
    for n in range(spec.d - 1):
        lam = _prior_floor(jax.lax.stop_gradient(lambdas[n]))
        sq = slice_sqnorms(cores[n])
        c = 0.5 * group_size(spec, n)
        total = total + jnp.sum(sq / lam + c * jnp.log(lam))
    return total


def rank_masks(lambdas: Sequence[jax.Array], threshold: float) -> list[jax.Array]:
    """Binary keep-masks per adapted rank: keep r if λ(r) > threshold·max λ."""
    masks = []
    for lam in lambdas:
        masks.append((lam > threshold * jnp.max(lam)).astype(jnp.float32))
    return masks


def apply_masks(cores: Sequence[jax.Array], masks: Sequence[jax.Array]) -> list[jax.Array]:
    """Zero out pruned rank slices. mask n applies to core n's last axis and
    core n+1's first axis (one multiply suffices for the matvec product; we
    mask the last axis of core n)."""
    out = list(cores)
    for n, m in enumerate(masks):
        out[n] = out[n] * m[None, None, None, :].astype(out[n].dtype)
    return out


def effective_ranks(lambdas: Sequence[jax.Array], threshold: float) -> list[int]:
    return [int(jnp.sum(lam > threshold * jnp.max(lam))) for lam in lambdas]


def compress_cores(cores: Sequence[jax.Array], lambdas: Sequence[jax.Array],
                   spec: TTMSpec, threshold: float) -> tuple[list[jax.Array], TTMSpec]:
    """Physically slice away pruned ranks (export / checkpoint path; not jit)."""
    d = spec.d
    keep = [jnp.nonzero(lam > threshold * jnp.max(lam))[0] for lam in lambdas]
    new_cores = []
    new_ranks = [1]
    for n in range(d):
        c = cores[n]
        if n > 0:
            c = jnp.take(c, keep[n - 1], axis=0)
        if n < d - 1:
            c = jnp.take(c, keep[n], axis=3)
        new_cores.append(c)
        new_ranks.append(c.shape[3])
    new_spec = TTMSpec(spec.j_dims, spec.i_dims, tuple(new_ranks))
    return new_cores, new_spec


def tt_memory_bits(spec: TTMSpec, weight_bits: int, eff_ranks: list[int] | None = None) -> int:
    """Model-parameter memory in bits (paper Table 1 accounting)."""
    ranks = list(spec.ranks)
    if eff_ranks is not None:
        ranks = [1] + [int(r) for r in eff_ranks] + [1]
    total = 0
    for n in range(spec.d):
        total += ranks[n] * spec.j_dims[n] * spec.i_dims[n] * ranks[n + 1]
    return total * weight_bits

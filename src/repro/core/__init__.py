"""Core: the paper's contribution — TTM algebra, Bayesian rank adaptation,
low-precision numerics (pow-2 fixed point + STE + scale manager), and the
composed TT linear layer."""
from . import quant, rank_adapt, tt_layer, ttm  # noqa: F401

"""Config system: plain dataclasses, JSON-serializable, CLI-overridable.

One ``ModelConfig`` describes any arch in the zoo (dense / GQA / MLA / MoE /
Mamba / RWKV6 / hybrid); ``TTConfig``/``QuantConfig`` toggle the paper's
technique per weight-site; ``ShapeConfig`` is one assigned input-shape cell.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any

# ---------------------------------------------------------------------------
# Paper technique configs
# ---------------------------------------------------------------------------

# Weight sites the TTM factorization / QAT can be applied to.
TT_SITES = ("attn_qkv", "attn_o", "ffn", "expert", "embed", "head", "ssm_proj")


@dataclass(frozen=True)
class TTConfig:
    """Tensor-Train-Matrix factorization config (paper §2, §3.1)."""
    enable: bool = False
    apply_to: tuple[str, ...] = ("ffn", "attn_qkv", "attn_o")
    d: int = 3                      # number of TT cores per matrix
    max_rank: int = 32              # initial rank R_n (adapted downward in training)
    rank_adapt: bool = True         # Bayesian rank shrinkage (Eq. 2/4)
    prune_threshold: float = 1e-3   # lambda_n(r)/max(lambda_n) below this -> slice pruned
    gamma: float = 1.0              # weight on the log-posterior prior term g(.)
    min_elements: int = 1 << 16     # matrices below this stay dense


@dataclass(frozen=True)
class QuantConfig:
    """Low-precision training config (paper §3.2-3.3).

    This is the config-surface *constructor* for the unified quantization
    policy: ``QuantConfig.policy()`` lowers the paper-era knob set onto a
    ``repro.numerics.NumericsPolicy`` (named sites -> QuantSpec), which is
    what the codecs and step factories actually consume.
    """
    enable: bool = False
    weight_bits: int = 4            # TT factors
    act_bits: int = 8               # activations + bias
    grad_bits: int = 16             # gradients
    weight_scale_log2: int = -2     # fixed pow-2 scale for TT factors (paper: fixed)
    # scale manager (§3.3): keep mean |x/2^k| within [lo, hi]
    target_lo: float = 0.1
    target_hi: float = 0.3
    ema: float = 0.9                # running-mean decay for |x| tracking
    health: bool = False            # trace quant-health aggregates (repro.obs)

    def policy(self):
        """Lower onto the unified numerics policy (lazy import: configs
        stay importable without pulling jax-heavy modules)."""
        from ..numerics.policy import policy_from_quant_config
        return policy_from_quant_config(self)


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0            # 0 => dense FFN everywhere
    top_k: int = 2
    num_shared: int = 0             # shared (always-on) experts, DeepSeek-style
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01   # load-balance aux loss


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba / RWKV6 block parameters."""
    d_state: int = 16               # mamba state dim
    d_conv: int = 4                 # mamba conv width
    expand: int = 2                 # mamba inner expansion
    head_dim: int = 64              # rwkv6 head size
    dt_rank: int = 0                # 0 => ceil(d_model/16)


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | ssm_rwkv6 | hybrid_jamba | encoder
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4           # GQA; ==num_heads -> MHA; 1 -> MQA
    head_dim: int = 0               # 0 => d_model // num_heads
    d_ff: int = 1024
    vocab_size: int = 1000
    max_seq_len: int = 8192
    norm_eps: float = 1e-5
    rope_theta: float = 1e6
    tie_embeddings: bool = False
    # attention kind: "gqa" | "mla"
    attn_kind: str = "gqa"
    # pad q-head count up to this for TP divisibility (0 = no padding);
    # pad-head outputs are sliced before o-proj: numerically identical to
    # the unpadded arch, +pad/real extra attention FLOPs, even sharding.
    pad_heads_to: int = 0
    mla: MLAConfig | None = None
    moe: MoEConfig = field(default_factory=MoEConfig)
    ssm: SSMConfig = field(default_factory=SSMConfig)
    # hybrid (jamba): layers per period and which position is attention
    period: int = 1                 # 1 => homogeneous stack
    attn_positions: tuple[int, ...] = ()   # positions within period that are attention
    moe_positions: tuple[int, ...] = ()    # positions within period whose FFN is MoE
    # modality frontend stub: "none" | "audio" | "vision"
    frontend: str = "none"
    is_encoder: bool = False        # encoder-only (no causal mask, no decode)
    # paper technique
    tt: TTConfig = field(default_factory=TTConfig)
    quant: QuantConfig = field(default_factory=QuantConfig)
    # numerics / memory
    dtype: str = "bfloat16"         # activation/param compute dtype
    remat: str = "full"             # "none" | "full" | "dots"
    logits_softcap: float = 0.0

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input-shape cells (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatch: int = 0             # 0 => no grad accumulation
    opt_state_dtype: str = "float32"   # "float32" | "int8" (blockwise-quantized m/v)
    grad_compress: bool = False     # int8+error-feedback DP all-reduce
    seed: int = 0
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 200
    log_every: int = 10


@dataclass(frozen=True)
class MeshConfig:
    data: int = 1
    model: int = 1
    pods: int = 1

    @property
    def num_devices(self) -> int:
        return self.data * self.model * self.pods


def asdict(cfg: Any) -> dict:
    return dataclasses.asdict(cfg)

"""hubert-xlarge [audio]: 48L d_model=1280 16H (GQA kv=16) d_ff=5120
vocab=504 — encoder-only [arXiv:2106.07447]. Frontend (CNN feature
extractor) is a stub: input_specs provides precomputed frame embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="dense", num_layers=48, d_model=1280,
    num_heads=16, num_kv_heads=16, d_ff=5120, vocab_size=504,
    is_encoder=True, frontend="audio", rope_theta=1e4,
)
STRATEGY = "tp"

REDUCED = CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                         num_kv_heads=4, d_ff=128, vocab_size=64)

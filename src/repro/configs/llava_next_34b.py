"""llava-next-34b [vlm]: yi-34b backbone (60L d_model=7168 56H GQA kv=8
d_ff=20480 vocab=64000) + anyres vision frontend STUB — input_specs provides
precomputed patch embeddings (projector output)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="dense", num_layers=60, d_model=7168,
    num_heads=56, num_kv_heads=8, d_ff=20480, vocab_size=64000,
    frontend="vision", pad_heads_to=64,
)
STRATEGY = "tp"
N_PATCHES = 2304          # anyres 672x672: (2x2+1 tiles + newline tokens)

REDUCED = CONFIG.replace(num_layers=2, d_model=112, num_heads=7,
                         num_kv_heads=1, head_dim=16, d_ff=256, vocab_size=64)

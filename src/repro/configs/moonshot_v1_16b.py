"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (GQA kv=16) expert
d_ff=1408 vocab=163840, MoE 64e top-6 [hf:moonshotai/Moonlight-16B-A3B].
Per the assigned one-line spec: all layers MoE, no shared experts (HF config
has 2 shared + first dense layer — deviation recorded in DESIGN.md §4).
The 163,840-row embedding is the zoo's biggest TTM win when --tt is on."""
from .base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b", family="moe", num_layers=48, d_model=2048,
    num_heads=16, num_kv_heads=16, d_ff=1408, vocab_size=163840,
    moe=MoEConfig(num_experts=64, top_k=6),
)
STRATEGY = "tp"

REDUCED = CONFIG.replace(num_layers=2, d_model=64, num_heads=4,
                         num_kv_heads=4, d_ff=96, vocab_size=128,
                         moe=MoEConfig(num_experts=8, top_k=2))

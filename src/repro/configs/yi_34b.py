"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000 —
llama-arch GQA [arXiv:2403.04652]. 56 heads do not divide the 16-way model
axis → q-heads padded to 64 for TP (pad outputs sliced before o-proj:
numerically identical, +14% attention FLOPs; beat the cp/ZeRO-3 baseline by
2.7x on the memory roofline term — EXPERIMENTS.md §Perf)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense", num_layers=60, d_model=7168,
    num_heads=56, num_kv_heads=8, d_ff=20480, vocab_size=64000,
    pad_heads_to=64,
)
STRATEGY = "tp"

REDUCED = CONFIG.replace(num_layers=2, d_model=112, num_heads=7,
                         num_kv_heads=1, head_dim=16, d_ff=256, vocab_size=64)

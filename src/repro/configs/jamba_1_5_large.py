"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16e top-2 — Mamba+attn 1:7 interleave [arXiv:2403.19887].

Period of 8 layers: attention at position 4 (Jamba's attn_layer_offset),
Mamba elsewhere; MoE FFN at odd positions, dense FFN at even (Jamba applies
MoE every other layer).

Serving (repro.serve): hybrid routing — the 1-in-8 attention sublayers page
K/V through the quantized KV pool while the 7-in-8 Mamba sublayers keep
O(1) state (conv (d_conv-1)·d_inner + h d_inner·d_state per layer) in the
``serve/state_cache.py`` pool, so resident serving memory is dominated by
the single attention layer's pages, not the Mamba stack."""
from .base import MoEConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large", family="hybrid_jamba", num_layers=72,
    d_model=8192, num_heads=64, num_kv_heads=8, d_ff=24576,
    vocab_size=65536,
    moe=MoEConfig(num_experts=16, top_k=2),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    period=8, attn_positions=(4,), moe_positions=(1, 3, 5, 7),
)
STRATEGY = "tp"

REDUCED = CONFIG.replace(
    num_layers=8, d_model=64, num_heads=4, num_kv_heads=2, d_ff=96,
    vocab_size=64, period=4, attn_positions=(1,), moe_positions=(1, 3),
    moe=MoEConfig(num_experts=4, top_k=2),
    ssm=SSMConfig(d_state=8, d_conv=4, expand=2))

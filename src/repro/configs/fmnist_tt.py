"""The paper's own experiment: 2-layer TT MLP on (Fashion)MNIST
(Appendix B). Model defined in models/mlp_tt.py; this registry entry only
carries the training hyperparameters."""
from .base import QuantConfig, TTConfig, TrainConfig

TT = TTConfig(enable=True, d=4, max_rank=16, rank_adapt=True,
              prune_threshold=1e-2)
QUANT = QuantConfig(enable=True, weight_bits=4, act_bits=8, grad_bits=16)
TRAIN = TrainConfig(learning_rate=3e-3, warmup_steps=20, total_steps=600,
                    weight_decay=0.0, grad_clip=0.0)
BATCH = 64                 # paper: batches of 64 samples

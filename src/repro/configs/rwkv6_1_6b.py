"""rwkv6-1.6b [ssm]: 24L d_model=2048 (attn-free) d_ff=7168 vocab=65536 —
Finch, data-dependent decay [arXiv:2404.05892]. head_dim=64 → 32 heads.

Serving (repro.serve): attention-free, so the engine runs the scheduler
unpaged — per-slot memory is the O(1) recurrent state in the
``serve/state_cache.py`` pool (per layer: shift 2·d_model + wkv
heads·head_dim² = 135168 f32 elements/slot at full size, int8-quantized
under the ``ssm_state`` policy site), independent of context length."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b", family="ssm_rwkv6", num_layers=24, d_model=2048,
    d_ff=7168, vocab_size=65536, num_heads=32, num_kv_heads=32,
    ssm=SSMConfig(head_dim=64),
)
STRATEGY = "tp"

REDUCED = CONFIG.replace(num_layers=2, d_model=64, d_ff=128, vocab_size=64,
                         num_heads=4, num_kv_heads=4,
                         ssm=SSMConfig(head_dim=16))

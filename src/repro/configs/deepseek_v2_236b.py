"""deepseek-v2-236b [moe]: 60L d_model=5120 128H MLA (kv_lora=512) expert
d_ff=1536, 2 shared + 160 routed top-6, vocab=102400 [arXiv:2405.04434].
Per the assigned spec all layers are MoE (HF: first layer dense — deviation
recorded). Optimizer states default to int8 (blockwise) so the 236B state
fits a 256-chip pod (DESIGN.md §5)."""
from .base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe", num_layers=60, d_model=5120,
    num_heads=128, num_kv_heads=128, d_ff=1536, vocab_size=102400,
    attn_kind="mla",
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, num_shared=2),
)
STRATEGY = "tp"

REDUCED = CONFIG.replace(
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=48,
    vocab_size=128,
    mla=MLAConfig(kv_lora_rank=32, q_lora_rank=48, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, num_shared=1))

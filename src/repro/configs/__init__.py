"""Config registry: ``get_config(arch)`` -> (ModelConfig, strategy),
``get_reduced(arch)`` for smoke tests; ``--tt`` variants via ``with_tt``."""
from __future__ import annotations

import importlib

from .base import (MLAConfig, MeshConfig, ModelConfig, MoEConfig, QuantConfig,
                   SHAPES, SSMConfig, ShapeConfig, TTConfig, TrainConfig)

ARCHS = {
    "hubert-xlarge": "hubert_xlarge",
    "yi-34b": "yi_34b",
    "granite-34b": "granite_34b",
    "internlm2-1.8b": "internlm2_1_8b",
    "stablelm-3b": "stablelm_3b",
    "jamba-1.5-large": "jamba_1_5_large",
    "llava-next-34b": "llava_next_34b",
    "rwkv6-1.6b": "rwkv6_1_6b",
    "moonshot-v1-16b": "moonshot_v1_16b",
    "deepseek-v2-236b": "deepseek_v2_236b",
}


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f".{ARCHS[arch]}", __package__)


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_strategy(arch: str) -> str:
    return getattr(_module(arch), "STRATEGY", "tp")


def get_reduced(arch: str) -> ModelConfig:
    return _module(arch).REDUCED


def with_tt(cfg: ModelConfig, d: int = 3, max_rank: int = 16,
            apply_to=("ffn", "attn_qkv", "attn_o", "expert"),
            quantize: bool = False) -> ModelConfig:
    """The paper's technique switched on for any zoo config.

    Default sites: FFN/attention/expert projections. Embedding/head are NOT
    tensorized by default: vocab sizes with large prime factors (92544 =
    2^7·3·241) make the TTM chain cost explode (measured 26× the dense
    FLOPs at rank 64 — EXPERIMENTS.md §Perf, refuted-hypothesis entry);
    pass apply_to with "embed"/"head" explicitly for power-of-two-ish
    vocabs where it pays off. Default rank 16 (the paper's):
    TTM middle-core cost scales with R^2 — rank 32 measured 5x the
    dense-baseline FLOPs, rank 16 is near parity while cutting the
    projection parameter bytes ~40x (EXPERIMENTS.md §Perf)."""
    return cfg.replace(
        tt=TTConfig(enable=True, d=d, max_rank=max_rank, apply_to=apply_to),
        quant=QuantConfig(enable=quantize))


def valid_cells(arch: str) -> list[str]:
    """Assigned shape cells minus documented skips (DESIGN.md §4)."""
    cfg = get_config(arch)
    cells = ["train_4k", "prefill_32k"]
    if not cfg.is_encoder:
        cells.append("decode_32k")
        if cfg.family in ("ssm_rwkv6", "hybrid_jamba"):
            cells.append("long_500k")
    return cells


ALL_CELLS = [(a, s) for a in ARCHS for s in valid_cells(a)]

"""End-to-end training driver.

Works at every scale: single CPU device (reduced/quickstart configs), a dev
mesh, or the production pod meshes. Includes the fault-tolerance loop:
atomic async checkpointing + resume, SIGTERM emergency save, step-time EWMA
straggler monitor, prefetching input pipeline.

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 50 --batch 8 --seq 128
    PYTHONPATH=src python -m repro.launch.train --arch lm100m --steps 200
"""
from __future__ import annotations

import argparse
import math
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs as C
from ..ckpt import (AsyncCheckpointer, install_preemption_handler,
                    latest_step, load, step_path)
from ..configs.base import ModelConfig, TrainConfig
from ..data import Prefetcher, host_shard_info, lm_batch
from ..models.frontend import synth_audio_frames, synth_vision_patches
from ..models.lm import build_lm, init_lm, lm_param_counts
from ..sharding import make_plan
from ..launch.steps import (TrainState, init_dp_train_state,
                            init_train_state, make_dp_train_step,
                            make_train_step)

# a ~100M-param dense config for the end-to-end example driver
LM100M = ModelConfig(name="lm100m", num_layers=12, d_model=768, num_heads=12,
                     num_kv_heads=12, d_ff=3072, vocab_size=32768,
                     remat="none", dtype="float32")


class StragglerMonitor:
    """EWMA step-time monitor; flags steps slower than ``factor``× the mean.
    At fleet scale the flag feeds the orchestration layer (preempt/replace);
    here it logs — the hook point is what matters."""

    def __init__(self, factor: float = 2.0, decay: float = 0.95):
        self.mean = None
        self.factor = factor
        self.decay = decay
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = self.mean is not None and dt > self.factor * self.mean
        self.mean = dt if self.mean is None else \
            self.decay * self.mean + (1 - self.decay) * dt
        self.flagged += int(slow)
        return slow


def get_model_cfg(name: str, reduced: bool) -> tuple[ModelConfig, str]:
    if name == "lm100m":
        return LM100M, "tp"
    cfg = C.get_reduced(name) if reduced else C.get_config(name)
    if reduced:
        cfg = cfg.replace(dtype="float32", remat="none")
    return cfg, C.get_strategy(name)


def make_batch_fn(cfg: ModelConfig, batch: int, seq: int, seed: int):
    shard, num_shards = host_shard_info()

    def fn(step: int) -> dict:
        b = lm_batch(step, batch=batch, seq=seq, vocab=cfg.vocab_size,
                     shard=shard, num_shards=num_shards, seed=seed)
        if cfg.frontend == "audio":
            rng = np.random.default_rng(step)
            frames = rng.normal(size=(b["tokens"].shape[0], seq,
                                      cfg.d_model)).astype(np.float32)
            return {"frames": frames, "labels": b["labels"] % cfg.vocab_size}
        if cfg.frontend == "vision":
            npatch = max(4, seq // 4)
            rng = np.random.default_rng(step)
            patches = rng.normal(size=(b["tokens"].shape[0], npatch,
                                       cfg.d_model)).astype(np.float32)
            return {"patches": patches, "tokens": b["tokens"],
                    "labels": b["labels"]}
        return b

    return fn


def _record_train_state(ledger, state) -> None:
    """Fold one concrete TrainState into the memory ledger (host-side; runs
    between steps, never inside the jitted body)."""
    from .steps import train_state_sites
    for site, row in train_state_sites(state).items():
        ledger.set(site, row["bytes"], fp32=row["fp32_bytes"])


def train(cfg: ModelConfig, strategy: str, tcfg: TrainConfig, *,
          batch: int, seq: int, mesh=None, verbose: bool = True,
          trace=None, ledger=None):
    """``trace``: optional ``repro.obs.TraceRecorder`` — when attached the
    loop emits one host-side ``train_step`` event per step (step, loss,
    dur, and the step's quant-health aggregates when the policy traces
    them). No recorder → the loop is byte-for-byte the old one.

    ``ledger``: optional ``repro.obs.MemoryLedger`` (one is created
    internally when None) — the loop records the TrainState's allocation
    sites (params / int8 moments / wire residual / scale state) at init and
    after every step, so per-phase peak watermarks and the live
    reduction-vs-f32 figure cover the whole run.  Host-side only: the
    jitted step is untouched."""
    plan = make_plan(mesh, strategy)
    lm = build_lm(cfg)
    key = jax.random.PRNGKey(tcfg.seed)
    params = init_lm(key, lm)
    # the numerics policy owns the managed scale-state tree (threaded
    # through TrainState; no-op scales=None when quantization is off)
    dp_only = (mesh is not None and tcfg.grad_compress
               and all(a in plan.dp_axes for a in mesh.shape))
    if dp_only:
        # dp-only mesh: the explicit shard_map step — the int8 wire is the
        # only payload-sized collective (see steps.make_dp_train_step)
        state = init_dp_train_state(params, tcfg, plan,
                                    policy=cfg.quant.policy())
        step_fn = jax.jit(make_dp_train_step(lm, plan, tcfg),
                          donate_argnums=(0,))
    else:
        state = init_train_state(params, tcfg, policy=cfg.quant.policy())
        step_fn = jax.jit(make_train_step(lm, plan, tcfg),
                          donate_argnums=(0,))

    if ledger is None:
        from ..obs import MemoryLedger
        ledger = MemoryLedger()
    _record_train_state(ledger, state)     # "init" watermark

    ckpt = AsyncCheckpointer(tcfg.ckpt_dir)
    start = 0
    resume = latest_step(tcfg.ckpt_dir)
    if resume is not None:
        state, meta = load(step_path(tcfg.ckpt_dir, resume), like=state)
        start = int(meta.get("step", resume))
        if verbose:
            print(f"[train] resumed from step {start}")

    def emergency():
        ckpt.save(int(state.step), state, {"emergency": True})
        ckpt.wait()

    install_preemption_handler(emergency)

    batch_fn = make_batch_fn(cfg, batch, seq, tcfg.seed)
    prefetch = Prefetcher(batch_fn, start)
    monitor = StragglerMonitor()
    losses = []
    t_start = time.time()
    try:
        for step, np_batch in prefetch:
            if step >= tcfg.total_steps:
                break
            t0 = time.time()
            jb = jax.tree.map(jnp.asarray, np_batch)
            state, metrics = step_fn(state, jb)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            ledger.set_phase("train_step")
            _record_train_state(ledger, state)
            slow = monitor.observe(dt)
            if trace is not None:
                ev = {"step": step, "loss": loss, "dur": dt}
                if "health" in metrics:
                    h = metrics["health"]
                    ev["grad_sat_fraction"] = float(
                        h["grad_edge"]["sat_fraction"])
                    if "activation" in h:
                        ev["act_scale_log2"] = float(
                            h["activation"]["scale_log2"])
                        ev["act_in_band"] = float(h["activation"]["in_band"])
                trace.emit("train_step", **ev)
            if verbose and (step % tcfg.log_every == 0 or slow):
                extra = "  [STRAGGLER]" if slow else ""
                print(f"[train] step {step} loss {loss:.4f} "
                      f"ce {float(metrics['ce']):.4f} {dt*1e3:.0f}ms{extra}")
            if tcfg.ckpt_every and step > 0 and step % tcfg.ckpt_every == 0:
                ckpt.save(step, state, {"loss": loss})
        ckpt.save(int(state.step), state, {"final": True})
        ckpt.wait()
    finally:
        prefetch.close()
        ckpt.close()
    if verbose and losses:
        counts = lm_param_counts(state.params, lm)
        print(f"[train] done: {len(losses)} steps in "
              f"{time.time()-t_start:.1f}s  first-loss {losses[0]:.4f} "
              f"last-loss {losses[-1]:.4f}")
        print(f"[train] params dense-equiv {counts['dense']:.3e} "
              f"live {counts['live']:.3e} "
              f"compression {counts['compression']:.1f}x")
        if mesh is not None:
            ledger.record_devices(state.params, state.opt, state.residual)
        rec = ledger.reconcile()
        wm = ledger.watermark("train_step") or ledger.watermark("init")
        print(f"[train] memory {ledger.total()/1e6:.2f} MB live "
              f"({ledger.reduction_vs_fp32():.1f}x vs same-shape f32), "
              f"train-step watermark {wm['total_bytes']/1e6:.2f} MB, "
              f"reconcile {'ok' if rec['ok'] else 'FAILED'} "
              f"(ledger covers {rec['coverage_frac']:.0%} of "
              f"{rec['live_bytes']/1e6:.2f} MB live arrays)")
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="lm100m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--tt", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=200)
    ap.add_argument("--mesh", default=None,
                    help="e.g. 2x2 for a (data, model) dev mesh, or a bare "
                         "device count (e.g. 8) for the dp-only 1-D mesh "
                         "(with --grad-compress: the shard_map int8-wire "
                         "step)")
    ap.add_argument("--grad-compress", action="store_true",
                    help="int8 + error-feedback gradient wire (dp_wire)")
    ap.add_argument("--trace-out", default=None,
                    help="write per-step train_step trace events (JSONL)")
    args = ap.parse_args()

    cfg, strategy = get_model_cfg(args.arch, args.reduced)
    if args.tt:
        cfg = C.with_tt(cfg, max_rank=32)
    if args.trace_out and cfg.quant.enable:
        # trace run: also switch on the in-step quant-health aggregates
        import dataclasses
        cfg = cfg.replace(quant=dataclasses.replace(cfg.quant, health=True))
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(5, args.steps // 20),
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       grad_compress=args.grad_compress)
    mesh = None
    if args.mesh:
        if "x" in args.mesh:
            d, m = (int(x) for x in args.mesh.split("x"))
            mesh = jax.make_mesh((d, m), ("data", "model"))
        else:
            from .mesh import make_dp_mesh
            mesh = make_dp_mesh(int(args.mesh))
    trace = None
    if args.trace_out:
        from ..obs import TraceRecorder
        trace = TraceRecorder()
    train(cfg, strategy, tcfg, batch=args.batch, seq=args.seq, mesh=mesh,
          trace=trace)
    if trace is not None:
        from ..obs import write_jsonl
        n = write_jsonl(trace, args.trace_out)
        print(f"[train] wrote {n} trace events to {args.trace_out}")


if __name__ == "__main__":
    main()

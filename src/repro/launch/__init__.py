from . import mesh, roofline, specs, steps  # noqa: F401
from .mesh import make_production_mesh  # noqa: F401

"""``input_specs()`` — ShapeDtypeStruct stand-ins for every model input of
every (arch × shape) cell: weak-type-correct, shardable, no allocation."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .. import configs as C
from ..configs.base import ModelConfig, SHAPES, ShapeConfig
from ..models.lm import LMDef, lm_init_cache
from ..sharding import ShardPlan


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig, dtype=jnp.bfloat16,
                      n_patches: int = 2304) -> dict:
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if cfg.frontend == "audio":
        return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype),
                "labels": jax.ShapeDtypeStruct((b, s), i32)}
    if cfg.frontend == "vision":
        st = s - n_patches
        return {"patches": jax.ShapeDtypeStruct((b, n_patches, cfg.d_model), dtype),
                "tokens": jax.ShapeDtypeStruct((b, st), i32),
                "labels": jax.ShapeDtypeStruct((b, st), i32)}
    return {"tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32)}


def batch_pspec(cfg: ModelConfig, specs: dict, plan: ShardPlan) -> dict:
    out = {}
    for k, v in specs.items():
        rest = (None,) * (len(v.shape) - 1)
        out[k] = P(plan.dp_axes, *rest)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig, lm: LMDef,
                       plan: ShardPlan, dtype=jnp.bfloat16):
    """(cache_specs, tokens_spec, cur_len_spec) for one decode step with a
    KV cache of shape.seq_len."""
    b, t = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(partial(lm_init_cache, lm, b, t, plan))
    tokens = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    cur_len = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, tokens, cur_len


def params_shapes(lm: LMDef, key=None):
    from ..models.lm import init_lm
    k = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(partial(init_lm, lm=lm), k)

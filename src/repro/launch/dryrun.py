import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")

"""Multi-pod dry-run: lower + compile every (arch × shape) cell on the
production meshes and extract roofline terms. No device allocation — all
inputs are ShapeDtypeStructs.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
        --shape train_4k [--multi-pod] [--tt] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse          # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402
from functools import partial  # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np       # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from .. import configs as C                         # noqa: E402
from ..configs.base import SHAPES, TrainConfig      # noqa: E402
from ..models.lm import build_lm, lm_cache_pspec    # noqa: E402
from ..sharding import make_plan                    # noqa: E402
from . import roofline as R                         # noqa: E402
from . import specs as SP                           # noqa: E402
from .mesh import make_production_mesh              # noqa: E402
from .steps import (init_train_state, make_prefill_step, make_serve_step,  # noqa: E402
                    make_train_step)


def _tree_pspec_to_shard(mesh, tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree,
        is_leaf=lambda s: isinstance(s, P))


def count_params(shapes_tree) -> float:
    import math
    return float(sum(
        math.prod(l.shape) if l.shape else 1
        for l in jax.tree_util.tree_leaves(shapes_tree)))


def active_params(cfg, n_total: float, lm=None) -> float:
    """Active (per-token) params for MoE archs: replace full expert stack
    with top_k (+shared) experts."""
    if cfg.moe.num_experts == 0:
        return n_total
    e = cfg.moe.num_experts
    # expert site params per layer-with-moe: 3 * d_model * d_ff * E
    from ..models.lm import build_lm as _b
    lmdef = lm or _b(cfg)
    moe_layers = 0
    for i, sub in enumerate(lmdef.period):
        if sub.ffn_kind == "moe":
            moe_layers += 1
    moe_layers *= lmdef.n_periods
    per_expert = 3 * cfg.d_model * cfg.d_ff
    inactive = moe_layers * per_expert * (e - cfg.moe.top_k)
    return n_total - inactive


def dryrun_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                tt: bool = False, verbose: bool = True) -> dict:
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cfg = C.get_config(arch)
    if tt:
        cfg = C.with_tt(cfg)
    strategy = C.get_strategy(arch)
    shape = SHAPES[shape_name]
    tcfg = TrainConfig(
        opt_state_dtype="int8" if arch == "deepseek-v2-236b" else "float32")
    plan = make_plan(mesh, strategy, multi_pod=multi_pod,
                     seq_sharded_cache=(shape_name == "long_500k"))
    lm = build_lm(cfg)
    pshapes = SP.params_shapes(lm)
    pspec = plan.params_pspec_tree(pshapes)
    pshard = _tree_pspec_to_shard(mesh, pspec)
    n_params = count_params(pshapes)
    n_active = active_params(cfg, n_params, lm)

    if shape.kind == "train":
        step_kind = "train"
        batch_specs = SP.train_input_specs(cfg, shape)
        bshard = _tree_pspec_to_shard(
            mesh, SP.batch_pspec(cfg, batch_specs, plan))
        state_shapes = jax.eval_shape(
            partial(init_train_state, tcfg=tcfg), pshapes)
        # moments: same sharding as params where float; q8 states sharded flat
        mspecs = _opt_shard(mesh, plan, state_shapes, pspec)
        state_shard = type(state_shapes)(
            pshard, mspecs, NamedSharding(mesh, P()))
        step = make_train_step(lm, plan, tcfg)
        # out_shardings must match in_shardings for the state or the donated
        # buffers cannot alias (measured: deepseek-v2 outputs ballooned to
        # 114 GiB/device without this).
        jitted = jax.jit(step, in_shardings=(state_shard, bshard),
                         out_shardings=(state_shard, None),
                         donate_argnums=(0,))
        lowered = jitted.lower(state_shapes, batch_specs)
    elif shape.kind == "prefill":
        step_kind = "prefill"
        batch_specs = SP.train_input_specs(cfg, shape)
        batch_specs.pop("labels")
        bshard = _tree_pspec_to_shard(
            mesh, SP.batch_pspec(cfg, batch_specs, plan))
        step = make_prefill_step(lm, plan)
        jitted = jax.jit(step, in_shardings=(pshard, bshard))
        lowered = jitted.lower(pshapes, batch_specs)
    else:
        step_kind = "decode"
        cache_shapes, tok_spec, len_spec = SP.decode_input_specs(
            cfg, shape, lm, plan)
        cache_shard = _tree_pspec_to_shard(
            mesh, lm_cache_pspec(lm, cache_shapes, plan))
        dp = plan.dp_axes
        tok_shard = NamedSharding(
            mesh, P(dp, None) if shape.global_batch >= mesh.shape["data"]
            else P())
        step = make_serve_step(lm, plan)
        jitted = jax.jit(step, in_shardings=(pshard, cache_shard, tok_shard,
                                             NamedSharding(mesh, P())),
                         out_shardings=(None, cache_shard),
                         donate_argnums=(1,))
        lowered = jitted.lower(pshapes, cache_shapes, tok_spec, len_spec)

    compiled = lowered.compile()
    mf = R.model_flops_estimate(cfg, shape, n_active, step_kind)
    roof = R.analyze(arch, shape_name, mesh_name, step_kind, compiled,
                     mesh.size, mf, n_params)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tt": tt,
        "strategy": strategy, "step_kind": step_kind,
        "n_params": n_params, "n_active": n_active,
        "compile_s": time.time() - t0,
        **{k: v for k, v in roof.__dict__.items()
           if k not in ("arch", "shape", "mesh")},
    }
    if verbose:
        ms = result.get("memory_stats", {})
        print(f"[{arch} × {shape_name} × {mesh_name}{' ×tt' if tt else ''}] "
              f"{step_kind}: compile {result['compile_s']:.1f}s  "
              f"flops/dev {roof.hlo_flops:.3e}  bytes/dev {roof.hlo_bytes:.3e}  "
              f"coll/dev {roof.coll_bytes:.3e}")
        print(f"  terms (ms): compute {roof.compute_s*1e3:.3f}  "
              f"memory {roof.memory_s*1e3:.3f}  "
              f"collective {roof.collective_s*1e3:.3f}  "
              f"-> bottleneck: {roof.bottleneck}  useful {roof.useful_ratio:.2f}")
        if ms:
            print(f"  memory_analysis: { {k: f'{v/2**30:.2f}GiB' for k, v in ms.items()} }")
    return result


def _opt_shard(mesh, plan, state_shapes, pspec):
    """Sharding for AdamState: moments follow their param spec exactly.
    The shape-preserving q8 QTensor states use the same spec (their codes'
    last dim is a padded multiple of the param's, so the same partitioning
    applies; the per-block scale drops the last-axis sharding)."""
    from ..numerics import QTensor
    from ..optim.adam import AdamState
    pspec_leaves = jax.tree_util.tree_flatten(
        pspec, is_leaf=lambda s: isinstance(s, P))[0]

    def one(mom):
        out = []
        for m, ps in zip(mom, pspec_leaves):
            if m is None:
                out.append(None)
            elif isinstance(m, QTensor):
                parts = list(ps) + [None] * (m.codes.ndim - len(ps))
                q_parts = parts[:m.codes.ndim]
                s_parts = list(q_parts)
                # scale's last axis is nb (small) — replicate it
                if len(s_parts) >= 1:
                    s_parts[-1] = None
                # codes' last axis is a padded multiple; only shard it if
                # the padded size still divides
                if q_parts[-1] is not None:
                    ax = q_parts[-1]
                    size = mesh.shape[ax] if isinstance(ax, str) else \
                        int(np.prod([mesh.shape[a] for a in ax]))
                    if m.codes.shape[-1] % size != 0:
                        q_parts[-1] = None
                out.append(QTensor(NamedSharding(mesh, P(*q_parts)),
                                   NamedSharding(mesh, P(*s_parts)),
                                   m.spec, m.shape))
            else:
                out.append(NamedSharding(mesh, ps))
        return tuple(out)

    return AdamState(NamedSharding(mesh, P()),
                     one(state_shapes.opt.m), one(state_shapes.opt.v))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--tt", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        cells = list(C.ALL_CELLS)
    else:
        shapes = [args.shape] if args.shape else C.valid_cells(args.arch)
        cells = [(args.arch, s) for s in shapes]
    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}" + \
                ("_tt" if args.tt else "")
            try:
                res = dryrun_cell(arch, shape, multi_pod=mp, tt=args.tt)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(res, f, indent=1, default=str)
            except Exception as e:
                failures.append((tag, repr(e)))
                print(f"[FAIL {tag}] {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for t, e in failures:
            print(" ", t, e)
        raise SystemExit(1)
    print("\nall dry-run cells compiled OK")


if __name__ == "__main__":
    main()

"""Roofline-term extraction from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / ICI link bw   (per chip)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (the SPMD
program is per-device, so no further division). collective_bytes is parsed
from the optimized HLO: we sum, over every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, the larger of operand and
result byte size, doubling all-reduce (ring send+recv) — a deliberate,
documented convention good for trend tracking, not bit-exact link accounting.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1,
}

_SHAPE_RE = re.compile(r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|"
                       r"s16|u16|s8|u8|s4|u4|pred)\[([0-9,]*)\]")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-kind collective bytes from optimized (post-SPMD) HLO text."""
    out = {k: 0.0 for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = (.*?) (all-gather|all-reduce|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(-start|-done)?\(", line)
        if not m:
            continue
        kind = m.group(2)
        if m.group(3) == "-done":
            continue   # counted at -start
        result_b = _shape_bytes(m.group(1))
        # operand shapes appear in the args; take the max of result vs args
        args = line.split("(", 1)[1]
        operand_b = _shape_bytes(args)
        b = max(result_b, operand_b)
        if kind == "all-reduce":
            b *= 2.0
        out[kind] += b
    out["total"] = sum(out[k] for k in _COLL_KINDS)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    step_kind: str
    hlo_flops: float          # per device
    hlo_bytes: float          # per device
    coll_bytes: float         # per device
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float        # 6*N*D (or 6*N_active*D) GLOBAL per step
    useful_ratio: float       # model_flops / (hlo_flops * n_devices)
    coll_detail: dict
    memory_stats: dict

    def row(self) -> str:
        return (f"| {self.arch} | {self.shape} | {self.mesh} | "
                f"{self.compute_s*1e3:.2f} | {self.memory_s*1e3:.2f} | "
                f"{self.collective_s*1e3:.2f} | {self.bottleneck} | "
                f"{self.useful_ratio:.2f} |")


def analyze(arch: str, shape: str, mesh_name: str, step_kind: str,
            compiled, n_devices: int, model_flops: float,
            n_model_params: float) -> Roofline:
    """Three-term roofline from the compiled artifact.

    Primary numbers come from the loop-aware HLO walk (hlo_cost.py) because
    ``cost_analysis()`` counts while-loop bodies once (verified; see
    EXPERIMENTS.md). Raw cost_analysis values are preserved in coll_detail
    ["xla_cost_analysis"] for reference.
    """
    from .hlo_cost import hlo_costs
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    t = hlo_costs(hlo)
    flops = t.flops
    byts = t.bytes_min
    coll = dict(t.coll)
    coll["total"] = t.coll_total
    coll["bytes_op_granularity"] = t.bytes
    coll["xla_cost_analysis"] = {
        "flops": float(ca.get("flops", 0.0)),
        "bytes accessed": float(ca.get("bytes accessed", 0.0)),
    }
    coll["loops"] = t.loops[:12]
    mem_stats = {}
    try:
        ma = compiled.memory_analysis()
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes"):
            v = getattr(ma, k, None)
            if v is not None:
                mem_stats[k] = int(v)
    except Exception:
        pass
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = byts / HBM_BW
    coll_s = coll["total"] / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    useful = model_flops / max(flops * n_devices, 1.0)
    return Roofline(arch, shape, mesh_name, step_kind, flops, byts,
                    coll["total"], compute_s, memory_s, coll_s, bottleneck,
                    model_flops, useful, coll, mem_stats)


def model_flops_estimate(cfg, shape, n_params_active: float,
                         step_kind: str) -> float:
    """6·N·D for train, 2·N·D for prefill, 2·N·B for one decode token."""
    if step_kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if step_kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    return 2.0 * n_params_active * shape.global_batch   # one decode step

"""Loop-aware HLO cost accounting.

``compiled.cost_analysis()`` counts a while-loop body ONCE regardless of its
trip count (verified in this repo; see EXPERIMENTS.md §Dry-run), which makes
scan-over-layers models look ~L× cheaper than they are and silently drops
the FSDP collectives inside the layer loop. This module walks the optimized
post-SPMD HLO text and accumulates per-device costs with correct
multipliers:

- FLOPs: every ``dot`` (2 · prod(out) · contraction), the only material
  FLOP source in these models (elementwise is <1%).
- HBM traffic: for every buffer-producing op (fusion, dot, copy, slices,
  gather/scatter, reduce, collectives, ...), output bytes + operand bytes —
  i.e. fusion-boundary traffic, the TPU roofline convention (VMEM is
  explicit, every fusion streams its operands from HBM once).
- Collective bytes: per kind, ×2 for all-reduce (ring send+recv).

Loop multipliers come from ``known_trip_count`` backend configs, with a
fallback that reads the loop-bound constant out of the condition
computation. Nested loops compose multiplicatively.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 0.5, "u4": 0.5, "pred": 1, "token": 0,
}

_SHAPE_RE = re.compile(
    r"(bf16|f64|f32|f16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|"
    r"pred)\[([0-9,]*)\]")

_ASSIGN_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?.*?\)?)\s*([\w\-]+)\((.*)$")

_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(.*\{\s*$")

_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SKIP_TRAFFIC = {
    "tuple", "get-tuple-element", "parameter", "constant", "bitcast",
    "after-all", "while", "conditional", "call", "custom-call", "iota",
    "partition-id", "replica-id", "add-dependency", "opt-barrier",
    "get-dimension-size",
}


def _parse_dims(shape_text: str) -> float:
    """Total bytes of all shapes appearing in the text fragment."""
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape_dims(shape_text: str) -> tuple[str, list[int]] | None:
    m = _SHAPE_RE.search(shape_text)
    if not m:
        return None
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",") if d]


@dataclass
class Op:
    name: str
    kind: str
    out_text: str
    args_text: str


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)
    shapes: dict[str, str] = field(default_factory=dict)  # name -> out_text


def parse_module(hlo: str) -> tuple[dict[str, Computation], str]:
    comps: dict[str, Computation] = {}
    entry = None
    cur: Computation | None = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr and line.rstrip().endswith("{"):
            cur = Computation(hdr.group(1))
            comps[cur.name] = cur
            if line.lstrip().startswith("ENTRY"):
                entry = cur.name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _ASSIGN_RE.match(line)
        if not m:
            continue
        name, out_text, kind, rest = m.groups()
        cur.ops.append(Op(name, kind, out_text, rest))
        cur.shapes[name] = out_text
    if entry is None:  # pragma: no cover
        raise ValueError("no ENTRY computation found")
    return comps, entry


def _trip_count(op: Op, comps: dict[str, Computation]) -> int:
    m = re.search(r'known_trip_count[^0-9]*(\d+)', op.args_text)
    if m:
        return int(m.group(1))
    # fallback: largest s32 constant in the condition computation
    m = re.search(r"condition=%([\w.\-]+)", op.args_text)
    if m and m.group(1) in comps:
        best = 1
        for o in comps[m.group(1)].ops:
            if o.kind == "constant":
                c = re.search(r"constant\((\d+)\)", "constant(" + o.args_text)
                if c:
                    best = max(best, int(c.group(1)))
        return best
    return 1


def _dot_flops(op: Op, comp: Computation) -> float:
    out = _first_shape_dims(op.out_text)
    if out is None:
        return 0.0
    _, odims = out
    out_n = 1
    for d in odims:
        out_n *= d
    # contraction size from lhs operand shape + lhs_contracting_dims
    args = op.args_text
    ops_m = _OPERAND_RE.findall(args.split(")", 1)[0])
    contract = 1
    cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", args)
    if ops_m and cd and ops_m[0] in comp.shapes:
        lhs = _first_shape_dims(comp.shapes[ops_m[0]])
        if lhs:
            for idx in cd.group(1).split(","):
                if idx:
                    i = int(idx)
                    if i < len(lhs[1]):
                        contract *= lhs[1][i]
    return 2.0 * out_n * contract


_MOVE_OPS = {
    "dot", "copy", "dynamic-slice", "dynamic-update-slice", "gather",
    "scatter", "concatenate", "pad", "transpose", "reduce", "reverse",
    "convolution", "sort", "reduce-window", "select-and-scatter",
} | set(COLLECTIVES) | {k + "-start" for k in COLLECTIVES}


@dataclass
class CostTotals:
    """bytes      — op-granularity traffic (CPU-HLO fusion boundaries):
                    upper bound for a TPU program.
       bytes_min  — dots/collectives/data-movement only, assuming perfect
                    elementwise fusion: lower bound, closest to a
                    well-optimized TPU program. The roofline memory term
                    uses bytes_min; both are recorded."""
    flops: float = 0.0
    bytes: float = 0.0
    bytes_min: float = 0.0
    coll: dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in COLLECTIVES})
    loops: list[tuple[str, int]] = field(default_factory=list)
    top_ops: list[tuple[float, str, str]] = field(default_factory=list)
    by_kind: dict[str, float] = field(default_factory=dict)

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


def _walk(comp_name: str, mult: float, comps: dict[str, Computation],
          totals: CostTotals, seen_stack: tuple = ()):
    if comp_name not in comps or comp_name in seen_stack:
        return
    comp = comps[comp_name]
    for op in comp.ops:
        if op.kind == "dot":
            totals.flops += mult * _dot_flops(op, comp)
        if op.kind in COLLECTIVES or any(
                op.kind == k + "-start" for k in COLLECTIVES):
            kind = op.kind.replace("-start", "")
            out_b = _parse_dims(op.out_text)
            arg_names = _OPERAND_RE.findall(op.args_text.split(")", 1)[0])
            in_b = sum(_parse_dims(comp.shapes.get(a, ""))
                       for a in arg_names)
            b = max(out_b, in_b)
            if kind == "all-reduce":
                b *= 2.0
            totals.coll[kind] += mult * b
        if op.kind.endswith("-done"):
            continue
        if op.kind not in _SKIP_TRAFFIC:
            out_b = _parse_dims(op.out_text)
            arg_names = _OPERAND_RE.findall(op.args_text.split(")", 1)[0])
            in_b = sum(_parse_dims(comp.shapes.get(a, ""))
                       for a in arg_names)
            # in-place slice ops move only the slice, not the carrier buffer
            if op.kind == "dynamic-slice":
                traffic = 2.0 * out_b
            elif op.kind == "dynamic-update-slice":
                upd = (_parse_dims(comp.shapes.get(arg_names[1], ""))
                       if len(arg_names) > 1 else out_b)
                traffic = 2.0 * upd
            else:
                traffic = out_b + in_b
            totals.bytes += mult * traffic
            if op.kind in _MOVE_OPS:
                b = mult * traffic
                totals.bytes_min += b
                totals.by_kind[op.kind] = totals.by_kind.get(op.kind, 0.0) + b
                if b > 1e9:
                    totals.top_ops.append((b, op.kind, op.name))
        # recurse into called computations
        if op.kind == "while":
            n = _trip_count(op, comps)
            body = re.search(r"body=%([\w.\-]+)", op.args_text)
            if body:
                totals.loops.append((body.group(1), n))
                _walk(body.group(1), mult * n, comps, totals,
                      seen_stack + (comp_name,))
            cond = re.search(r"condition=%([\w.\-]+)", op.args_text)
            if cond:
                _walk(cond.group(1), mult * n, comps, totals,
                      seen_stack + (comp_name,))
        elif op.kind in ("fusion", "call", "map", "reduce", "reduce-window",
                         "sort", "scatter", "select-and-scatter", "custom-call"):
            for m in re.finditer(r"(?:calls|to_apply)=%([\w.\-]+)",
                                 op.args_text):
                _walk(m.group(1), mult, comps, totals,
                      seen_stack + (comp_name,))
        elif op.kind == "conditional":
            for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                 r"(?:true|false)_computation=%([\w.\-]+))",
                                 op.args_text):
                blob = m.group(1) or m.group(2) or ""
                for c in _OPERAND_RE.findall("%" + blob.replace("%", " %")):
                    _walk(c, mult, comps, totals, seen_stack + (comp_name,))


def hlo_costs(hlo_text: str) -> CostTotals:
    comps, entry = parse_module(hlo_text)
    totals = CostTotals()
    _walk(entry, 1.0, comps, totals)
    return totals

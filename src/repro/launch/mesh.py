"""Production mesh definitions.

Defined as FUNCTIONS (not module-level constants) so importing this module
never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import and only then calls these.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_dev_mesh(data: int = 1, model: int = 1):
    """Small mesh for tests (requires device count >= data*model)."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_dp_mesh(data: int = 0):
    """1-D data-parallel mesh (``("data",)``) over ``data`` devices (0 =>
    all local devices) — the dp-only mesh ``steps.make_dp_train_step``
    expects (no ``model`` axis at all; the plan's activation/param helpers
    fall back to replication for the absent axis)."""
    return jax.make_mesh((data or len(jax.devices()),), ("data",))


# TPU v5e hardware constants (roofline denominators)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~per-direction)
VMEM_BYTES = 16 * 2 ** 20     # ~16 MiB usable
HBM_BYTES = 16 * 2 ** 30      # v5e: 16 GiB HBM

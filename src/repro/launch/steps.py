"""Train / serve step factories — the functions the dry-run lowers and the
real launcher runs. One code path for every arch in the zoo."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import TrainConfig
from ..models.lm import (LMDef, lm_decode_step, lm_forward, lm_lambda_update,
                         lm_prior_loss)
from ..numerics import (NumericsPolicy, fake_quant,
                        per_tensor_max_scale_log2)
from ..optim import (AdamState, adam_update, clip_by_global_norm, init_adam,
                     lr_at)
from ..sharding import ShardPlan


class TrainState(NamedTuple):
    params: Any
    opt: AdamState
    step: jax.Array
    residual: Any = None     # grad-compression error feedback (optional)
    scales: Any = None       # NumericsPolicy managed scale-state tree
                             # ({site: ScaleState}, optional)


def init_train_state(params, tcfg: TrainConfig,
                     policy: NumericsPolicy | None = None) -> TrainState:
    residual = None
    if tcfg.grad_compress:
        residual = tuple(
            jnp.zeros(p.shape, jnp.float32)
            if jnp.issubdtype(p.dtype, jnp.floating) else None
            for p in jax.tree_util.tree_leaves(params))
    scales = None
    if policy is not None and policy.enable:
        scales = policy.init_scales()
    return TrainState(params, init_adam(params, tcfg),
                      jnp.zeros((), jnp.int32), residual, scales)


def train_state_sites(state: TrainState) -> dict[str, dict]:
    """Byte accounting of one concrete TrainState, keyed by ``obs.ledger``
    site: params, int8 Adam moments, grad-wire error-feedback residual,
    managed scale state.  Host-side only (reads ``.nbytes`` off concrete
    arrays — never call inside a jitted body).

    The fp32 shadow here is elementwise — what the *same tensors* would
    cost in f32.  The paper's Table-1 dense baseline (dense weights vs TT
    factors) is a modelling choice the benches supply per-site instead."""
    from ..optim.adam import moment_nbytes
    from ..optim.grad_compress import residual_nbytes
    p_res = p_fp32 = 0
    for leaf in jax.tree_util.tree_leaves(state.params):
        p_res += int(leaf.nbytes)
        p_fp32 += 4 * int(leaf.size)
    m_res, m_fp32 = moment_nbytes(state.opt)
    out = {
        "params": {"bytes": p_res, "fp32_bytes": p_fp32},
        "optimizer_moment": {"bytes": m_res, "fp32_bytes": m_fp32},
    }
    r = residual_nbytes(state.residual)
    if r:
        out["grad_residual"] = {"bytes": r, "fp32_bytes": r}
    if state.scales is not None:
        s = sum(int(l.nbytes)
                for l in jax.tree_util.tree_leaves(state.scales))
        out["scale_state"] = {"bytes": s, "fp32_bytes": s}
    return out


def _quantize_grad_edge(grads, scales, policy: NumericsPolicy):
    """The ``grad_edge`` site at the step level: round the weight-gradient
    tree onto the grad_bits pow-2 grid (paper: 16-bit gradients).

    Each gradient leaf is its own tensor-site, so each gets a
    per-tensor-max scale — the grid always covers max|g| and rounding is
    clip-free (a pooled scale would persistently clip large-magnitude
    leaves such as embedding/norm grads). The policy's managed
    ``grad_edge`` ScaleState still advances on the tree-wide magnitude:
    it is the §3.3 statistic the activation-gradient edges
    (``core.quant.quant_edge``) share."""
    if scales is None or "grad_edge" not in scales:
        return grads, scales
    spec = policy.spec_for("grad_edge")

    def is_f(g):
        return hasattr(g, "dtype") and g.dtype != jax.dtypes.float0 \
            and jnp.issubdtype(g.dtype, jnp.floating)

    def q(g):
        if not is_f(g):
            return g
        step = per_tensor_max_scale_log2(g, spec)
        return fake_quant(g, spec, step)

    gq = jax.tree.map(q, grads)
    leaves = [g for g in jax.tree_util.tree_leaves(grads) if is_f(g)]
    tot = sum(jnp.sum(jnp.abs(g.astype(jnp.float32))) for g in leaves)
    cnt = sum(g.size for g in leaves)
    gm = (tot / jnp.maximum(cnt, 1))[None]
    return gq, policy.update_scales(scales, {"grad_edge": gm})


def _train_health(grads, scales, policy: NumericsPolicy) -> dict:
    """Per-site quant-health aggregates of one train step (repro.obs).

    Traced only when ``policy.health`` is on — the default step's jaxpr is
    byte-identical to a health-free build (Python gate, no dead device
    code). ``grads`` is the tree entering the grad_edge quantizer:
    ``sat_fraction`` counts codes pinned at the 16-bit grid edge under the
    per-tensor-max scales the quantizer itself uses (clip-free by
    construction, so saturation here means values AT max|g|). Managed-site
    ScaleStates report their §3.3 statistic and whether it sits inside the
    policy's target band."""
    from ..obs.counters import fraction, tree_sat_stats
    sat, tot = tree_sat_stats(grads, policy.spec_for("grad_edge"))
    health = {"grad_edge": {"sat_fraction": fraction(sat, tot),
                            "saturated": sat, "total": tot}}
    for site, st in scales.items():
        health.setdefault(site, {})
        health[site]["scale_log2"] = st.log2.astype(jnp.float32)
        health[site]["mean_abs"] = st.mean_abs
        health[site]["in_band"] = jnp.asarray(
            (st.mean_abs >= policy.target_lo)
            & (st.mean_abs <= policy.target_hi), jnp.float32)
    return health


def _ce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over positions with label >= 0."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0).astype(jnp.float32)
    lab = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    ce = (logz - gold) * mask
    return jnp.sum(ce) / jnp.maximum(jnp.sum(mask), 1.0)


def make_loss_fn(lm: LMDef, plan: ShardPlan, tcfg: TrainConfig):
    """Loss over one batch. ``loss_fn(params, batch, scales=None)``: with a
    managed scale-state tree (``TrainState.scales``) the forward runs the
    policy's ``activation`` quant edges and the aux output carries the
    observed activation statistic alongside the metrics:
    ``loss, (metrics, obs) = loss_fn(...)``."""
    cfg = lm.cfg

    def loss_fn(params, batch, scales=None):
        kwargs = {}
        if cfg.frontend == "audio":
            kwargs["embeds"] = batch["frames"]
        elif cfg.frontend == "vision":
            kwargs["embeds"] = batch["patches"]
            kwargs["tokens"] = batch["tokens"]
        else:
            kwargs["tokens"] = batch["tokens"]
        if scales is not None:
            logits, aux, _, obs = lm_forward(params, lm, plan,
                                             scales=scales, **kwargs)
        else:
            logits, aux, _ = lm_forward(params, lm, plan, **kwargs)
            obs = {}
        labels = batch["labels"]
        if cfg.frontend == "vision":
            # loss on the text positions only (the last len(labels) positions)
            logits = logits[:, -labels.shape[1]:]
        ce = _ce_loss(logits, labels)
        loss = ce + cfg.moe.router_aux_coef * aux
        prior = jnp.zeros((), jnp.float32)
        if cfg.tt.enable and cfg.tt.rank_adapt:
            # Eq. (1): CE mean + prior; prior scaled per-token so its
            # gradient pressure is batch-size independent.
            denom = float(labels.shape[0] * labels.shape[1]) * tcfg.total_steps
            prior = lm_prior_loss(params, lm) / denom
        metrics = {"ce": ce, "aux": aux, "prior": prior}
        return loss + prior, (metrics, obs)

    return loss_fn


def make_train_step(lm: LMDef, plan: ShardPlan, tcfg: TrainConfig):
    loss_fn = make_loss_fn(lm, plan, tcfg)
    policy = lm.cfg.quant.policy()

    def train_step(state: TrainState, batch):
        (loss, (metrics, obs)), grads = jax.value_and_grad(
            loss_fn, has_aux=True, allow_int=True)(state.params, batch,
                                                   state.scales)
        scales = state.scales
        if scales is not None and obs:
            # §3.3 activation scale manager: advance on the forward's
            # observed mean |activation| (lm_forward's ``activation`` edges)
            scales = policy.update_scales(scales, obs)
        residual = state.residual
        if tcfg.grad_compress:
            # int8-valued grads + error feedback BEFORE the DP reduce:
            # the all-reduce then moves 1/4 the wire bytes — the ``dp_wire``
            # site of the numerics policy (optim/grad_compress); on real
            # meshes ``grad_compress.psum_int8`` is the shard_map collective
            # that puts the int8 codes themselves on the wire
            from ..optim.grad_compress import compress_decompress
            grads, residual = compress_decompress(
                grads, residual, policy.spec_for("dp_wire"))
        # pre-quant grads held only when health tracing is on (Python gate:
        # the default step's jaxpr carries no health ops at all)
        want_health = policy.health and policy.enable and scales is not None
        pre_edge = grads if want_health else None
        grads, scales = _quantize_grad_edge(grads, scales, policy)
        if tcfg.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        lr = lr_at(state.step, tcfg)
        params, opt = adam_update(state.params, grads, state.opt, lr, tcfg)
        # closed-form Eq.(4) rank-hyperparameter update (no-op if TT off)
        params = lm_lambda_update(params, lm)
        metrics = dict(metrics, loss=loss, gnorm=gnorm, lr=lr)
        if want_health:
            metrics["health"] = _train_health(pre_edge, scales, policy)
        return TrainState(params, opt, state.step + 1, residual,
                          scales), metrics

    return train_step


def init_dp_train_state(params, tcfg: TrainConfig, plan: ShardPlan,
                        policy: NumericsPolicy | None = None) -> TrainState:
    """TrainState for ``make_dp_train_step``: residual leaves carry a
    leading ``(dp_size,)`` replica axis — each data-parallel replica keeps
    its own error-feedback residual (it quantized its own local gradient),
    while params/opt/scales stay replicated (the wire's summed codes are
    bitwise identical on every replica)."""
    st = init_train_state(params, tcfg, policy)
    if st.residual is not None:
        n = plan.dp_size()
        st = st._replace(residual=tuple(
            None if r is None else jnp.zeros((n,) + r.shape, r.dtype)
            for r in st.residual))
    return st


def make_dp_train_step(lm: LMDef, plan: ShardPlan, tcfg: TrainConfig):
    """Data-parallel ``shard_map`` train step whose ONLY payload-sized
    collective is the int8 gradient wire (``optim.grad_compress.psum_int8``,
    PR 5) — the explicit-collective realization of the paper's low-precision
    training story at the cluster level.

    The plan's mesh must be dp-only (every axis in ``plan.dp_axes`` — e.g.
    the 1-D ``("data",)`` mesh): inside the body each replica holds the full
    (replicated) params and its batch shard, runs the mesh-less forward/
    backward locally, and reduces gradients through ``psum_int8_tree`` —
    blockwise pmax scales (payload/1024 f32 elements) + int8 codes on an
    ``all_gather``, summed in a widened int32 accumulator. Everything after
    the wire (grad_edge quantizer, clipping, adam, lambda update) is local
    arithmetic on bitwise-replicated values, so no f32 gradient, parameter,
    or optimizer tensor ever crosses a collective; the only other
    collectives are scalar ``pmean``s of loss/metrics/activation stats.
    tests/test_distributed.py walks the jaxpr and asserts exactly this.

    State convention: ``init_dp_train_state`` (residual leaves lead with a
    ``(dp_size,)`` replica axis, sharded over the dp axes; everything else
    replicated). Batch leaves shard their leading (batch) dim.

    Numerics contract vs ``make_train_step`` (the mesh-less path): identical
    forward/backward math; the wire replaces ``compress_decompress`` — same
    blockwise int8 grid, with the block scale chosen by cross-replica pmax
    instead of locally, i.e. exactly the PR 5 ``psum_int8`` semantics the
    ``wire`` test pins bitwise.
    """
    if plan.mesh is None:
        raise ValueError("make_dp_train_step needs a plan with a real mesh")
    extra = [a for a in plan.mesh.shape if a not in plan.dp_axes]
    if extra:
        raise ValueError(
            f"make_dp_train_step is dp-only: mesh axes {extra} are not in "
            f"dp_axes {plan.dp_axes} (use make_train_step's GSPMD path for "
            f"tensor/context parallelism)")
    if not tcfg.grad_compress:
        raise ValueError("the dp shard_map step IS the int8 wire — "
                         "enable tcfg.grad_compress")
    from ..optim.grad_compress import psum_int8_tree
    from ..sharding import compat_shard_map
    # the body sees per-replica local shards: the model runs mesh-less
    # (a with_sharding_constraint cannot reference manual mesh axes)
    loss_fn = make_loss_fn(lm, ShardPlan(mesh=None), tcfg)
    policy = lm.cfg.quant.policy()
    axis = plan.dp_axis()
    ndev = plan.dp_size()
    wire_spec = policy.spec_for("dp_wire")

    def is_f(g):
        return hasattr(g, "dtype") and g.dtype != jax.dtypes.float0 \
            and jnp.issubdtype(g.dtype, jnp.floating)

    def local_step(state: TrainState, batch):
        res_local = None if state.residual is None else tuple(
            None if r is None else r[0] for r in state.residual)
        (loss, (metrics, obs)), grads = jax.value_and_grad(
            loss_fn, has_aux=True, allow_int=True)(state.params, batch,
                                                   state.scales)
        # scalar cross-replica means — bytes on the wire: a handful of f32s
        loss = jax.lax.pmean(loss, axis)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)
        scales = state.scales
        if scales is not None and obs:
            obs = jax.tree.map(lambda o: jax.lax.pmean(o, axis), obs)
            scales = policy.update_scales(scales, obs)
        # THE payload collective: int8 codes + pmax block scales
        summed, new_res = psum_int8_tree(grads, res_local, axis, wire_spec)
        grads = jax.tree.map(lambda g: g / ndev if is_f(g) else g, summed)
        want_health = policy.health and policy.enable and scales is not None
        pre_edge = grads if want_health else None
        grads, scales = _quantize_grad_edge(grads, scales, policy)
        if tcfg.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        lr = lr_at(state.step, tcfg)
        params, opt = adam_update(state.params, grads, state.opt, lr, tcfg)
        params = lm_lambda_update(params, lm)
        metrics = dict(metrics, loss=loss, gnorm=gnorm, lr=lr)
        if want_health:
            metrics["health"] = _train_health(pre_edge, scales, policy)
        residual = None if new_res is None else tuple(
            None if r is None else r[None] for r in new_res)
        return TrainState(params, opt, state.step + 1, residual,
                          scales), metrics

    from jax.sharding import PartitionSpec as P
    dp = P(plan.dp_axes)
    state_specs = TrainState(params=P(), opt=P(), step=P(),
                             residual=dp, scales=P())

    def train_step(state: TrainState, batch):
        batch_specs = jax.tree.map(
            lambda b: P(plan.dp_axes, *([None] * (jnp.ndim(b) - 1))), batch)
        f = compat_shard_map(local_step, plan.mesh,
                             in_specs=(state_specs, batch_specs),
                             out_specs=(state_specs, P()))
        return f(state, batch)

    return train_step


def make_grad_accum_train_step(lm: LMDef, plan: ShardPlan, tcfg: TrainConfig,
                               n_micro: int):
    """Gradient-accumulation variant: batch leading dim = n_micro.

    Numerics contract: identical to ``make_train_step`` after the gradient
    average — compression/error-feedback, the grad_edge quantizer, and
    clipping all apply to the accumulated mean gradient, and the residual /
    scale trees are carried exactly as in the non-accum step (asserted by
    tests/test_numerics.py)."""
    loss_fn = make_loss_fn(lm, plan, tcfg)
    policy = lm.cfg.quant.policy()

    def train_step(state: TrainState, batch):
        def micro(carry, mb):
            gsum, lsum, osum = carry
            (loss, (_, obs)), g = jax.value_and_grad(
                loss_fn, has_aux=True, allow_int=True)(state.params, mb,
                                                       state.scales)
            gsum = jax.tree.map(
                lambda a, b: a + b if hasattr(b, "dtype")
                and b.dtype != jax.dtypes.float0 else a, gsum, g)
            if "activation" in obs:
                osum = osum + obs["activation"]
            return (gsum, lsum + loss, osum), None

        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32)
            if jnp.issubdtype(p.dtype, jnp.floating) else
            jnp.zeros((), jnp.float32), state.params)
        (gsum, lsum, osum), _ = jax.lax.scan(
            micro, (zeros, jnp.zeros(()), jnp.zeros((1,))), batch)
        grads = jax.tree.map(lambda g: g / n_micro, gsum)
        scales = state.scales
        if scales is not None and "activation" in scales \
                and lm.cfg.quant.enable:
            scales = policy.update_scales(
                scales, {"activation": osum / n_micro})
        residual = state.residual
        if tcfg.grad_compress:
            from ..optim.grad_compress import compress_decompress
            grads, residual = compress_decompress(
                grads, residual, policy.spec_for("dp_wire"))
        want_health = policy.health and policy.enable and scales is not None
        pre_edge = grads if want_health else None
        grads, scales = _quantize_grad_edge(grads, scales, policy)
        if tcfg.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, tcfg.grad_clip)
        else:
            gnorm = jnp.zeros((), jnp.float32)
        lr = lr_at(state.step, tcfg)
        params, opt = adam_update(state.params, grads, state.opt, lr, tcfg)
        params = lm_lambda_update(params, lm)
        metrics = {"loss": lsum / n_micro, "gnorm": gnorm, "lr": lr}
        if want_health:
            metrics["health"] = _train_health(pre_edge, scales, policy)
        return TrainState(params, opt, state.step + 1, residual,
                          scales), metrics

    return train_step


def make_prefill_step(lm: LMDef, plan: ShardPlan):
    cfg = lm.cfg

    def prefill(params, batch):
        kwargs = {}
        if cfg.frontend == "audio":
            kwargs["embeds"] = batch["frames"]
        elif cfg.frontend == "vision":
            kwargs["embeds"] = batch["patches"]
            kwargs["tokens"] = batch["tokens"]
        else:
            kwargs["tokens"] = batch["tokens"]
        logits, _, cache = lm_forward(params, lm, plan, return_cache=True,
                                      **kwargs)
        return logits[:, -1:], cache

    return prefill


def make_serve_step(lm: LMDef, plan: ShardPlan):
    """Decode step. ``cur_len``: scalar shared position, or a per-slot (B,)
    vector — one compiled step then decodes a batch of requests at
    *different* positions (the continuous-batching primitive; the decode
    paths in models/attention.py scatter each row at its own length and
    mask per-row)."""

    def serve_step(params, cache, tokens, cur_len):
        return lm_decode_step(params, cache, tokens,
                              jnp.asarray(cur_len, jnp.int32), lm, plan)

    return serve_step

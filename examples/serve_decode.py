"""Serving example on the continuous-batching engine (repro.serve): a
stream of variable-length requests is packed into a fixed-slot batch with a
slot-paged, optionally int8-quantized KV-cache pool — and, for SSM/hybrid
archs, a slot-indexed quantized recurrent-state cache (attention sublayers
hit the KV pool, SSM/RWKV sublayers hit the state cache; one engine serves
every decoder family in the zoo):

    PYTHONPATH=src python examples/serve_decode.py --arch internlm2-1.8b
    PYTHONPATH=src python examples/serve_decode.py --arch internlm2-1.8b --quantized
    PYTHONPATH=src python examples/serve_decode.py --arch deepseek-v2-236b --temperature 0.8
    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-1.6b --quantized
    PYTHONPATH=src python examples/serve_decode.py --arch jamba-1.5-large
"""
import argparse
import json
import time

import numpy as np
import jax

import repro.configs as C
from repro.models import build_lm, init_lm
from repro.serve import (Engine, EngineConfig, PoolConfig, SamplingParams)
from repro.sharding import ShardPlan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--quantized", action="store_true",
                    help="int8 pow-2 KV-cache pool + recurrent-state cache "
                         "(fp storage otherwise)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--prefill-chunk", type=int, default=0)
    ap.add_argument("--fused", action="store_true",
                    help="fused paged-attention decode (per-page in-kernel "
                         "dequant; MLA sublayers fall back to gather)")
    args = ap.parse_args()

    cfg = C.get_reduced(args.arch).replace(dtype="float32", remat="none")
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only — no decode path")
    if cfg.frontend != "none":
        raise SystemExit(f"{args.arch}: frontend (vision/audio) serving is "
                         f"an open roadmap item")
    plan = ShardPlan(mesh=None)
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)

    horizon = args.prompt_len + args.gen_len
    pcfg = PoolConfig(
        num_slots=args.slots, page_size=args.page_size,
        pages_per_slot=-(-horizon // args.page_size) + 1,
        quantized=args.quantized)
    eng = Engine(lm, params,
                 EngineConfig(pool=pcfg, prefill_chunk=args.prefill_chunk,
                              fused_attention=args.fused),
                 plan)
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p)

    rng = np.random.RandomState(1)
    rids = []
    for i in range(args.requests):
        # variable-length prompts: 1/2..1x of --prompt-len
        plen = int(rng.randint(max(args.prompt_len // 2, 1),
                               args.prompt_len + 1))
        prompt = rng.randint(0, cfg.vocab_size, plen).tolist()
        rids.append(eng.submit(prompt, max_new_tokens=args.gen_len,
                               sampling=sp))

    t0 = time.time()
    results = eng.run()
    dt = time.time() - t0
    s = eng.summary()
    mode = "int8" if args.quantized else "fp"
    # only report the pools this arch actually allocates: pure-SSM archs
    # have no KV pool (and run unpaged), attn-only archs no state cache
    pools = []
    if s["cache_bytes"]:
        pools.append(f"kv cache {s['cache_bytes']/1024:.0f} KiB "
                     f"({s['cache_reduction']:.1f}x vs fp32)")
    if s["state_bytes"]:
        pools.append(f"state cache {s['state_bytes']/1024:.0f} KiB "
                     f"({s['state_reduction']:.1f}x vs fp32)")
    label = f"{mode}-paged" if s["cache_bytes"] else f"{mode}-state"
    print(f"served {s['requests_completed']} requests "
          f"({s['generated_tokens']} tokens) on {args.slots} slots "
          f"[{label}] in {dt:.2f}s — {s['tokens_per_s']:.0f} tok/s, "
          f"ttft p50 {s['ttft_p50_s']*1e3:.0f}ms, "
          + ", ".join(pools))
    print("sample:", results[rids[0]].tokens[:16])
    print(json.dumps(s, indent=2))


if __name__ == "__main__":
    main()

"""Serving example: batched prefill + autoregressive decode with a KV cache
on a reduced config of any zoo arch (GQA / MLA / RWKV / hybrid all work —
the cache type adapts automatically).

    PYTHONPATH=src python examples/serve_decode.py --arch internlm2-1.8b
    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-1.6b
"""
import argparse
import time

import jax
import jax.numpy as jnp

import repro.configs as C
from repro.models import build_lm, init_lm, lm_decode_step, lm_init_cache
from repro.launch.steps import make_prefill_step
from repro.sharding import ShardPlan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()

    cfg = C.get_reduced(args.arch).replace(dtype="float32", remat="none")
    plan = ShardPlan(mesh=None)
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    b, p, g = args.batch, args.prompt_len, args.gen_len

    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, p), 0,
                                cfg.vocab_size)
    total = p + g

    # prefill: one forward pass builds the cache for every request
    prefill = jax.jit(make_prefill_step(lm, plan))
    t0 = time.time()
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only — no decode path")
    logits, cache = prefill(params, {"tokens": prompt})
    # pad caches out to the full horizon for attention archs
    def pad_seq(a):
        if a.ndim >= 3 and a.shape[2] == p:   # (L, B, S, ...)
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, g)
            return jnp.pad(a, pad)
        return a
    cache = jax.tree.map(pad_seq, cache)
    print(f"prefill {b}x{p} in {time.time()-t0:.2f}s")

    step = jax.jit(lambda pr, c, t, l: lm_decode_step(pr, c, t, l, lm, plan))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(g - 1):
        logits, cache = step(params, cache, tok, jnp.int32(p + i))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {b}x{g-1} tokens in {dt:.2f}s "
          f"({b*(g-1)/max(dt,1e-9):.0f} tok/s greedy)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()

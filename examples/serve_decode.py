"""Serving example on the continuous-batching engine (repro.serve): a
stream of variable-length requests is packed into a fixed-slot batch with a
slot-paged, optionally int8-quantized KV-cache pool.

    PYTHONPATH=src python examples/serve_decode.py --arch internlm2-1.8b
    PYTHONPATH=src python examples/serve_decode.py --arch internlm2-1.8b --quantized
    PYTHONPATH=src python examples/serve_decode.py --arch deepseek-v2-236b --temperature 0.8

SSM / hybrid archs (rwkv6, jamba) fall back to the legacy static-batch
greedy loop (recurrent-state serving is an open roadmap item):

    PYTHONPATH=src python examples/serve_decode.py --arch rwkv6-1.6b
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as C
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import build_lm, init_lm
from repro.serve import (Engine, EngineConfig, PoolConfig, SamplingParams)
from repro.sharding import ShardPlan


def static_fallback(cfg, lm, params, plan, args):
    """Legacy single-batch greedy loop (kept for SSM/hybrid archs)."""
    b, p, g = args.requests, args.prompt_len, args.gen_len
    prompt = jax.random.randint(jax.random.PRNGKey(1), (b, p), 0,
                                cfg.vocab_size)
    prefill = jax.jit(make_prefill_step(lm, plan))
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompt})

    def pad_seq(a):
        if a.ndim >= 3 and a.shape[2] == p:   # (L, B, S, ...)
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, g)
            return jnp.pad(a, pad)
        return a

    cache = jax.tree.map(pad_seq, cache)
    print(f"prefill {b}x{p} in {time.time()-t0:.2f}s")
    step = jax.jit(make_serve_step(lm, plan))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(g - 1):
        logits, cache = step(params, cache, tok, jnp.int32(p + i))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(tok)
    dt = time.time() - t0
    gen = jnp.concatenate(out, axis=1)
    print(f"decoded {b}x{g-1} tokens in {dt:.2f}s "
          f"({b*(g-1)/max(dt,1e-9):.0f} tok/s greedy)")
    print("sample:", gen[0, :16].tolist())


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--quantized", action="store_true",
                    help="int8 pow-2 KV-cache pool (fp storage otherwise)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--top-p", type=float, default=1.0)
    ap.add_argument("--prefill-chunk", type=int, default=0)
    ap.add_argument("--fused", action="store_true",
                    help="fused paged-attention decode (per-page in-kernel "
                         "dequant; MLA sublayers fall back to gather)")
    args = ap.parse_args()

    cfg = C.get_reduced(args.arch).replace(dtype="float32", remat="none")
    if cfg.is_encoder:
        raise SystemExit(f"{args.arch} is encoder-only — no decode path")
    plan = ShardPlan(mesh=None)
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)

    attn_only = all(s.mixer_kind in ("attn_gqa", "attn_mla")
                    for s in lm.period)
    if not attn_only or cfg.frontend != "none":
        print(f"{args.arch}: recurrent/frontend arch — using the static "
              f"fallback loop (engine support is an open roadmap item)")
        return static_fallback(cfg, lm, params, plan, args)

    horizon = args.prompt_len + args.gen_len
    pcfg = PoolConfig(
        num_slots=args.slots, page_size=args.page_size,
        pages_per_slot=-(-horizon // args.page_size) + 1,
        quantized=args.quantized)
    eng = Engine(lm, params,
                 EngineConfig(pool=pcfg, prefill_chunk=args.prefill_chunk,
                              fused_attention=args.fused),
                 plan)
    sp = SamplingParams(temperature=args.temperature, top_k=args.top_k,
                        top_p=args.top_p)

    rng = np.random.RandomState(1)
    rids = []
    for i in range(args.requests):
        # variable-length prompts: 1/2..1x of --prompt-len
        plen = int(rng.randint(max(args.prompt_len // 2, 1),
                               args.prompt_len + 1))
        prompt = rng.randint(0, cfg.vocab_size, plen).tolist()
        rids.append(eng.submit(prompt, max_new_tokens=args.gen_len,
                               sampling=sp))

    t0 = time.time()
    results = eng.run()
    dt = time.time() - t0
    s = eng.summary()
    mode = "int8-paged" if args.quantized else "fp-paged"
    print(f"served {s['requests_completed']} requests "
          f"({s['generated_tokens']} tokens) on {args.slots} slots "
          f"[{mode}] in {dt:.2f}s — {s['tokens_per_s']:.0f} tok/s, "
          f"ttft p50 {s['ttft_p50_s']*1e3:.0f}ms, "
          f"cache {s['cache_bytes']/1024:.0f} KiB "
          f"({s['cache_reduction']:.1f}x vs fp32)")
    print("sample:", results[rids[0]].tokens[:16])
    print(json.dumps(s, indent=2))


if __name__ == "__main__":
    main()

"""The paper's experiment end-to-end (Appendix B): two-layer tensorized MLP,
rank-adaptive prior, 4/8/16-bit quantized training with automatic scale
selection, BinaryConnect — on the synthetic FashionMNIST drop-in.

Prints the Table-1 row for the proposed method.

    PYTHONPATH=src python examples/train_fmnist_tt.py [--steps 600]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.data import fashion_like
from repro.models import mlp_tt as MLP
from repro.optim import adam as A
from repro.optim.binaryconnect import quantize_for_deploy


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--no-prior", action="store_true")
    ap.add_argument("--no-quant", action="store_true")
    args = ap.parse_args()

    d = MLP.make_mlp(prior=not args.no_prior, quantize=not args.no_quant)
    params = MLP.init_mlp(jax.random.PRNGKey(0), d)
    tcfg = TrainConfig(learning_rate=3e-3, weight_decay=0.0)
    opt = A.init_adam(params, tcfg)
    xs, ys = fashion_like(8192, seed=1)
    xt, yt = fashion_like(2048, seed=2)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(MLP.mlp_loss, allow_int=True)(
            params, batch, d)
        params, opt = A.adam_update(params, grads, opt,
                                    jnp.asarray(3e-3), tcfg)
        if d.tt.rank_adapt:
            params = MLP.mlp_lambda_update(params, d)       # Eq. (4)
        if d.qc.enable:
            params = MLP.mlp_scale_update(params, batch, grads, d)  # §3.3
        return params, opt, loss

    bsz, t0 = 64, time.time()
    for i in range(args.steps):
        lo = (i * bsz) % (len(ys) - bsz)
        batch = {"x": jnp.asarray(xs[lo:lo + bsz]),
                 "y": jnp.asarray(ys[lo:lo + bsz])}
        params, opt, loss = step(params, opt, batch)
        if i % 100 == 0:
            logits = MLP.mlp_forward(params, jnp.asarray(xt), d)
            acc = float((jnp.argmax(logits, -1) == jnp.asarray(yt)).mean())
            print(f"step {i:4d}  loss {float(loss):.4f}  test acc {acc:.3f}")

    dt = (time.time() - t0) / args.steps
    logits = MLP.mlp_forward(params, jnp.asarray(xt), d)
    acc = float((jnp.argmax(logits, -1) == jnp.asarray(yt)).mean())
    if d.tt.rank_adapt:
        eff1, eff2 = MLP.effective_ranks(params, d)
        c = MLP.param_counts(d, eff1, eff2)
        print(f"\neffective ranks: L1 {eff1}  L2 {eff2}")
    else:
        c = MLP.param_counts(d)
    bits = c["fixed_bits"] if d.qc.enable else c["float_bits"]
    print(f"test acc {acc:.3f}   params {c['tt_params']:,}   "
          f"memory {bits:,} bits   "
          f"reduction {c['dense_bits']/bits:.0f}x vs dense "
          f"(paper: 292x, 84.86% on real FMNIST)")
    print(f"{dt*1e3:.1f} ms/batch-64 on this CPU "
          f"(paper: 90 ms on the FPGA, 5340 ms on a Pi 3B)")
    deploy = quantize_for_deploy(params, d.qc)   # 4-bit cores for inference
    _ = deploy
    if d.qc.enable:
        # packed int4x2 deploy artifact: two codes per byte on disk
        from repro.ckpt import export_tt_deploy
        stats = export_tt_deploy("/tmp/fmnist_tt_deploy.ckpt", params)
        print(f"deploy export: {stats['packed_bytes']:,} B packed int4 "
              f"cores ({stats['reduction_x']:.1f}x vs fp32) "
              f"-> /tmp/fmnist_tt_deploy.ckpt")


if __name__ == "__main__":
    main()

"""End-to-end driver: train a ~100M-param decoder LM for a few hundred
steps on synthetic data — dense baseline or TT-compressed (--tt), with
checkpoint/resume, async checkpointing, straggler monitoring and prefetch.

    PYTHONPATH=src python examples/train_lm_100m.py --steps 200
    PYTHONPATH=src python examples/train_lm_100m.py --steps 200 --tt
"""
import argparse

import repro.configs as C
from repro.configs.base import TrainConfig
from repro.launch.train import LM100M, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tt", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm100m")
    args = ap.parse_args()

    cfg = LM100M
    if args.tt:
        cfg = C.with_tt(cfg, d=3, max_rank=48)
    tcfg = TrainConfig(learning_rate=3e-4, total_steps=args.steps,
                       warmup_steps=max(10, args.steps // 20),
                       ckpt_dir=args.ckpt_dir, ckpt_every=100, log_every=10)
    train(cfg, "tp", tcfg, batch=args.batch, seq=args.seq)


if __name__ == "__main__":
    main()

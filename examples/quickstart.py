"""Quickstart: the paper's technique in 40 lines.

Builds a TT-factorized, rank-adaptive, 4-bit-quantized linear layer, trains
it on a synthetic regression task, and shows the rank shrinking while the
quantized forward stays accurate.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.base import QuantConfig, TTConfig
from repro.core import rank_adapt as RA
from repro.core import tt_layer as TL
from repro.core import ttm

tt = TTConfig(enable=True, d=3, max_rank=12, rank_adapt=True,
              prune_threshold=1e-2)
qc = QuantConfig(enable=True, weight_bits=4, act_bits=8, grad_bits=16)

# a true low-TT-rank target to recover
true_spec = ttm.make_spec(128, 256, 3, 3)
true_cores = ttm.init_cores(jax.random.PRNGKey(42), true_spec, scale=1.0)
x = jax.random.normal(jax.random.PRNGKey(1), (512, 256))
y = ttm.ttm_matvec(true_cores, x, true_spec)

params, spec = TL.tt_linear_init(jax.random.PRNGKey(0), 128, 256, tt)
print(f"dense params: {spec.dense_params:,}  TT params: {spec.num_params:,} "
      f"({spec.compression:.1f}x smaller)")


def loss_fn(params):
    pred = TL.tt_linear_apply(params, x, spec, tt, qc)
    return (jnp.mean(jnp.square(pred - y))
            + 0.003 * TL.tt_prior_loss(params, spec, tt))


grad_fn = jax.jit(jax.grad(loss_fn, allow_int=True))
lr = 0.02
for step in range(801):
    g = grad_fn(params)
    params = jax.tree.map(
        lambda p, gg: p - lr * gg
        if hasattr(gg, "dtype") and gg.dtype != jax.dtypes.float0
        and jnp.issubdtype(p.dtype, jnp.floating) else p, params, g)
    params = TL.tt_lambda_update(params, spec, tt)   # closed-form Eq. (4)
    if step % 200 == 0:
        live, total = TL.tt_param_count(params, spec, tt)
        lambdas = TL.get_lambdas(params, spec)
        eff = RA.effective_ranks(lambdas, tt.prune_threshold)
        print(f"step {step:4d}  loss {float(loss_fn(params)):.5f}  "
              f"effective ranks {eff}  live params {live}/{total}")

print("\nrank-adaptive 4-bit TT training: ranks shrank one-shot, "
      "no rank search (paper §3).")

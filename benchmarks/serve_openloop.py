"""Open-loop serving benchmark: Poisson arrivals over a shared-prefix
workload, sweeping the shared-prefix fraction with the radix prefix cache
on vs off.

Unlike the closed-loop throughput sweep (serve_throughput.py submits
everything up front), requests arrive on a Poisson clock independent of the
engine's progress — the open-loop regime where prefill compute is the
bottleneck that decides goodput and tail TTFT. Each cell drives the engine
over the same seeded workload (arrival times and prompts are a function of
the sweep point only, never of the prefix flag) and records goodput,
p99 TTFT, prefix-hit rate, pages saved, and the prefill-compute savings
ratio (prompt tokens submitted / prompt tokens actually computed — the
cache's whole effect; 1.0 with the cache off).

Workload: with probability ``shared_frac`` a prompt is the cell's
``prefix_len``-token shared preamble plus a short random suffix (the
system-prompt/few-shot pattern); otherwise a fully random prompt of mixed
length. Acceptance target: >= 2x prefill-compute savings at the 80%%
shared-prefix point.

    PYTHONPATH=src python benchmarks/serve_openloop.py --smoke \
        --out BENCH_prefix_serve.json
    PYTHONPATH=src python benchmarks/serve_openloop.py \
        --shared-fracs 0.0 0.5 0.8 --requests 24
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np


def _history_append(doc) -> None:
    """Append this run to the bench-history ledger (git SHA + timestamp);
    ``benchmarks/history.py gate`` reads it in CI."""
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import history
    entry = history.append_entry(doc)
    print(f"[history] {entry['bench']} @ {entry['git_sha'][:9]} -> "
          f"{history.history_path()}", file=sys.stderr)


def make_workload(vocab: int, *, requests: int, shared_frac: float,
                  prefix_len: int, gen_len: int, rate: float, seed: int):
    """Seeded (arrival_s, prompt, max_new) triples; pure function of the
    sweep point so prefix-on and prefix-off cells replay the same traffic."""
    rng = np.random.RandomState(seed)
    prefix = rng.randint(0, vocab, prefix_len).tolist()
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=requests))
    # exactly round(frac * n) shared-prefix requests, order shuffled — the
    # mix is a property of the cell, not of sampling noise (small sweeps
    # would otherwise jitter the hit rate)
    shared = np.zeros(requests, bool)
    shared[:int(round(shared_frac * requests))] = True
    rng.shuffle(shared)
    work = []
    for t, is_shared in zip(arrivals, shared):
        if is_shared:
            prompt = prefix + rng.randint(
                0, vocab, int(rng.randint(1, 9))).tolist()
        else:
            prompt = rng.randint(
                0, vocab,
                int(rng.randint(max(prefix_len // 4, 2),
                                prefix_len))).tolist()
        work.append((float(t), prompt, int(rng.randint(2, gen_len + 1))))
    return work


def bench_cell(lm, params, plan, *, shared_frac: float, prefix_on: bool,
               requests: int, prefix_len: int, gen_len: int, rate: float,
               slots: int, page_size: int, seed: int, trace=None) -> dict:
    from repro.serve import Engine, EngineConfig, PoolConfig

    horizon = prefix_len + 8 + gen_len
    pcfg = PoolConfig(num_slots=slots, page_size=page_size,
                      pages_per_slot=-(-horizon // page_size) + 1,
                      quantized=True)
    if trace is not None:
        trace.emit("bench_cell", shared_frac=shared_frac,
                   prefix="on" if prefix_on else "off")
    eng = Engine(lm, params,
                 EngineConfig(pool=pcfg, prefix_cache=prefix_on,
                              prefill_bucket=8), plan, trace=trace)
    work = make_workload(lm.cfg.vocab_size, requests=requests,
                         shared_frac=shared_frac, prefix_len=prefix_len,
                         gen_len=gen_len, rate=rate, seed=seed)
    t0 = time.monotonic()
    i = 0
    while i < len(work) or eng.sched.has_work():
        now = time.monotonic() - t0
        while i < len(work) and work[i][0] <= now:
            _, prompt, new = work[i]
            eng.submit(prompt, max_new_tokens=new)
            i += 1
        if eng.sched.has_work():
            eng.step()
        elif i < len(work):
            # idle between arrivals: sleep to the next one
            time.sleep(max(min(work[i][0] - now, 0.05), 0.0))
    wall = time.monotonic() - t0
    s = eng.summary()
    computed = max(s["prefill_tokens"], 1)
    return {
        "shared_frac": shared_frac,
        "prefix_cache": "on" if prefix_on else "off",
        "requests": requests,
        "wall_s": wall,
        "goodput_tokens_per_s": s["tokens_per_s"],
        "ttft_p50_s": s["ttft_p50_s"],
        "ttft_p99_s": s["ttft_p99_s"],
        "prompt_tokens": s["prompt_tokens"],
        "prefill_tokens_computed": s["prefill_tokens"],
        "prefill_compute_savings": s["prompt_tokens"] / computed,
        "prefix_hit_rate": s["prefix_hit_rate"],
        "prefix_hit_tokens": s["prefix_hit_tokens"],
        "pages_saved": s["pages_saved"],
        "cow_forks": s["cow_forks"],
        "prefix_evictions": s["prefix_evictions"],
        "preemptions": s["preemptions"],
        "compile_evictions": s["compile_evictions"],
        "memory": s["memory"],
    }


def run_sweep(arch: str, shared_fracs: list[float], *, requests: int,
              prefix_len: int, gen_len: int, rate: float, slots: int,
              page_size: int, seed: int, trace=None) -> dict:
    import repro.configs as C
    from repro.models import build_lm, init_lm
    from repro.sharding import ShardPlan

    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    plan = ShardPlan(mesh=None)
    cells = []
    for frac in shared_fracs:
        for prefix_on in (False, True):
            cells.append(bench_cell(
                lm, params, plan, shared_frac=frac, prefix_on=prefix_on,
                requests=requests, prefix_len=prefix_len, gen_len=gen_len,
                rate=rate, slots=slots, page_size=page_size,
                seed=seed + int(frac * 1000), trace=trace))
            c = cells[-1]
            print(f"  shared={frac:.1f} prefix={c['prefix_cache']}: "
                  f"{c['goodput_tokens_per_s']:.1f} tok/s, "
                  f"hit_rate={c['prefix_hit_rate']:.2f}, "
                  f"savings={c['prefill_compute_savings']:.2f}x, "
                  f"pages_saved={c['pages_saved']}", file=sys.stderr)
    top = max(shared_fracs)
    best = next(c for c in cells
                if c["shared_frac"] == top and c["prefix_cache"] == "on")
    return {"bench": "prefix_serve", "arch": arch,
            "slots": slots, "page_size": page_size,
            "prefix_len": prefix_len, "gen_len": gen_len,
            "arrival_rate_per_s": rate, "requests_per_cell": requests,
            "backend": jax.default_backend(),
            "savings_at_top_shared_frac": best["prefill_compute_savings"],
            "hit_rate_at_top_shared_frac": best["prefix_hit_rate"],
            "target": {f"shared_frac={top}":
                       ">=2x prefill-compute savings, hit rate > 0.5"},
            "cells": cells}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=20.0,
                    help="Poisson arrival rate (requests/s)")
    ap.add_argument("--prefix-len", type=int, default=48,
                    help="shared-preamble length (tokens)")
    ap.add_argument("--gen-len", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--shared-fracs", type=float, nargs="+",
                    default=[0.0, 0.5, 0.8])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="small CI sweep: fewer requests, 0.8 only")
    ap.add_argument("--trace-out", default="",
                    help="record engine events (cache_hit/cow_fork/"
                         "prefix_evict among them) to this JSONL")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    trace = None
    if args.trace_out:
        from repro.obs import TraceRecorder
        trace = TraceRecorder()

    fracs = [0.8] if args.smoke else args.shared_fracs
    requests = 10 if args.smoke else args.requests
    gen = 6 if args.smoke else args.gen_len
    doc = run_sweep(args.arch, fracs, requests=requests,
                    prefix_len=args.prefix_len, gen_len=gen,
                    rate=args.rate, slots=args.slots,
                    page_size=args.page_size, seed=args.seed, trace=trace)
    if trace is not None:
        from repro.obs import write_jsonl
        n = write_jsonl(trace, args.trace_out)
        doc["telemetry"] = {"trace_jsonl": args.trace_out,
                            "trace_events": n,
                            "trace_capacity": trace.capacity,
                            "trace_dropped": trace.dropped}
        print(f"  wrote {n} trace events to {args.trace_out}",
              file=sys.stderr)
    text = json.dumps(doc, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
        _history_append(doc)
    else:
        print(text)


if __name__ == "__main__":
    main()

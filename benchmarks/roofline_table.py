"""Render the roofline table (EXPERIMENTS.md §Roofline) from the dry-run
JSON artifacts in experiments/dryrun/."""
from __future__ import annotations

import json
import os

OUT_DIR = "experiments/dryrun"
OPT_DIR = "experiments/dryrun_opt"


def load_all(out_dir: str | None = None) -> list[dict]:
    if out_dir is None:
        out_dir = OPT_DIR if os.path.isdir(OPT_DIR) else OUT_DIR
    rows = []
    if not os.path.isdir(out_dir):
        return rows
    for f in sorted(os.listdir(out_dir)):
        if f.endswith(".json"):
            with open(os.path.join(out_dir, f)) as fh:
                rows.append(json.load(fh))
    return rows


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | step | compute (ms) | memory (ms) | "
           "collective (ms) | bottleneck | useful |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']}"
            f"{' tt' if r.get('tt') else ''} | {r['step_kind']} | "
            f"{r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} | "
            f"{r['collective_s']*1e3:.1f} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} |")
    return "\n".join(lines)


def run() -> list[str]:
    rows = load_all()
    out = []
    for r in rows:
        if r["mesh"] != "16x16":
            continue
        dom = max(("compute_s", "memory_s", "collective_s"),
                  key=lambda k: r[k])
        out.append(f"roofline/{r['arch']}_{r['shape']},"
                   f"{r[dom]*1e6:.0f},"
                   f"bottleneck={r['bottleneck']} useful={r['useful_ratio']:.2f}"
                   f" compute_ms={r['compute_s']*1e3:.1f}"
                   f" memory_ms={r['memory_s']*1e3:.1f}"
                   f" coll_ms={r['collective_s']*1e3:.1f}")
    if not out:
        out.append("roofline/no_dryrun_artifacts,0,"
                   "run `python -m repro.launch.dryrun --all` first")
    return out


if __name__ == "__main__":
    print(markdown_table(load_all()))

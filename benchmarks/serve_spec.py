"""Speculative-decoding benchmark: draft-propose / q-block-verify engine
vs the plain one-token-per-step engine on the same paged int8 KV pool.

One reduced zoo pair (stablelm-3b drafting for yi-34b by default, random
init — acceptance reflects the rejection-sampling mechanics, not language
modeling), fixed request mix, greedy decoding so the spec run is
token-identical to the baseline (the bench asserts it). Cells record
end-to-end tokens/s, the acceptance telemetry (``summary()["spec"]``:
acceptance_rate, tokens_per_step) and the memory ledger — the draft's
params + private KV pool show up as ``draft_params`` / ``draft_kv_pool``
sites, which is the honest cost side of the speedup.

A ``self_draft`` cell (draft == target) closes the loop on draft-cache
consistency: P == Q makes rejection sampling accept every proposal, so its
acceptance_rate must be 1.0 — anything lower means the draft attended over
a stale or missing K/V position.

    PYTHONPATH=src python benchmarks/serve_spec.py
    PYTHONPATH=src python benchmarks/serve_spec.py --smoke \
        --out BENCH_spec_decode.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from serve_throughput import _history_append


def _build(arch: str, seed: int, vocab: int | None = None):
    import repro.configs as C
    from repro.models import build_lm, init_lm

    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    if vocab is not None:
        cfg = cfg.replace(vocab_size=vocab)
    lm = build_lm(cfg)
    return lm, init_lm(jax.random.PRNGKey(seed), lm)


def bench_cell(lm, params, plan, *, slots: int, requests: int,
               prompt_len: int, gen_len: int, page_size: int,
               quantized: bool, spec_k: int, draft=None,
               label: str) -> tuple[dict, list[list[int]]]:
    """One engine run; returns the cell dict and the emitted token streams
    (sorted by request id) so the caller can assert greedy identity."""
    from repro.serve import Engine, EngineConfig, PoolConfig

    horizon = prompt_len + gen_len + spec_k
    pcfg = PoolConfig(num_slots=slots, page_size=page_size,
                      pages_per_slot=-(-horizon // page_size) + 1,
                      quantized=quantized)
    eng = Engine(lm, params, EngineConfig(pool=pcfg, spec_k=spec_k), plan,
                 draft=draft)
    rng = np.random.RandomState(0)
    rids = []
    for _ in range(requests):
        plen = int(rng.randint(max(prompt_len // 2, 1), prompt_len + 1))
        rids.append(eng.submit(
            rng.randint(0, lm.cfg.vocab_size, plen).tolist(),
            max_new_tokens=gen_len))
    t0 = time.time()
    res = eng.run()
    wall = time.time() - t0
    s = eng.summary()
    cell = {
        "mode": label,
        "spec_k": spec_k,
        "slots": slots,
        "requests": requests,
        "kv_cache": "int8" if quantized else "fp32",
        "wall_s": wall,
        "tokens_per_s": s["tokens_per_s"],
        "decode_steps": s["decode_steps"],
        "ttft_p50_s": s["ttft_p50_s"],
        "latency_p50_s": s["latency_p50_s"],
        "preemptions": s["preemptions"],
        "memory": s["memory"],
    }
    if spec_k > 0:
        cell["spec"] = s["spec"]
    return cell, [res[r].tokens for r in rids]


def run_sweep(arch: str, draft_arch: str, ks: list[int], *, slots: int,
              requests: int, prompt_len: int, gen_len: int,
              page_size: int, quantized: bool) -> dict:
    from repro.sharding import ShardPlan

    lm, params = _build(arch, seed=0)
    dlm, dparams = _build(draft_arch, seed=1, vocab=lm.cfg.vocab_size)
    plan = ShardPlan(mesh=None)
    kw = dict(slots=slots, requests=requests, prompt_len=prompt_len,
              gen_len=gen_len, page_size=page_size, quantized=quantized)

    base, ref_tokens = bench_cell(lm, params, plan, spec_k=0, label="baseline",
                                  **kw)
    print(f"  baseline: {base['tokens_per_s']:.1f} tok/s", file=sys.stderr)
    cells = [base]
    for k in ks:
        cell, toks = bench_cell(lm, params, plan, spec_k=k,
                                draft=(dlm, dparams), label="spec", **kw)
        if toks != ref_tokens:
            raise SystemExit(f"greedy spec-k={k} output diverged from the "
                             f"non-speculative baseline — correctness bug")
        cell["greedy_identical_to_baseline"] = True
        cells.append(cell)
        sp = cell["spec"]
        print(f"  spec k={k}: {cell['tokens_per_s']:.1f} tok/s, "
              f"accept={sp['acceptance_rate']:.3f}, "
              f"{sp['tokens_per_step']:.2f} tok/step", file=sys.stderr)

    # draft == target: acceptance must be exactly 1.0 (cache-consistency
    # canary — see module docstring)
    k = ks[0]
    cell, toks = bench_cell(lm, params, plan, spec_k=k, draft=(lm, params),
                            label="self_draft", **kw)
    if toks != ref_tokens:
        raise SystemExit("greedy self-draft output diverged from baseline")
    if cell["spec"]["acceptance_rate"] != 1.0:
        raise SystemExit(
            f"self-draft acceptance {cell['spec']['acceptance_rate']:.4f} "
            f"!= 1.0 — draft KV cache out of sync with target context")
    cell["greedy_identical_to_baseline"] = True
    cells.append(cell)
    print(f"  self-draft k={k}: accept="
          f"{cell['spec']['acceptance_rate']:.3f}", file=sys.stderr)

    # acceptance metrics over EVERY spec_k>0 cell, self_draft included: two
    # independently random-initialized models almost never agree on argmax
    # (greedy acceptance ~0 is the honest zoo-pair figure), so the gateable
    # acceptance signal is the self-draft 1.0 — the cache-consistency pin
    # that regressed to ~0.62 under the missing-last-K/V bug.
    spec_cells = [c for c in cells if "spec" in c]
    return {
        "bench": "spec_decode",
        "arch": arch,
        "draft_arch": draft_arch,
        "spec_k": ks,
        "slots": slots,
        "prompt_len": prompt_len,
        "gen_len": gen_len,
        "page_size": page_size,
        "kv_cache": "int8" if quantized else "fp32",
        "backend": jax.default_backend(),
        "acceptance_rate_best": max(c["spec"]["acceptance_rate"]
                                    for c in spec_cells),
        "tokens_per_step_best": max(c["spec"]["tokens_per_step"]
                                    for c in spec_cells),
        "target": {
            "greedy_identity": "spec output == baseline output (asserted)",
            "self_draft_acceptance": "== 1.0 (asserted)",
            "tokens_per_step": "> 1.0 for an aligned draft "
                               "(random-init drafts measure mechanics only)",
        },
        "cells": cells,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-34b")
    ap.add_argument("--draft-arch", default="stablelm-3b")
    ap.add_argument("--spec-k", type=int, nargs="+", default=[2, 4])
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--fp-pool", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (4 requests, one k)")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    if args.smoke:
        args.requests, args.spec_k = 4, args.spec_k[:1]
        args.prompt_len, args.gen_len = 12, 10
    doc = run_sweep(args.arch, args.draft_arch, args.spec_k,
                    slots=args.slots, requests=args.requests,
                    prompt_len=args.prompt_len, gen_len=args.gen_len,
                    page_size=args.page_size, quantized=not args.fp_pool)
    text = json.dumps(doc, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
        _history_append(doc)
    else:
        print(text)


if __name__ == "__main__":
    main()

"""Paper Table 1: model parameters + memory bits for the five methods.

The parameter/memory columns are analytic (exact reproduction); accuracy
columns come from training on the synthetic FashionMNIST drop-in
(directional validation — the real dataset is not available offline).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.data import fashion_like
from repro.models import mlp_tt as MLP
from repro.optim import adam as A


def train_once(prior: bool, quantize: bool, steps: int = 400, lr=3e-3):
    d = MLP.make_mlp(prior=prior, quantize=quantize)
    params = MLP.init_mlp(jax.random.PRNGKey(0), d)
    tcfg = TrainConfig(learning_rate=lr, weight_decay=0.0)
    opt = A.init_adam(params, tcfg)
    xs, ys = fashion_like(4096, seed=1)
    xq, yq = fashion_like(1024, seed=2)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(MLP.mlp_loss, allow_int=True)(
            params, batch, d)
        params, opt = A.adam_update(params, grads, opt, jnp.asarray(lr), tcfg)
        if d.tt.rank_adapt:
            params = MLP.mlp_lambda_update(params, d)
        if d.qc.enable:
            params = MLP.mlp_scale_update(params, batch, grads, d)
        return params, opt, loss

    bsz = 64
    for i in range(steps):
        lo = (i * bsz) % (len(ys) - bsz)
        b = {"x": jnp.asarray(xs[lo:lo + bsz]), "y": jnp.asarray(ys[lo:lo + bsz])}
        params, opt, loss = step(params, opt, b)
    tr = MLP.mlp_forward(params, jnp.asarray(xs[:1024]), d)
    tr_acc = float((jnp.argmax(tr, -1) == jnp.asarray(ys[:1024])).mean())
    te = MLP.mlp_forward(params, jnp.asarray(xq), d)
    te_acc = float((jnp.argmax(te, -1) == jnp.asarray(yq)).mean())
    return params, d, tr_acc, te_acc


def run() -> list[str]:
    rows = []
    d = MLP.make_mlp()
    base = MLP.param_counts(d)
    # vanilla (dense) row — analytic
    rows.append(f"table1/vanilla_params,{base['dense_params']},paper=4.67e5")
    rows.append(f"table1/vanilla_bits,{base['dense_bits']},paper=1.49e7")
    for name, prior, quant, paper_bits in (
            ("float_noprior", False, False, 4.74e5),
            ("fixed_noprior", False, True, 6.13e4),
            ("float_prior", True, False, 3.46e5),
            ("fixed_prior", True, True, 5.11e4)):
        t0 = time.time()
        params, dd, tr, te = train_once(prior, quant, steps=250)
        if prior:
            eff = MLP.effective_ranks(params, dd)
            c = MLP.param_counts(dd, *eff)
        else:
            c = MLP.param_counts(dd)
        bits = c["fixed_bits"] if quant else c["float_bits"]
        red = base["dense_bits"] / bits
        rows.append(
            f"table1/{name},{(time.time()-t0)*1e6:.0f},"
            f"params={c['tt_params']} bits={bits} paper_bits={paper_bits:.3g}"
            f" reduction={red:.0f}x train_acc={tr:.3f} test_acc={te:.3f}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Rank-adaptation trajectory (paper §3.1): effective ranks + live params
per training step on the FMNIST model — the one-shot rank-selection claim."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.data import fashion_like
from repro.models import mlp_tt as MLP
from repro.optim import adam as A


def run(steps: int = 300) -> list[str]:
    d = MLP.make_mlp(prior=True, quantize=False)
    params = MLP.init_mlp(jax.random.PRNGKey(0), d)
    tcfg = TrainConfig(learning_rate=3e-3, weight_decay=0.0)
    opt = A.init_adam(params, tcfg)
    xs, ys = fashion_like(4096, seed=1)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(MLP.mlp_loss, allow_int=True)(
            params, batch, d)
        params, opt = A.adam_update(params, grads, opt,
                                    jnp.asarray(3e-3), tcfg)
        params = MLP.mlp_lambda_update(params, d)
        return params, opt, loss

    rows = []
    bsz = 64
    for i in range(steps):
        lo = (i * bsz) % (len(ys) - bsz)
        b = {"x": jnp.asarray(xs[lo:lo + bsz]), "y": jnp.asarray(ys[lo:lo + bsz])}
        params, opt, loss = step(params, opt, b)
        if i in (0, 50, 100, 200, steps - 1):
            eff1, eff2 = MLP.effective_ranks(params, d)
            c = MLP.param_counts(d, eff1, eff2)
            rows.append(f"rank_curve/step{i},{float(loss)*1e6:.0f},"
                        f"ranks_l1={eff1} ranks_l2={eff2} "
                        f"live_params={c['tt_params']}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

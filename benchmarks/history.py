"""Bench-history ledger + noise-aware regression gate.

Every bench run appends one JSONL entry to ``BENCH_history.jsonl`` — git
SHA, UTC timestamp, bench name, and the gate metrics extracted from the
BENCH document — so the repo carries its own measurement trajectory. The
gate then compares the newest entry per bench against the **median of the
last <=5 prior entries** with per-metric relative tolerances: tight (5%)
for byte-accounting metrics, which are deterministic functions of the
config, and loose (50–100%) for wall-clock throughput, which rides shared
CI machines.  Median-of-window + per-class tolerance is the noise model:
one slow machine day neither fails the gate nor poisons the baseline.

    python benchmarks/history.py append --doc BENCH_train_wire.json
    python benchmarks/history.py gate          # exit 1 on any regression

The benches append automatically when writing ``--out`` (their
``_history_append`` hook calls :func:`append_entry`); CI runs ``gate`` as a
separate step after the smoke benches.  ``REPRO_BENCH_HISTORY`` overrides
the ledger path (default: ``BENCH_history.jsonl`` at the repo root).
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

HISTORY_ENV = "REPRO_BENCH_HISTORY"
DEFAULT_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_history.jsonl")
WINDOW = 5   # prior entries the gate medians over


def history_path(path: str | None = None) -> str:
    return path or os.environ.get(HISTORY_ENV) or DEFAULT_PATH


def _git_sha() -> str:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)))
        if out.returncode == 0 and out.stdout.strip():
            return out.stdout.strip()
    except OSError:
        pass
    return os.environ.get("GITHUB_SHA", "unknown")


def _cells(doc: dict, **match) -> list[dict]:
    return [c for c in doc.get("cells", [])
            if all(c.get(k) == v for k, v in match.items())]


def _max_over(vals):
    vals = [v for v in vals if v is not None]
    return max(vals) if vals else None


def _min_over(vals):
    vals = [v for v in vals if v is not None]
    return min(vals) if vals else None


# Per-bench gate metrics. ``dir`` is the GOOD direction ("higher": a
# regression is candidate < median*(1-tol)); ``tol`` is the relative noise
# band.  Byte/reduction metrics are deterministic -> 5%; wall-clock
# throughput on shared CI runners -> 50%; step timing is gated only
# against a 2x blowup (tol 1.0).
GATES: dict[str, list[dict]] = {
    "serve_throughput": [
        dict(metric="tokens_per_s_best", dir="higher", tol=0.5,
             get=lambda d: _max_over(c.get("tokens_per_s")
                                     for c in d.get("cells", []))),
        dict(metric="cache_reduction_vs_fp32", dir="higher", tol=0.05,
             get=lambda d: _min_over(c.get("cache_reduction_vs_fp32")
                                     for c in _cells(d, kv_cache="int8"))),
        dict(metric="memory_total_bytes_int8", dir="lower", tol=0.05,
             get=lambda d: _min_over(
                 c["memory"]["total_bytes"]
                 for c in _cells(d, kv_cache="int8") if "memory" in c)),
    ],
    "prefix_serve": [
        dict(metric="goodput_tokens_per_s", dir="higher", tol=0.5,
             get=lambda d: _max_over(c.get("goodput_tokens_per_s")
                                     for c in _cells(d, prefix_cache="on"))),
        dict(metric="prefill_compute_savings", dir="higher", tol=0.1,
             get=lambda d: d.get("savings_at_top_shared_frac")),
        # verified bytes figure: (logical - physical) pages * page_nbytes
        # at its peak — COW forks make the instantaneous end-of-run value
        # timing-dependent, hence the loose band
        dict(metric="prefix_bytes_saved_peak", dir="higher", tol=0.5,
             get=lambda d: _max_over(
                 c["memory"]["sites"]["prefix_bytes_saved"]["peak_bytes"]
                 for c in _cells(d, prefix_cache="on") if "memory" in c)),
    ],
    "train_wire": [
        dict(metric="reduction_x", dir="higher", tol=0.05,
             get=lambda d: d.get("reduction_x")),
        dict(metric="table1_live_reduction_x", dir="higher", tol=0.05,
             get=lambda d: (d.get("memory") or {}).get(
                 "table1_live_reduction_x")),
        dict(metric="step_ms_low_precision", dir="lower", tol=1.0,
             get=lambda d: d.get("step_ms_low_precision")),
    ],
    "ssm_serve": [
        dict(metric="state_reduction_int8", dir="higher", tol=0.05,
             get=lambda d: d.get("state_reduction_int8")),
        dict(metric="tokens_per_s_int8", dir="higher", tol=0.5,
             get=lambda d: _max_over(c.get("tokens_per_s")
                                     for c in _cells(d, mode="engine",
                                                     state="int8"))),
    ],
    "paged_attention": [
        dict(metric="decode_tokens_per_s_fused", dir="higher", tol=0.5,
             get=lambda d: _max_over(c.get("decode_tokens_per_s")
                                     for c in _cells(d, impl="fused"))),
    ],
    "spec_decode": [
        # acceptance with a fixed-seed draft is deterministic modulo
        # borderline accept-test flips across BLAS backends -> 10%
        dict(metric="acceptance_rate_best", dir="higher", tol=0.1,
             get=lambda d: d.get("acceptance_rate_best")),
        dict(metric="tokens_per_step_best", dir="higher", tol=0.1,
             get=lambda d: d.get("tokens_per_step_best")),
        dict(metric="tokens_per_s_spec", dir="higher", tol=0.5,
             get=lambda d: _max_over(c.get("tokens_per_s")
                                     for c in _cells(d, mode="spec"))),
    ],
}


def extract_metrics(doc: dict) -> dict[str, float]:
    """The gate metrics of one BENCH document (empty for ungated benches)."""
    out = {}
    for g in GATES.get(doc.get("bench", ""), []):
        try:
            v = g["get"](doc)
        except (KeyError, TypeError, ValueError):
            v = None
        if v is not None:
            out[g["metric"]] = float(v)
    return out


def read_history(path: str | None = None) -> list[dict]:
    path = history_path(path)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return [json.loads(line) for line in f if line.strip()]


def append_entry(doc: dict, path: str | None = None, *,
                 sha: str | None = None, timestamp: str | None = None
                 ) -> dict:
    """Append one bench result to the history ledger; returns the entry."""
    path = history_path(path)
    entry = {
        "bench": doc.get("bench", "unknown"),
        "git_sha": sha or _git_sha(),
        "timestamp": timestamp or time.strftime("%Y-%m-%dT%H:%M:%SZ",
                                                time.gmtime()),
        "metrics": extract_metrics(doc),
    }
    with open(path, "a") as f:
        f.write(json.dumps(entry, sort_keys=True) + "\n")
    return entry


def _median(vals: list[float]) -> float:
    vals = sorted(vals)
    n = len(vals)
    return vals[n // 2] if n % 2 else 0.5 * (vals[n // 2 - 1] + vals[n // 2])


def check_regression(entry: dict, prior: list[dict]) -> list[str]:
    """Gate one entry against its bench's prior entries. Returns failure
    strings (empty list = pass; no priors for a metric = trivially pass —
    that's how the first entry seeds the ledger)."""
    fails = []
    specs = {g["metric"]: g for g in GATES.get(entry.get("bench", ""), [])}
    for name, cand in entry.get("metrics", {}).items():
        g = specs.get(name)
        if g is None:
            continue
        vals = [e["metrics"][name] for e in prior
                if name in e.get("metrics", {})][-WINDOW:]
        if not vals:
            continue
        med = _median(vals)
        if g["dir"] == "higher" and cand < med * (1 - g["tol"]):
            fails.append(
                f"{entry['bench']}.{name}: {cand:.6g} < "
                f"median({len(vals)}) {med:.6g} - {g['tol']:.0%}")
        elif g["dir"] == "lower" and cand > med * (1 + g["tol"]):
            fails.append(
                f"{entry['bench']}.{name}: {cand:.6g} > "
                f"median({len(vals)}) {med:.6g} + {g['tol']:.0%}")
    return fails


def gate(path: str | None = None) -> list[str]:
    """Gate the newest entry of every bench in the history. Returns the
    combined failure list."""
    entries = read_history(path)
    by_bench: dict[str, list[dict]] = {}
    for e in entries:
        by_bench.setdefault(e.get("bench", "unknown"), []).append(e)
    fails = []
    for bench, rows in sorted(by_bench.items()):
        cand, prior = rows[-1], rows[:-1]
        f = check_regression(cand, prior)
        fails.extend(f)
        state = "REGRESSED" if f else "ok"
        print(f"[history] {bench}: {len(rows)} entries, newest "
              f"{cand['git_sha'][:9]} {state} "
              f"({len(cand.get('metrics', {}))} metrics, "
              f"{len(prior)} priors)")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    sub = ap.add_subparsers(dest="cmd", required=True)
    a = sub.add_parser("append", help="append one BENCH_*.json to history")
    a.add_argument("--doc", required=True,
                   help="BENCH document path, or '-' for stdin")
    a.add_argument("--history", default=None)
    g = sub.add_parser("gate", help="regression-gate the newest entry "
                                    "of every bench; exit 1 on failure")
    g.add_argument("--history", default=None)
    args = ap.parse_args()

    if args.cmd == "append":
        doc = json.load(sys.stdin if args.doc == "-" else open(args.doc))
        entry = append_entry(doc, args.history)
        print(f"[history] appended {entry['bench']} @ "
              f"{entry['git_sha'][:9]}: {entry['metrics']}")
    elif args.cmd == "gate":
        fails = gate(args.history)
        if fails:
            print("[history] REGRESSIONS:\n  " + "\n  ".join(fails))
            raise SystemExit(1)
        print("[history] gate passed")


if __name__ == "__main__":
    main()

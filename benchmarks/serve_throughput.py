"""Serving-throughput sweep: batch slots × quantized-vs-fp KV pool.

For each cell, drives the continuous-batching engine over a fixed request
mix on a reduced config and records tokens/s, TTFT/latency percentiles and
resident cache bytes. Emits one JSON document (the bench-trajectory format)
to stdout or ``--out``.

    PYTHONPATH=src python benchmarks/serve_throughput.py
    PYTHONPATH=src python benchmarks/serve_throughput.py \
        --arch deepseek-v2-236b --slots 2 4 --out /tmp/serve_bench.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np


def bench_cell(lm, params, plan, *, slots: int, quantized: bool,
               requests: int, prompt_len: int, gen_len: int,
               page_size: int) -> dict:
    from repro.serve import Engine, EngineConfig, PoolConfig

    horizon = prompt_len + gen_len
    pcfg = PoolConfig(num_slots=slots, page_size=page_size,
                      pages_per_slot=-(-horizon // page_size) + 1,
                      quantized=quantized)
    eng = Engine(lm, params, EngineConfig(pool=pcfg), plan)
    rng = np.random.RandomState(0)
    for _ in range(requests):
        plen = int(rng.randint(max(prompt_len // 2, 1), prompt_len + 1))
        eng.submit(rng.randint(0, lm.cfg.vocab_size, plen).tolist(),
                   max_new_tokens=gen_len)
    t0 = time.time()
    eng.run()
    wall = time.time() - t0
    s = eng.summary()
    return {
        "slots": slots,
        "kv_cache": "int8" if quantized else "fp32",
        "requests": requests,
        "wall_s": wall,
        "tokens_per_s": s["tokens_per_s"],
        "ttft_p50_s": s["ttft_p50_s"],
        "ttft_p95_s": s["ttft_p95_s"],
        "latency_p50_s": s["latency_p50_s"],
        "latency_p95_s": s["latency_p95_s"],
        "cache_bytes": s["cache_bytes"],
        "cache_reduction_vs_fp32": s["cache_reduction"],
        "preemptions": s["preemptions"],
    }


def run_sweep(arch: str, slots_list: list[int], requests: int,
              prompt_len: int, gen_len: int, page_size: int) -> dict:
    import repro.configs as C
    from repro.models import build_lm, init_lm
    from repro.sharding import ShardPlan

    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    plan = ShardPlan(mesh=None)
    cells = []
    for slots in slots_list:
        for quantized in (False, True):
            cells.append(bench_cell(
                lm, params, plan, slots=slots, quantized=quantized,
                requests=requests, prompt_len=prompt_len, gen_len=gen_len,
                page_size=page_size))
            print(f"  slots={slots} kv={cells[-1]['kv_cache']}: "
                  f"{cells[-1]['tokens_per_s']:.1f} tok/s, "
                  f"{cells[-1]['cache_bytes']} cache bytes",
                  file=sys.stderr)
    return {"bench": "serve_throughput", "arch": arch,
            "prompt_len": prompt_len, "gen_len": gen_len,
            "page_size": page_size, "cells": cells}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--slots", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    doc = run_sweep(args.arch, args.slots, args.requests, args.prompt_len,
                    args.gen_len, args.page_size)
    text = json.dumps(doc, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)


if __name__ == "__main__":
    main()

"""Serving-throughput sweep: batch slots × quantized-vs-fp KV pool, plus a
fused-vs-gather paged-attention decode sweep (``--fused``) and an
SSM/hybrid recurrent-state serving sweep (``--ssm``).

Default mode drives the continuous-batching engine over a fixed request mix
on a reduced config and records tokens/s, TTFT/latency percentiles and
resident cache bytes. ``--fused`` instead sweeps context lengths and times
the batched decode step on the gather path (``gather_slots`` materializes
the fp32 slot view) vs the fused paged-attention path (per-page in-kernel
dequant + online softmax), recording measured decode tokens/s per cell and
a modeled KV-byte ratio (the gather path moves ~9x the HBM bytes per decode
step on an int8 pool: 1B codes read + 4B fp32 view written + 4B re-read by
attention, vs 1B codes read once). Emits one JSON document (the
bench-trajectory format) to stdout or ``--out``.

``--ssm`` drives an SSM or hybrid arch through the engine (fp32 vs int8
recurrent-state cache) against the legacy static-batch greedy loop
baseline, recording tokens/s and resident state bytes — the ≥3.5×
state-byte reduction acceptance measurement — into ``BENCH_ssm_serve.json``.

    PYTHONPATH=src python benchmarks/serve_throughput.py
    PYTHONPATH=src python benchmarks/serve_throughput.py \
        --arch deepseek-v2-236b --slots 2 4 --out /tmp/serve_bench.json
    PYTHONPATH=src python benchmarks/serve_throughput.py --fused \
        --out BENCH_paged_attn.json
    PYTHONPATH=src python benchmarks/serve_throughput.py --ssm \
        --arch rwkv6-1.6b --out BENCH_ssm_serve.json
    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python benchmarks/serve_throughput.py --mesh 1x8
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import numpy as np


def _history_append(doc) -> None:
    """Append this run to the bench-history ledger (git SHA + timestamp);
    ``benchmarks/history.py gate`` reads it in CI."""
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import history
    entry = history.append_entry(doc)
    print(f"[history] {entry['bench']} @ {entry['git_sha'][:9]} -> "
          f"{history.history_path()}", file=sys.stderr)


def plan_for(mesh: str | None):
    """``DxM`` -> a TP ShardPlan on a (data, model) dev mesh (shards the
    paged pool over KV heads and params per the plan); None/"" -> the
    mesh-less single-device plan."""
    from repro.sharding import ShardPlan, make_plan
    if not mesh:
        return ShardPlan(mesh=None)
    d, m = (int(x) for x in mesh.split("x"))
    return make_plan(jax.make_mesh((d, m), ("data", "model")), "tp")


def bench_cell(lm, params, plan, *, slots: int, quantized: bool,
               requests: int, prompt_len: int, gen_len: int,
               page_size: int, trace=None, health: bool = False) -> dict:
    """One (slots, kv-mode) engine run. ``trace``: shared
    ``repro.obs.TraceRecorder`` (cells are delimited by ``bench_cell``
    marker events); ``health`` switches on the in-engine quant-health
    aggregates — quantized cells only (the policy would otherwise force
    the fp32 cell's pool to int8)."""
    from repro.serve import Engine, EngineConfig, PoolConfig

    horizon = prompt_len + gen_len
    pcfg = PoolConfig(num_slots=slots, page_size=page_size,
                      pages_per_slot=-(-horizon // page_size) + 1,
                      quantized=quantized)
    policy = None
    if health and quantized:
        from repro.numerics import NumericsPolicy
        policy = NumericsPolicy(enable=True, health=True)
    if trace is not None:
        trace.emit("bench_cell", slots=slots,
                   kv="int8" if quantized else "fp32")
    eng = Engine(lm, params, EngineConfig(pool=pcfg, policy=policy), plan,
                 trace=trace)
    rng = np.random.RandomState(0)
    for _ in range(requests):
        plen = int(rng.randint(max(prompt_len // 2, 1), prompt_len + 1))
        eng.submit(rng.randint(0, lm.cfg.vocab_size, plen).tolist(),
                   max_new_tokens=gen_len)
    t0 = time.time()
    eng.run()
    wall = time.time() - t0
    s = eng.summary()
    return {
        "slots": slots,
        "kv_cache": "int8" if quantized else "fp32",
        "requests": requests,
        "wall_s": wall,
        "tokens_per_s": s["tokens_per_s"],
        "ttft_p50_s": s["ttft_p50_s"],
        "ttft_p95_s": s["ttft_p95_s"],
        "ttft_queue_p50_s": s["ttft_queue_p50_s"],
        "ttft_compute_p50_s": s["ttft_compute_p50_s"],
        "latency_p50_s": s["latency_p50_s"],
        "latency_p95_s": s["latency_p95_s"],
        "batch_fill_mean": s["batch_fill_mean"],
        "batch_fill_frac": s["batch_fill_frac"],
        "free_pages_min": s["free_pages_min"],
        "cache_bytes": s["cache_bytes"],
        "cache_reduction_vs_fp32": s["cache_reduction"],
        "preemptions": s["preemptions"],
        "quant_health": s["quant_health"],
        "memory": s["memory"],
    }


def run_sweep(arch: str, slots_list: list[int], requests: int,
              prompt_len: int, gen_len: int, page_size: int,
              trace=None, health: bool = False, mesh: str = "") -> dict:
    import repro.configs as C
    from repro.models import build_lm, init_lm

    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    plan = plan_for(mesh)
    cells = []
    for slots in slots_list:
        for quantized in (False, True):
            cells.append(bench_cell(
                lm, params, plan, slots=slots, quantized=quantized,
                requests=requests, prompt_len=prompt_len, gen_len=gen_len,
                page_size=page_size, trace=trace, health=health))
            print(f"  slots={slots} kv={cells[-1]['kv_cache']}: "
                  f"{cells[-1]['tokens_per_s']:.1f} tok/s, "
                  f"{cells[-1]['cache_bytes']} cache bytes",
                  file=sys.stderr)
    return {"bench": "serve_throughput", "arch": arch,
            "prompt_len": prompt_len, "gen_len": gen_len,
            "page_size": page_size, "mesh": mesh or "1",
            "cells": cells}


def _decode_timer(lm, params, plan, *, fused: bool, ctx: int, slots: int,
                  page_size: int, quantized: bool):
    """Build an engine at a fixed context depth and return a closure timing
    its jitted batched decode step (the path the fused kernel owns; host
    scheduling/sampling are identical across paths and excluded)."""
    import jax.numpy as jnp
    from repro.serve import Engine, EngineConfig, PoolConfig

    horizon = ctx + 40
    pcfg = PoolConfig(num_slots=slots, page_size=page_size,
                      pages_per_slot=-(-horizon // page_size) + 1,
                      quantized=quantized)
    eng = Engine(lm, params, EngineConfig(pool=pcfg, fused_attention=fused),
                 plan)
    rng = np.random.RandomState(0)
    for _ in range(slots):
        eng.submit(rng.randint(0, lm.cfg.vocab_size, ctx).tolist(),
                   max_new_tokens=30)
    eng.step()                          # admit + prefill + compile decode
    sched = eng.sched
    args = (jnp.asarray(sched.page_table), jnp.asarray(sched.lens_vector()),
            jnp.asarray(sched.active_mask()),
            jnp.asarray(sched.tokens_vector()))
    state = {"pool": eng.pool, "spool": eng.spool}

    def one():
        # pool + state pool are donated (argnums 1,2): rebind both each call
        logits, state["pool"], state["spool"] = eng._decode_jit(
            eng.params, state["pool"], state["spool"], *args)
        return logits

    def timed(steps: int) -> float:
        jax.block_until_ready(one())    # warm
        t0 = time.time()
        for _ in range(steps):
            logits = one()
        jax.block_until_ready(logits)
        return time.time() - t0

    return timed


def bench_decode_pair(lm, params, plan, *, ctx: int, slots: int,
                      page_size: int, quantized: bool, steps: int,
                      reps: int = 3) -> list[dict]:
    """Time gather vs fused decode at one context depth with interleaved
    repetitions (decorrelates machine noise); keeps the best rep of each."""
    timers = {impl: _decode_timer(lm, params, plan, fused=(impl == "fused"),
                                  ctx=ctx, slots=slots, page_size=page_size,
                                  quantized=quantized)
              for impl in ("gather", "fused")}
    best = {impl: float("inf") for impl in timers}
    for _ in range(reps):
        for impl, timed in timers.items():
            best[impl] = min(best[impl], timed(steps))
    return [{
        "ctx": ctx,
        "impl": impl,
        "decode_ms_per_step": 1e3 * best[impl] / steps,
        "decode_tokens_per_s": steps * slots / best[impl],
    } for impl in ("gather", "fused")]


def modeled_kv_bytes(lm, *, ctx: int, slots: int, quantized: bool) -> dict:
    """Per-decode-step KV-path HBM bytes of each attention path (the
    roofline-style model the ≥1.3x long-context target comes from; on CPU
    the Pallas kernel runs in interpret mode, so measured wall-clock there
    validates dataflow, not the TPU roofline)."""
    from repro.serve.kv_cache import kv_feature_shapes
    code = 1 if quantized else 4
    feat = 0
    for sub in lm.period:
        for shp in kv_feature_shapes(sub).values():
            f = 1
            for d in shp:
                f *= d
            feat += f
    elems = lm.n_periods * slots * ctx * feat
    # gather: codes read + fp32 view written + fp32 view read by attend
    gather = elems * (code + 4 + 4)
    # fused: codes read once, dequantized in-register
    fused = elems * code
    return {"gather_bytes": gather, "fused_bytes": fused,
            "bytes_ratio": gather / fused}


def run_fused_sweep(arch: str, ctxs: list[int], slots: int, page_size: int,
                    quantized: bool, steps: int, mesh: str = "") -> dict:
    import repro.configs as C
    from repro.models import build_lm, init_lm
    from repro.numerics.pallas_backend import interpret_mode as _interpret
    from repro.numerics.pallas_backend import native_backend as _native

    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    lm = build_lm(cfg)
    params = init_lm(jax.random.PRNGKey(0), lm)
    plan = plan_for(mesh)
    cells, speedup, modeled = [], {}, {}
    for ctx in ctxs:
        pair_cells = bench_decode_pair(
            lm, params, plan, ctx=ctx, slots=slots, page_size=page_size,
            quantized=quantized, steps=steps)
        cells.extend(pair_cells)
        pair = {c["impl"]: c for c in pair_cells}
        for c in pair_cells:
            print(f"  ctx={ctx} {c['impl']}: "
                  f"{c['decode_tokens_per_s']:.1f} tok/s "
                  f"({c['decode_ms_per_step']:.2f} ms/step)",
                  file=sys.stderr)
        speedup[str(ctx)] = (pair["fused"]["decode_tokens_per_s"]
                             / pair["gather"]["decode_tokens_per_s"])
        modeled[str(ctx)] = modeled_kv_bytes(lm, ctx=ctx, slots=slots,
                                             quantized=quantized)
    return {"bench": "paged_attention", "arch": arch, "slots": slots,
            "page_size": page_size,
            "kv_cache": "int8" if quantized else "fp32",
            "backend": jax.default_backend(),
            # label derived from the SAME predicate the engine's auto
            # selection uses (native_backend: TPU, or forced kernel
            # validation via JAX_PALLAS_INTERPRET=1 — interpret-mode
            # timings are dataflow validation, not performance); off-TPU
            # the fused path is the jnp page-scan. The modeled bytes ratio
            # carries the HBM-roofline expectation the >=1.3x long-context
            # target comes from.
            "fused_impl": ("pallas-interpret" if _interpret()
                           else "pallas") if _native()
                          else "jnp-page-scan",
            "decode_steps_timed": steps, "cells": cells,
            "measured_speedup_fused_vs_gather": speedup,
            "modeled_kv_hbm_bytes": modeled,
            "target": {"ctx<=512": "fused >= gather",
                       "ctx>=2048": ">=1.3x (HBM roofline; see modeled)"}}


def _static_loop_cell(lm, params, plan, *, batch: int, prompt_len: int,
                      gen_len: int) -> dict:
    """Legacy static-batch greedy loop (the pre-state-cache serving path
    for SSM/hybrid archs): whole-batch prefill, scalar-position decode, no
    admission/retirement. The baseline the engine cells compare against."""
    import jax.numpy as jnp
    from repro.launch.steps import make_prefill_step, make_serve_step

    prompt = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                                0, lm.cfg.vocab_size)
    prefill = jax.jit(make_prefill_step(lm, plan))
    logits, cache = prefill(params, {"tokens": prompt})

    # grow only the per-token attention leaves (keyed by name: recurrent
    # state axes can coincide with prompt_len — e.g. reduced-jamba d_inner)
    def pad_seq(path, a):
        leaf = path[-1].key if hasattr(path[-1], "key") else None
        if leaf in ("k", "v", "c_kv", "k_rope") and a.shape[2] == prompt_len:
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, gen_len)
            return jnp.pad(a, pad)
        return a

    cache = jax.tree_util.tree_map_with_path(pad_seq, cache)
    cache_bytes = sum(a.nbytes
                      for a in jax.tree_util.tree_leaves(cache))
    step = jax.jit(make_serve_step(lm, plan))
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    logits, cache = step(params, cache, tok, jnp.int32(prompt_len))  # warm
    jax.block_until_ready(logits)
    t0 = time.time()
    n = 0
    for i in range(1, gen_len - 1):
        logits, cache = step(params, cache, tok, jnp.int32(prompt_len + i))
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        n += batch
    jax.block_until_ready(tok)
    wall = time.time() - t0
    return {"mode": "static_loop", "state": "fp32", "batch": batch,
            "tokens_per_s": n / max(wall, 1e-9),
            "cache_bytes": cache_bytes}


def run_ssm_sweep(arch: str, slots: int, requests: int, prompt_len: int,
                  gen_len: int, page_size: int) -> dict:
    """Engine (fp32-state vs int8-state) vs static-loop baseline for an
    SSM/hybrid arch. Emits the BENCH_ssm_serve document."""
    import repro.configs as C
    from repro.models import build_lm, init_lm
    from repro.serve import Engine, EngineConfig, PoolConfig
    from repro.sharding import ShardPlan

    cfg = C.get_reduced(arch).replace(dtype="float32", remat="none")
    lm = build_lm(cfg)
    recurrent = [s.mixer_kind for s in lm.period
                 if s.mixer_kind in ("mamba", "rwkv6")]
    if not recurrent:
        raise SystemExit(f"--ssm wants an SSM/hybrid arch, {arch} has no "
                         f"recurrent sublayers")
    params = init_lm(jax.random.PRNGKey(0), lm)
    plan = ShardPlan(mesh=None)
    cells = [_static_loop_cell(lm, params, plan, batch=slots,
                               prompt_len=prompt_len, gen_len=gen_len)]
    print(f"  static loop: {cells[0]['tokens_per_s']:.1f} tok/s, "
          f"{cells[0]['cache_bytes']} cache bytes", file=sys.stderr)
    horizon = prompt_len + gen_len
    state_bytes = {}
    for quantized in (False, True):
        pcfg = PoolConfig(num_slots=slots, page_size=page_size,
                          pages_per_slot=-(-horizon // page_size) + 1,
                          quantized=quantized)
        eng = Engine(lm, params, EngineConfig(pool=pcfg), plan)
        rng = np.random.RandomState(0)
        for _ in range(requests):
            plen = int(rng.randint(max(prompt_len // 2, 1), prompt_len + 1))
            eng.submit(rng.randint(0, lm.cfg.vocab_size, plen).tolist(),
                       max_new_tokens=gen_len)
        t0 = time.time()
        eng.run()
        wall = time.time() - t0
        s = eng.summary()
        state = "int8" if quantized else "fp32"
        state_bytes[state] = s["state_bytes"]
        cells.append({
            "mode": "engine", "state": state, "slots": slots,
            "requests": requests, "wall_s": wall,
            "tokens_per_s": s["tokens_per_s"],
            "ttft_p50_s": s["ttft_p50_s"],
            "latency_p50_s": s["latency_p50_s"],
            "state_bytes": s["state_bytes"],
            "state_bytes_fp32": s["state_bytes_fp32"],
            "state_reduction_vs_fp32": s["state_reduction"],
            "cache_bytes": s["cache_bytes"],
            "preemptions": s["preemptions"],
            "memory": s["memory"],
        })
        print(f"  engine state={state}: {s['tokens_per_s']:.1f} tok/s, "
              f"{s['state_bytes']} state bytes "
              f"({s['state_reduction']:.2f}x vs fp32)", file=sys.stderr)
    return {"bench": "ssm_serve", "arch": arch,
            "mixers": sorted(set(recurrent)), "slots": slots,
            "prompt_len": prompt_len, "gen_len": gen_len,
            "page_size": page_size, "backend": jax.default_backend(),
            "state_reduction_int8": (state_bytes["fp32"]
                                     / max(state_bytes["int8"], 1)),
            "target": {"state_reduction_int8": ">=3.5x"},
            "cells": cells}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--slots", type=int, nargs="+", default=[2, 4, 8])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--page-size", type=int, default=None,
                    help="tokens per page (default: 8; 16 for the full "
                         "--fused sweep)")
    ap.add_argument("--fused", action="store_true",
                    help="fused-vs-gather paged-attention decode sweep "
                         "(emits the BENCH_paged_attn document)")
    ap.add_argument("--ssm", action="store_true",
                    help="SSM/hybrid engine vs static-loop sweep "
                         "(emits the BENCH_ssm_serve document)")
    ap.add_argument("--ctx", type=int, nargs="+", default=[128, 512, 2048])
    ap.add_argument("--decode-steps", type=int, default=12)
    ap.add_argument("--fp-pool", action="store_true",
                    help="fused sweep on an fp32 pool instead of int8")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny fused sweep for CI (ctx 64, few steps)")
    ap.add_argument("--trace-out", default="",
                    help="default sweep only: record per-step engine events "
                         "(admit/prefill/decode/preempt/retire/page "
                         "alloc-free) to this JSONL and switch on the "
                         "quant-health aggregates for int8 cells; the BENCH "
                         "doc grows a 'telemetry' key")
    ap.add_argument("--mesh", default="",
                    help="DxM (data, model) dev mesh for the default and "
                         "--fused sweeps — runs the engine on the TP plan "
                         "(KV pool sharded over KV heads). Needs D*M "
                         "devices, e.g. XLA_FLAGS="
                         "--xla_force_host_platform_device_count=8 "
                         "--mesh 1x8 on CPU")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    trace = None
    if args.trace_out:
        if args.fused or args.ssm:
            raise SystemExit("--trace-out drives the default engine sweep "
                             "(not --fused/--ssm)")
        from repro.obs import TraceRecorder
        trace = TraceRecorder()

    if args.ssm:
        requests = 4 if args.smoke else args.requests
        plen = 8 if args.smoke else args.prompt_len
        glen = 6 if args.smoke else args.gen_len
        doc = run_ssm_sweep(args.arch, slots=args.slots[0],
                            requests=requests, prompt_len=plen,
                            gen_len=glen, page_size=args.page_size or 8)
    elif args.fused:
        ctxs = [64] if args.smoke else args.ctx
        steps = 4 if args.smoke else args.decode_steps
        page = args.page_size or (8 if args.smoke else 16)
        doc = run_fused_sweep(args.arch, ctxs, slots=args.slots[0],
                              page_size=page,
                              quantized=not args.fp_pool, steps=steps,
                              mesh=args.mesh)
    else:
        doc = run_sweep(args.arch, args.slots, args.requests,
                        args.prompt_len, args.gen_len, args.page_size or 8,
                        trace=trace, health=trace is not None,
                        mesh=args.mesh)
    if trace is not None:
        from repro.numerics.pallas_backend import fallback_count
        from repro.obs import kernel_costs, write_jsonl
        n = write_jsonl(trace, args.trace_out)
        doc["telemetry"] = {
            "trace_jsonl": args.trace_out,
            "trace_events": n,
            "trace_capacity": trace.capacity,
            "trace_dropped": trace.dropped,
            "codec_fallbacks": fallback_count(),
            "kernel_costs": kernel_costs(),
        }
        print(f"  wrote {n} trace events to {args.trace_out}",
              file=sys.stderr)
    text = json.dumps(doc, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
        _history_append(doc)
    else:
        print(text)


if __name__ == "__main__":
    main()

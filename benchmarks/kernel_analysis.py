"""Paper Table 2 analogue: per-kernel resource analysis.

The FPGA table reports LUT/FF/DSP/BRAM; the TPU-native equivalents are
per-block VMEM footprint, MXU FLOPs, HBM bytes, and arithmetic intensity.
Also times each kernel in interpret mode against its jnp oracle (correctness
wall, not a perf claim — interpret mode runs the kernel body in Python).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16, VMEM_BYTES

def _analyze_pe1(a, b, c, d):
    k = b * c
    bm, bn, bk = min(128, a), min(128, d), min(512, k)
    vmem = (bm * bk + bk * bn + bm * bn) * 4
    flops = 2 * a * d * k
    byts = (a * k + k * d + a * d) * 4
    return vmem, flops, byts


def run() -> list[str]:
    rows = []
    key = jax.random.PRNGKey(0)

    def timed(f, *args):
        out = f(*args)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            out = f(*args)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / 3

    # PE1
    for name, (a, b, c, d) in (("pe1_fmnist", (3584, 1, 16, 256)),
                               ("pe1_lm", (4096, 16, 28, 1024))):
        z = jax.random.normal(key, (a, b, c))
        g = jax.random.normal(key, (b, d, c))
        t = timed(ops.pe1, z, g)
        err = float(jnp.abs(ops.pe1(z, g) - ref.pe1_ref(z, g)).max())
        vmem, flops, byts = _analyze_pe1(a, b, c, d)
        ai = flops / byts
        rows.append(
            f"kernel/{name},{t*1e6:.0f},vmem_block={vmem} flops={flops:.2e}"
            f" bytes={byts:.2e} AI={ai:.1f}"
            f" v5e_bound={'compute' if ai > PEAK_FLOPS_BF16/HBM_BW else 'memory'}"
            f" err={err:.1e}")
    # PE2 (interpret mode runs the kernel body in Python per block — the
    # LM-scale shape is reduced to keep the correctness wall fast; the
    # analytic columns use the true shape)
    for name, (a, b, c, d) in (("pe2_fmnist", (896, 64, 16, 64)),
                               ("pe2_lm", (512, 448, 16, 256))):
        z = jax.random.normal(key, (a, b, c))
        g = jax.random.normal(key, (b, d))
        t = timed(ops.pe2, z, g)
        err = float(jnp.abs(ops.pe2(z, g) - ref.pe2_ref(z, g)).max())
        flops = 2 * a * b * c * d
        byts = (a * b * c + b * d + a * d * c) * 4
        rows.append(f"kernel/{name},{t*1e6:.0f},flops={flops:.2e}"
                    f" bytes={byts:.2e} AI={flops/byts:.1f} err={err:.1e}")
    # PE3
    for name, (bsz, j, i) in (("pe3_fmnist", (64, 512, 896)),
                              ("pe3_lm", (4096, 1024, 512))):
        y = jax.random.normal(key, (bsz, j))
        x = jax.random.normal(key, (bsz, i))
        t = timed(ops.pe3, y, x)
        err = float(jnp.abs(ops.pe3(y, x) - ref.pe3_ref(y, x)).max())
        flops = 2 * bsz * j * i
        byts = (bsz * j + bsz * i + j * i) * 4
        rows.append(f"kernel/{name},{t*1e6:.0f},flops={flops:.2e}"
                    f" bytes={byts:.2e} AI={flops/byts:.1f} err={err:.1e}")
    # fused quantizer
    x = jax.random.normal(key, (1 << 16,))
    t = timed(ops.quantize_fused, x, jnp.asarray(-3.0), 8)
    err = float(jnp.abs(ops.quantize_fused(x, jnp.asarray(-3.0), 8)
                        - ref.quantize_ref(x, jnp.asarray(-3.0), 8)).max())
    rows.append(f"kernel/quantize_64k,{t*1e6:.0f},bytes={x.size*8:.2e}"
                f" AI=0.25 err={err:.1e}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

  table1_memory      — paper Table 1 (memory reduction, 5 methods)
  speed_tensorized   — paper §5 runtime comparison (fwd+bwd per batch-64)
  kernel_analysis    — paper Table 2 analogue (per-kernel VMEM/FLOPs/AI)
  rank_adapt_curve   — paper §3.1 rank-shrinkage trajectory
  roofline_table     — §Roofline terms from the dry-run artifacts (if any)
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from . import (kernel_analysis, rank_adapt_curve, roofline_table,
                   speed_tensorized, table1_memory)
    modules = [
        ("table1_memory", table1_memory),
        ("speed_tensorized", speed_tensorized),
        ("kernel_analysis", kernel_analysis),
        ("rank_adapt_curve", rank_adapt_curve),
        ("roofline_table", roofline_table),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, mod in modules:
        try:
            for row in mod.run():
                print(row)
        except Exception as e:
            failed.append(name)
            print(f"{name}/ERROR,0,{e!r}", file=sys.stderr)
            traceback.print_exc()
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()

"""CI telemetry-schema assertions (the smoke gate for repro.obs).

Validates the artifacts the ``--trace-out`` bench runs emit: the trace
JSONL carries the engine event schema (including the paged-pool and
prefix-cache event kinds), the BENCH documents grow the ``telemetry`` /
``quant_health`` / ``memory`` keys, every clip fraction is finite and
< 0.5 at the seed config, the trace ring never dropped an event at bench
capacity, and the live memory ledger reconciles — with the train doc's
four-site live reduction agreeing with the analytic Table-1 figure.

    python benchmarks/check_telemetry.py \
        --serve BENCH_serve_telemetry.json --serve-trace serve_trace.jsonl \
        --train BENCH_train_wire.json --train-trace train_trace.jsonl \
        --prefix BENCH_prefix_serve.json --prefix-trace prefix_trace.jsonl
"""
from __future__ import annotations

import argparse
import json
import math

# always present in a default engine sweep that decodes past one page
SERVE_EVENT_KINDS = {"submit", "admit", "prefill", "first_token",
                     "decode_step", "retire", "page_alloc", "page_free"}
# kinds a trace may carry; anything outside this set is a schema drift
KNOWN_EVENT_KINDS = SERVE_EVENT_KINDS | {
    "prefill_chunk", "preempt", "cache_hit", "cow_fork", "prefix_evict",
    "state_snapshot", "state_restore", "bench_cell", "train_step"}


def _check_fraction(name: str, f: float) -> None:
    assert math.isfinite(f) and 0.0 <= f < 0.5, \
        f"{name}: clip/sat fraction {f!r} out of range"


def _check_ring(tel: dict) -> None:
    """The bench workload must fit the recorder: capacity respected and
    nothing silently dropped."""
    assert tel["trace_events"] > 0, tel
    assert tel["trace_events"] <= tel["trace_capacity"], tel
    assert tel["trace_dropped"] == 0, \
        f"trace ring dropped {tel['trace_dropped']} events at bench capacity"


def _check_kinds(trace_path: str, required: set[str]) -> set[str]:
    kinds = {json.loads(line)["kind"] for line in open(trace_path)}
    missing = required - kinds
    assert not missing, f"trace {trace_path} missing event kinds: {missing}"
    unknown = kinds - KNOWN_EVENT_KINDS
    assert not unknown, f"trace {trace_path} unknown event kinds: {unknown}"
    return kinds


def _check_memory(cell: dict, label: str) -> dict:
    mem = cell.get("memory")
    assert mem and mem["total_bytes"] > 0, f"{label}: no memory ledger"
    rec = mem["reconcile"]
    assert rec["ok"], f"{label}: ledger/live-arrays reconcile failed: {rec}"
    return mem


def check_serve(doc_path: str, trace_path: str) -> None:
    doc = json.load(open(doc_path))
    tel = doc["telemetry"]
    _check_ring(tel)
    assert tel["codec_fallbacks"] == 0, \
        f"serve sweep took {tel['codec_fallbacks']} reference-codec fallbacks"
    kinds = _check_kinds(trace_path, SERVE_EVENT_KINDS)
    # conditional kinds: required exactly when the counters say the code
    # path fired
    if any(c["preemptions"] > 0 for c in doc["cells"]):
        assert "preempt" in kinds, kinds
    int8 = [c for c in doc["cells"] if c["kv_cache"] == "int8"]
    assert int8, doc["cells"]
    for c in int8:
        kv = c["quant_health"].get("kv_cache")
        assert kv and kv["total"] > 0, c["quant_health"]
        _check_fraction(f"serve slots={c['slots']} kv_cache",
                        kv["clip_fraction"])
    for c in doc["cells"]:
        assert c["batch_fill_mean"] > 0, c
        label = f"serve slots={c['slots']} kv={c['kv_cache']}"
        mem = _check_memory(c, label)
        assert mem["sites"]["kv_pool"]["bytes"] == c["cache_bytes"], \
            f"{label}: ledger kv_pool disagrees with cache_bytes"
        assert "decode" in mem["watermarks"], mem["watermarks"].keys()
    print(f"[check_telemetry] serve OK: {tel['trace_events']} events, "
          f"{len(int8)} int8 cells with kv health + reconciled ledgers")


def check_prefix(doc_path: str, trace_path: str) -> None:
    """The open-loop prefix sweep: COW/prefix event kinds and the verified
    bytes-saved figure of the prefix-on cells."""
    doc = json.load(open(doc_path))
    tel = doc["telemetry"]
    _check_ring(tel)
    kinds = _check_kinds(trace_path, {"submit", "admit", "prefill",
                                      "decode_step", "retire"})
    on = [c for c in doc["cells"] if c["prefix_cache"] == "on"]
    assert on, doc["cells"]
    if any(c["cow_forks"] > 0 for c in on):
        assert {"cache_hit", "cow_fork"} <= kinds, kinds
    if any(c["prefix_evictions"] > 0 for c in on):
        assert "prefix_evict" in kinds, kinds
    if any(c["preemptions"] > 0 for c in doc["cells"]):
        assert "preempt" in kinds, kinds
    saved_peak = 0
    for c in doc["cells"]:
        label = f"prefix={c['prefix_cache']} shared={c['shared_frac']}"
        mem = _check_memory(c, label)
        if c["prefix_cache"] == "on":
            site = mem["sites"].get("prefix_bytes_saved", {})
            assert not site.get("counted", False), \
                f"{label}: prefix overlay must be uncounted"
            saved_peak = max(saved_peak, site.get("peak_bytes", 0))
    hits = any(c["prefix_hit_tokens"] > 0 for c in on)
    assert saved_peak > 0 or not hits, \
        "prefix hits occurred but the ledger never saw shared pages"
    print(f"[check_telemetry] prefix OK: {tel['trace_events']} events, "
          f"peak bytes saved {saved_peak}")


def check_train(doc_path: str, trace_path: str) -> None:
    doc = json.load(open(doc_path))
    qh = doc["quant_health"]
    for site in ("grad_edge", "dp_wire"):
        assert site in qh, qh
        _check_fraction(f"train {site} clip", qh[site]["clip_fraction"])
        _check_fraction(f"train {site} sat", qh[site]["sat_fraction"])
    assert qh["grad_edge"]["total"] > 0, qh
    mem = doc["memory"]
    assert mem["reconcile"]["ok"], mem["reconcile"]
    live = mem["table1_live_reduction_x"]
    analytic = doc["reduction_x"]
    assert live >= 8, \
        f"live Table-1 reduction {live:.2f}x below the paper's 8x floor"
    assert abs(live - analytic) <= 0.1 * analytic, \
        f"live ledger {live:.2f}x vs analytic {analytic:.2f}x drifted >10%"
    assert 0.9 <= mem["live_vs_analytic_frac"] <= 1.1, mem
    if "telemetry" in doc:
        _check_ring(doc["telemetry"])
    steps = [json.loads(line) for line in open(trace_path)]
    assert steps and all(s["kind"] == "train_step" and s["dur"] > 0
                         for s in steps), steps[:3]
    print(f"[check_telemetry] train OK: {len(steps)} train_step events, "
          f"grad_edge sat {qh['grad_edge']['sat_fraction']:.4f}, "
          f"live reduction {live:.1f}x (analytic {analytic:.1f}x)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--serve")
    ap.add_argument("--serve-trace")
    ap.add_argument("--train")
    ap.add_argument("--train-trace")
    ap.add_argument("--prefix")
    ap.add_argument("--prefix-trace")
    args = ap.parse_args()
    if args.serve:
        check_serve(args.serve, args.serve_trace)
    if args.train:
        check_train(args.train, args.train_trace)
    if args.prefix:
        check_prefix(args.prefix, args.prefix_trace)


if __name__ == "__main__":
    main()

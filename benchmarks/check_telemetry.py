"""CI telemetry-schema assertions (the smoke gate for repro.obs).

Validates the artifacts the ``--trace-out`` bench runs emit: the trace
JSONL carries the engine event schema, the BENCH documents grow the
``telemetry`` / ``quant_health`` keys, and every clip fraction is finite
and < 0.5 at the seed config (a clip fraction near the 0.5 ceiling means
the pow-2 scale manager is mis-tracking — the §3.3 regression this guards).

    python benchmarks/check_telemetry.py \
        --serve BENCH_serve_telemetry.json --serve-trace serve_trace.jsonl \
        --train BENCH_train_wire.json --train-trace train_trace.jsonl
"""
from __future__ import annotations

import argparse
import json
import math

SERVE_EVENT_KINDS = {"submit", "admit", "prefill", "first_token",
                     "decode_step", "retire"}


def _check_fraction(name: str, f: float) -> None:
    assert math.isfinite(f) and 0.0 <= f < 0.5, \
        f"{name}: clip/sat fraction {f!r} out of range"


def check_serve(doc_path: str, trace_path: str) -> None:
    doc = json.load(open(doc_path))
    tel = doc["telemetry"]
    assert tel["trace_events"] > 0, tel
    assert tel["trace_dropped"] == 0, tel
    assert tel["codec_fallbacks"] == 0, \
        f"serve sweep took {tel['codec_fallbacks']} reference-codec fallbacks"
    kinds = {json.loads(line)["kind"] for line in open(trace_path)}
    missing = SERVE_EVENT_KINDS - kinds
    assert not missing, f"trace {trace_path} missing event kinds: {missing}"
    int8 = [c for c in doc["cells"] if c["kv_cache"] == "int8"]
    assert int8, doc["cells"]
    for c in int8:
        kv = c["quant_health"].get("kv_cache")
        assert kv and kv["total"] > 0, c["quant_health"]
        _check_fraction(f"serve slots={c['slots']} kv_cache",
                        kv["clip_fraction"])
    for c in doc["cells"]:
        assert c["batch_fill_mean"] > 0, c
    print(f"[check_telemetry] serve OK: {tel['trace_events']} events, "
          f"{len(int8)} int8 cells with kv health")


def check_train(doc_path: str, trace_path: str) -> None:
    doc = json.load(open(doc_path))
    qh = doc["quant_health"]
    for site in ("grad_edge", "dp_wire"):
        assert site in qh, qh
        _check_fraction(f"train {site} clip", qh[site]["clip_fraction"])
        _check_fraction(f"train {site} sat", qh[site]["sat_fraction"])
    assert qh["grad_edge"]["total"] > 0, qh
    steps = [json.loads(line) for line in open(trace_path)]
    assert steps and all(s["kind"] == "train_step" and s["dur"] > 0
                         for s in steps), steps[:3]
    print(f"[check_telemetry] train OK: {len(steps)} train_step events, "
          f"grad_edge sat {qh['grad_edge']['sat_fraction']:.4f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--serve")
    ap.add_argument("--serve-trace")
    ap.add_argument("--train")
    ap.add_argument("--train-trace")
    args = ap.parse_args()
    if args.serve:
        check_serve(args.serve, args.serve_trace)
    if args.train:
        check_train(args.train, args.train_trace)


if __name__ == "__main__":
    main()

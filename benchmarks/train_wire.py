"""Train-wire memory/time benchmark: the paper's Table-1 as a JSON artifact.

Runs one jitted low-precision train step on the FMNIST TT config (4-bit TT
cores, 8-bit activations, 16-bit gradients, blockwise-int8 Adam moments,
blockwise-int8 gradient wire, packed-int4x2 deploy export) and the fp32
dense shadow, then emits per-NumericsPolicy-site measured bytes plus the
aggregate reduction (``reduction_x``), step timings, and a ``memory`` key —
a live ``repro.obs.MemoryLedger`` over the step's actual artifacts whose
four-site ``table1_live_reduction_x`` must agree with the analytic
``reduction_x`` (``BENCH_train_wire.json``). CI smoke asserts both are
>= 8. Writing ``--out`` also appends the run to ``BENCH_history.jsonl``
for the regression gate (``benchmarks/history.py``).

``fmnist_low_precision_step`` / ``fmnist_site_table`` are the single owners
of the step construction and the per-site byte accounting —
tests/test_train_wire.py imports THIS module so the executable test and the
bench artifact can never drift apart.

    PYTHONPATH=src python benchmarks/train_wire.py
    PYTHONPATH=src python benchmarks/train_wire.py --smoke --out /tmp/b.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np


def act_shapes(batch: int) -> list[tuple[int, int]]:
    """The MLP's three activation quant-edge sites (input/hidden/output)."""
    return [(batch, 896), (batch, 512), (batch, 16)]


# the four sites of the paper's Table-1 comparison — the live ledger's
# reduction over exactly this subset is what CI cross-checks against the
# analytic ``reduction_x``
TABLE1_SITES = ("tt_factor", "activation", "optimizer_moment", "dp_wire")


def _history_append(doc: dict) -> None:
    """Append this run to the bench-history ledger (git SHA + timestamp);
    ``benchmarks/history.py gate`` reads it in CI."""
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    import history
    entry = history.append_entry(doc)
    print(f"[history] {entry['bench']} @ {entry['git_sha'][:9]} -> "
          f"{history.history_path()}", file=sys.stderr)


def fmnist_low_precision_step(batch: int = 64, opt_dtype: str = "int8",
                              compress: bool = True) -> dict:
    """Build and run ONE jitted low-precision FMNIST train step (the
    paper's full wire: 4-bit cores / 8-bit acts / 16-bit grads / int8
    moments / int8 wire). Returns everything the accounting and timing
    need."""
    from repro.configs.base import TrainConfig
    from repro.models import mlp_tt as MLP
    from repro.optim import adam as A
    from repro.optim.grad_compress import compress_decompress

    d = MLP.make_mlp(prior=True, quantize=True)
    params = MLP.init_mlp(jax.random.PRNGKey(0), d)
    policy = d.qc.policy()
    wire_spec = policy.spec_for("dp_wire")
    tcfg = TrainConfig(learning_rate=3e-3, weight_decay=0.0,
                       opt_state_dtype=opt_dtype)
    opt = A.init_adam(params, tcfg)

    @jax.jit
    def step(params, opt, batch, residual):
        loss, grads = jax.value_and_grad(MLP.mlp_loss, allow_int=True)(
            params, batch, d)
        if compress:
            grads, residual = compress_decompress(grads, residual,
                                                  wire_spec)
        params, opt = A.adam_update(params, grads, opt, jnp.asarray(3e-3),
                                    tcfg)
        params = MLP.mlp_lambda_update(params, d)
        params = MLP.mlp_scale_update(params, batch, grads, d)
        return params, opt, loss, grads, residual

    rng = np.random.RandomState(0)
    b = {"x": jnp.asarray(rng.normal(size=(batch, 896)), jnp.float32),
         "y": jnp.asarray(rng.randint(0, 10, batch), jnp.int32)}
    new_params, opt, loss, grads, residual = step(params, opt, b, None)
    return {"d": d, "params": params, "new_params": new_params, "opt": opt,
            "loss": loss, "grads": grads, "residual": residual,
            "policy": policy, "step": step, "batch_arrays": b,
            "batch": batch}


def fmnist_site_table(result: dict,
                      deploy_path: str | None = None
                      ) -> tuple[dict, dict, dict]:
    """Per-site measured bytes for one low-precision step vs the fp32 dense
    baseline (the paper's Table-1 comparison). Returns (sites, baseline,
    deploy_stats) — sites/baseline keyed by NumericsPolicy site name,
    deploy_stats the ``export_tt_deploy`` byte accounting."""
    from repro import numerics as N
    from repro.ckpt import export_tt_deploy

    policy = result["policy"]
    batch = result["batch"]
    wire_spec = policy.spec_for("dp_wire")
    if deploy_path is None:
        deploy_path = os.path.join(tempfile.mkdtemp(), "deploy.ckpt")
    deploy = export_tt_deploy(deploy_path, result["new_params"],
                              policy=policy)
    shapes = act_shapes(batch)
    sites = {
        # tt_factor: the packed int4x2 deploy export (two codes per byte)
        "tt_factor": deploy["packed_bytes"],
        # activation: the quant-edge sites at 8-bit, via policy.nbytes
        "activation": sum(policy.nbytes("activation", s) for s in shapes),
        # optimizer_moment: resident bytes of the int8 m/v QTensors
        "optimizer_moment": sum(
            m.nbytes() for m in (*result["opt"].m, *result["opt"].v)
            if isinstance(m, N.QTensor)),
        # dp_wire: int8 codes + block scales of each float gradient leaf
        "dp_wire": sum(
            N.encode(np.asarray(g).reshape(-1), wire_spec).nbytes()
            for g in jax.tree_util.tree_leaves(result["grads"])
            if hasattr(g, "dtype")
            and jnp.issubdtype(g.dtype, jnp.floating)),
    }
    dense_w = (896 * 512 + 512 * 16 + 512 + 16) * 4
    baseline = {
        "tt_factor": dense_w,
        "activation": sum(int(np.prod(s)) * 4 for s in shapes),
        "optimizer_moment": 2 * dense_w,
        "dp_wire": dense_w,
    }
    return sites, baseline, deploy


def quant_health_table(result: dict) -> dict:
    """Host-side quant-health of one step's gradient wire (repro.obs):
    clip/saturation fractions of the actual gradients under the exact
    scales the ``grad_edge`` / ``dp_wire`` quantizers use. Keys mirror the
    engine's ``ServeMetrics.summary()['quant_health']`` sites; CI smoke
    asserts every clip fraction is finite and < 0.5 at the seed config
    (grad_edge is clip-free by construction — per-tensor-max scale)."""
    from repro import numerics as N
    from repro.obs.counters import fraction, pow2_clip_stats, tree_sat_stats

    policy = result["policy"]
    gspec = policy.spec_for("grad_edge")
    leaves = [g for g in jax.tree_util.tree_leaves(result["grads"])
              if hasattr(g, "dtype")
              and jnp.issubdtype(g.dtype, jnp.floating)]
    clipped = total = 0
    for g in leaves:
        step = N.per_tensor_max_scale_log2(g, gspec)
        c, t = pow2_clip_stats(g, step, gspec.bits)
        clipped, total = clipped + int(c), total + int(t)
    gsat, gtot = tree_sat_stats(result["grads"], gspec)
    wsat, wtot = tree_sat_stats(result["grads"], policy.spec_for("dp_wire"))
    return {
        "grad_edge": {"clipped": clipped, "total": total,
                      "clip_fraction": clipped / max(total, 1),
                      "sat_fraction": float(fraction(gsat, gtot))},
        "dp_wire": {"total": int(wtot),
                    "clip_fraction": 0.0,   # blockwise per-block-max scale
                    "sat_fraction": float(fraction(wsat, wtot))},
    }


def live_memory_ledger(low: dict, deploy: dict, baseline: dict):
    """Populate a ``repro.obs.MemoryLedger`` from the live artifacts of the
    step just run — resident bytes measured off the actual arrays/QTensors
    (``moment_nbytes`` / ``wire_nbytes`` / ``residual_nbytes`` /
    ``policy.nbytes`` / the deploy export), fp32 shadows from the analytic
    dense baseline.  This is the train-side half of the ISSUE's live-vs-
    analytic Table-1 cross-check: the ledger's four-site reduction must
    agree with ``fmnist_site_table``'s ``reduction_x``."""
    from repro.obs import MemoryLedger
    from repro.optim.adam import moment_nbytes
    from repro.optim.grad_compress import residual_nbytes, wire_nbytes

    policy = low["policy"]
    led = MemoryLedger()
    led.set_phase("train_step")
    led.set("tt_factor", deploy["packed_bytes"], fp32=baseline["tt_factor"])
    led.set("activation",
            sum(policy.nbytes("activation", s)
                for s in act_shapes(low["batch"])),
            fp32=baseline["activation"])
    led.set("optimizer_moment", moment_nbytes(low["opt"])[0],
            fp32=baseline["optimizer_moment"])
    enc, _ = wire_nbytes(low["grads"], policy.spec_for("dp_wire"))
    led.set("dp_wire", enc, fp32=baseline["dp_wire"])
    res = residual_nbytes(low["residual"])
    if res:
        led.set("grad_residual", res)
    return led


def _time(fn, *args, iters: int, warmup: int = 1) -> float:
    out = None
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run(batch: int, iters: int, trace=None) -> dict:
    low = fmnist_low_precision_step(batch)
    sites, baseline, deploy = fmnist_site_table(low)
    t_q = _time(lambda: low["step"](low["new_params"], low["opt"],
                                    low["batch_arrays"], low["residual"]),
                iters=iters)
    if trace is not None:
        # per-step timeline of the low-precision step (the train-side
        # analogue of the serve bench's decode_step events)
        for i in range(iters):
            t0 = time.perf_counter()
            out = low["step"](low["new_params"], low["opt"],
                              low["batch_arrays"], low["residual"])
            jax.block_until_ready(out)
            trace.emit("train_step", step=i,
                       dur=time.perf_counter() - t0)

    # fp32 shadow (no compression, f32 moments)
    fp = fmnist_low_precision_step(batch, opt_dtype="float32",
                                   compress=False)
    t_f = _time(lambda: fp["step"](fp["new_params"], fp["opt"],
                                   fp["batch_arrays"], None), iters=iters)

    total = sum(sites.values())
    base = sum(baseline.values())
    led = live_memory_ledger(low, deploy, baseline)
    mem = led.summary()
    mem["table1_live_reduction_x"] = led.reduction_vs_fp32(TABLE1_SITES)
    mem["live_vs_analytic_frac"] = led.total(TABLE1_SITES) / max(total, 1)
    mem["reconcile"] = led.reconcile()
    return {
        "bench": "train_wire",
        "device": str(jax.devices()[0]),
        "jax_backend": jax.default_backend(),
        "batch": batch,
        "iters": iters,
        "loss_low_precision": float(low["loss"]),
        "loss_fp32": float(fp["loss"]),
        "step_ms_low_precision": t_q * 1e3,
        "step_ms_fp32": t_f * 1e3,
        "site_bytes": sites,
        "fp32_baseline_bytes": baseline,
        "total_bytes": total,
        "fp32_total_bytes": base,
        "reduction_x": base / total,
        "tt_deploy_reduction_x": deploy["reduction_x"],
        "quant_health": quant_health_table(low),
        "memory": mem,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny iteration count for CI")
    ap.add_argument("--trace-out", default="",
                    help="write per-step train_step trace events (JSONL)")
    ap.add_argument("--out", default="BENCH_train_wire.json")
    args = ap.parse_args()

    trace = None
    if args.trace_out:
        from repro.obs import TraceRecorder
        trace = TraceRecorder()
    doc = run(args.batch, 2 if args.smoke else args.iters, trace=trace)
    if trace is not None:
        from repro.obs import kernel_costs, write_jsonl
        n = write_jsonl(trace, args.trace_out)
        doc["telemetry"] = {"trace_jsonl": args.trace_out,
                            "trace_events": n,
                            "trace_capacity": trace.capacity,
                            "trace_dropped": trace.dropped,
                            "kernel_costs": kernel_costs()}
        print(f"[train_wire] wrote {n} trace events to {args.trace_out}")
    text = json.dumps(doc, indent=2)
    if args.out == "-":
        sys.stdout.write(text + "\n")
    else:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"[train_wire] reduction {doc['reduction_x']:.1f}x "
              f"(live {doc['memory']['table1_live_reduction_x']:.1f}x, "
              f"sites {doc['site_bytes']}) "
              f"step {doc['step_ms_low_precision']:.1f} ms "
              f"(fp32 {doc['step_ms_fp32']:.1f} ms) -> {args.out}")
        _history_append(doc)


if __name__ == "__main__":
    main()

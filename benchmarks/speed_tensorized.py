"""Paper §5 runtime comparison: tensorized fwd+bwd per batch of 64 vs the
dense baseline (the paper reports 0.09 s/batch on FPGA vs 5.34 s on an
embedded CPU for the tensorized model). We measure our JAX implementation on
this host CPU and derive the TPU-v5e FLOP-bound estimate from the FLOP
model."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import ttm
from repro.launch.mesh import PEAK_FLOPS_BF16
from repro.models import mlp_tt as MLP


def _time(f, *args, iters=20):
    jax.block_until_ready(f(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run() -> list[str]:
    rows = []
    d = MLP.make_mlp(prior=True, quantize=True)
    params = MLP.init_mlp(jax.random.PRNGKey(0), d)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 896))
    y = jax.random.randint(jax.random.PRNGKey(2), (64,), 0, 10)
    batch = {"x": x, "y": y}

    fwdbwd = jax.jit(jax.grad(lambda p, b: MLP.mlp_loss(p, b, d),
                              allow_int=True))
    t_tt = _time(lambda: jax.tree.leaves(fwdbwd(params, batch))[0], iters=20)
    rows.append(f"speed/tt_fwdbwd_batch64,{t_tt*1e6:.1f},"
                f"paper_fpga=9e4us paper_cpu=5.34e6us")

    # dense baseline of the same architecture
    w1 = jax.random.normal(jax.random.PRNGKey(3), (896, 512)) * 0.03
    w2 = jax.random.normal(jax.random.PRNGKey(4), (512, 10)) * 0.05

    def dense_loss(ws, batch):
        h = jax.nn.relu(batch["x"] @ ws[0])
        logits = h @ ws[1]
        return -jnp.mean(jnp.sum(jax.nn.one_hot(batch["y"], 10)
                                 * jax.nn.log_softmax(logits), -1))

    dgrad = jax.jit(jax.grad(dense_loss))
    t_d = _time(lambda: dgrad((w1, w2), batch)[0], iters=20)
    rows.append(f"speed/dense_fwdbwd_batch64,{t_d*1e6:.1f},ratio_tt/dense="
                f"{t_tt/t_d:.2f}")

    # FLOP-model derived v5e times (compute-bound floor)
    spec1 = d.spec1
    spec2 = d.spec2
    f_tt = 3 * (ttm.ttm_flops_matvec(spec1, 64)
                + ttm.ttm_flops_matvec(spec2, 64))
    f_dense = 3 * 2 * 64 * (896 * 512 + 512 * 10)
    rows.append(f"speed/tt_v5e_flop_floor,{f_tt/PEAK_FLOPS_BF16*1e6:.4f},"
                f"flops={f_tt}")
    rows.append(f"speed/dense_v5e_flop_floor,{f_dense/PEAK_FLOPS_BF16*1e6:.4f},"
                f"flops={f_dense}")
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)

"""Quantization-codec micro-benchmark: spec × backend × op sweep.

Times ``encode`` / ``decode`` / ``fake_quant`` for the policy's site specs
on both codec backends (reference jnp vs Pallas) and records achieved
GB/s plus the compression ratio of the quantized representation. Emits one
JSON document (the bench-trajectory format, ``BENCH_quant_codec.json``)
seeding the perf trajectory for the codec hot paths (KV-cache writes,
optimizer-state re-encode every step, DP wire).

    PYTHONPATH=src python benchmarks/quant_codec.py
    PYTHONPATH=src python benchmarks/quant_codec.py --smoke --out /tmp/b.json
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def bench_cell(site: str, spec, backend: str, n: int, iters: int) -> dict:
    from repro import numerics as N

    x = jax.random.normal(jax.random.PRNGKey(0), (n,)) * 3
    scale = jnp.asarray(-3.0)

    enc = jax.jit(lambda v: N.encode(v, spec, scale, backend=backend))
    qt = jax.block_until_ready(enc(x))
    dec = jax.jit(lambda q: N.decode(q, jnp.float32, backend=backend))
    fq = jax.jit(lambda v: N.fake_quant(v, spec, scale, backend=backend))

    t_enc = _time(enc, x, iters=iters)
    t_dec = _time(dec, qt, iters=iters)
    t_fq = _time(fq, x, iters=iters)
    return {
        "site": site,
        "kind": spec.kind,
        "bits": spec.bits,
        "block": spec.block,
        "storage": spec.storage_dtype,
        "backend": backend,
        "elements": n,
        "encode_s": t_enc,
        "decode_s": t_dec,
        "fake_quant_s": t_fq,
        "encode_gbps": x.nbytes / t_enc / 1e9,
        "decode_gbps": x.nbytes / t_dec / 1e9,
        "fake_quant_gbps": x.nbytes / t_fq / 1e9,
        "compression_x": x.nbytes / qt.nbytes(),
    }


def run_sweep(n: int, iters: int) -> dict:
    import dataclasses

    from repro import numerics as N

    pol = N.NumericsPolicy(enable=True)
    cells = []
    for site in N.SITES:
        spec = pol.spec_for(site)
        for backend in N.BACKENDS:
            cells.append(bench_cell(site, spec, backend, n, iters))
    # the packed-int4 deploy format (two codes per byte): tt_factor spec
    # with int4x2 storage — the ckpt export path's codec
    deploy = dataclasses.replace(pol.spec_for("tt_factor"),
                                 storage_dtype="int4x2")
    for backend in N.BACKENDS:
        cells.append(bench_cell("tt_factor_deploy", deploy, backend, n,
                                iters))
    return {
        "bench": "quant_codec",
        "device": str(jax.devices()[0]),
        "jax_backend": jax.default_backend(),
        "elements": n,
        "iters": iters,
        "cells": cells,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--elements", type=int, default=1 << 22)
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sweep for CI (correct shapes, trivial sizes)")
    ap.add_argument("--out", default="BENCH_quant_codec.json")
    args = ap.parse_args()

    n = 1 << 12 if args.smoke else args.elements
    iters = 2 if args.smoke else args.iters
    doc = run_sweep(n, iters)
    text = json.dumps(doc, indent=2)
    if args.out == "-":
        sys.stdout.write(text + "\n")
    else:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        slowest = max(doc["cells"], key=lambda c: c["encode_s"])
        print(f"[quant_codec] {len(doc['cells'])} cells -> {args.out} "
              f"(slowest encode: {slowest['site']}/{slowest['backend']} "
              f"{slowest['encode_s']*1e3:.2f} ms)")


if __name__ == "__main__":
    main()
